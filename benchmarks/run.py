"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,value,derived`` CSV.  The roofline table (§Roofline) is
produced by ``repro.roofline.analysis`` from the dry-run artifacts and is
summarized here when those artifacts exist.
"""

import importlib
import os
import sys
import traceback

MODULES = [
    "benchmarks.fig1_motivation",
    "benchmarks.fig3_no_caching",
    "benchmarks.fig4_active_tasks",
    "benchmarks.fig5_caching",
    "benchmarks.fig6_peak_usage",
    "benchmarks.fig7_starvation",
    "benchmarks.table3_spill",
    "benchmarks.kernel_micro",
    "benchmarks.serve_pressure",
    "benchmarks.serve_capacity_sweep",
]


def main() -> None:
    print("name,value,derived")
    failures = 0
    for name in MODULES:
        try:
            mod = importlib.import_module(name)
            mod.main()
        except Exception:
            failures += 1
            print(f"{name},ERROR,", file=sys.stdout)
            traceback.print_exc()
    # roofline summary (if dry-run artifacts are present)
    try:
        from repro.roofline.analysis import load_all

        cells = load_all("experiments/dryrun", "16x16")
        if cells:
            worst = min(cells, key=lambda c: c.roofline_fraction)
            best = max(cells, key=lambda c: c.roofline_fraction)
            print(f"roofline.cells,{len(cells)},16x16 baseline")
            print(
                f"roofline.worst,{worst.roofline_fraction:.4f},"
                f"{worst.arch}/{worst.shape} ({worst.bottleneck}-bound)"
            )
            print(
                f"roofline.best,{best.roofline_fraction:.4f},"
                f"{best.arch}/{best.shape} ({best.bottleneck}-bound)"
            )
    except Exception:
        traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
