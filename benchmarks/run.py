"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,value,derived`` CSV.  The serving benchmark additionally
returns a machine-readable record that is written to ``BENCH_serve.json``
(throughput, p50/p99 ticks-to-finish, offload count, GC time) so the
bench trajectory is tracked as an artifact, not just console text.

``--only SUBSTR[,SUBSTR...]`` runs the subset of modules whose name
contains any of the comma-separated substrings (the CI benchmark-smoke
job uses ``--only serve_pressure,kernel_micro``); ``--json PATH``
overrides the JSON output path.  When both serve_pressure and
kernel_micro run, the kernel microbench rows are merged into the JSON
record under the ``kernels`` key.  If ANY selected benchmark raises,
the run exits non-zero and the JSON artifact is NOT written — a partial
record would silently poison the benchmark trajectory and the CI
regression gate that consumes it.  The roofline table (§Roofline) is
produced by ``repro.roofline.analysis`` from the dry-run artifacts and is
summarized here when those artifacts exist.
"""

import argparse
import importlib
import json
import os
import sys
import traceback

# allow `python benchmarks/run.py` (script mode): the repo root must be on
# sys.path for the `benchmarks.*` module imports below
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

MODULES = [
    "benchmarks.fig1_motivation",
    "benchmarks.fig3_no_caching",
    "benchmarks.fig4_active_tasks",
    "benchmarks.fig5_caching",
    "benchmarks.fig6_peak_usage",
    "benchmarks.fig7_starvation",
    "benchmarks.table3_spill",
    "benchmarks.kernel_micro",
    "benchmarks.serve_pressure",
    "benchmarks.serve_capacity_sweep",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--only", default="",
        help="run only modules whose name contains one of these "
        "comma-separated substrings",
    )
    ap.add_argument(
        "--json", default="BENCH_serve.json",
        help="path for the machine-readable serving record",
    )
    args = ap.parse_args(argv)
    wanted = [s for s in args.only.split(",") if s]
    modules = [m for m in MODULES if not wanted or any(s in m for s in wanted)]
    if not modules:
        raise SystemExit(f"--only {args.only!r} matches no benchmark module")

    print("name,value,derived")
    failures = 0
    bench_record = None
    kernel_record = None
    for name in modules:
        try:
            mod = importlib.import_module(name)
            result = mod.main()
            if name.endswith("serve_pressure") and isinstance(result, dict):
                bench_record = result
            if name.endswith("kernel_micro") and isinstance(result, dict):
                kernel_record = result
        except Exception:
            failures += 1
            print(f"{name},ERROR,", file=sys.stdout)
            traceback.print_exc()
    if bench_record is not None and kernel_record is not None:
        bench_record["kernels"] = kernel_record
    if failures:
        # a partial artifact would poison the benchmark trajectory (and the
        # CI regression gate): write NOTHING and exit non-zero below
        print(
            f"bench.json,SKIPPED,{failures} benchmark(s) raised — "
            "refusing to write a partial record",
            file=sys.stderr,
        )
    elif bench_record is not None:
        with open(args.json, "w") as f:
            json.dump(bench_record, f, indent=2, sort_keys=True)
        print(f"bench.json,{args.json},machine-readable serving record")
    # roofline summary (if dry-run artifacts are present)
    try:
        from repro.roofline.analysis import load_all

        cells = load_all("experiments/dryrun", "16x16")
        if cells:
            worst = min(cells, key=lambda c: c.roofline_fraction)
            best = max(cells, key=lambda c: c.roofline_fraction)
            print(f"roofline.cells,{len(cells)},16x16 baseline")
            print(
                f"roofline.worst,{worst.roofline_fraction:.4f},"
                f"{worst.arch}/{worst.shape} ({worst.bottleneck}-bound)"
            )
            print(
                f"roofline.best,{best.roofline_fraction:.4f},"
                f"{best.arch}/{best.shape} ({best.bottleneck}-bound)"
            )
    except Exception:
        traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
