"""Benchmark regression gate: compare BENCH_serve.json against a baseline.

CI's ``benchmark-gate`` job feeds this the record the benchmark-smoke job
just produced (same-workflow artifact) and the committed
``BENCH_baseline.json``; the PR fails on a >15% regression of any gated
metric and the full delta table lands in the job summary
(``$GITHUB_STEP_SUMMARY``) either way.

Gated metrics, per engine policy (fair / murs / priority):

    p50_ticks_to_finish            lower is better
    p99_ticks_to_finish            lower is better
    throughput_tokens_per_tick     higher is better

and per tiered leg (reactive / proactive):

    spilled_bytes                  lower is better (HBM→host traffic)
    disk_spill_bytes               lower is better (the paper's spill)
    throughput_tokens_per_tick     higher is better

and per cluster leg (round_robin / murs / straggler / crash):

    p99_ticks_to_finish            lower is better (cluster tail latency)
    throughput_tokens_per_tick     higher is better (cluster-wide)

and per overload front-door mode (fair / murs):

    goodput                        higher is better (SLO-met per tick)
    completed                      higher is better
    throughput_tokens_per_tick     higher is better

and per model-zoo routing mode (fair / murs):

    p99_ticks_to_finish            lower is better (mixed-fleet tail)
    completed                      higher is better

plus the prefix-cache acceptance bits (hit rate positive, shared peak
below the no-sharing baseline), the tiering bit (proactive demotion at
least halves disk spill at equal load), the cluster bits (live
migration round-trips with nothing lost, a replica crash loses no
requests, usage-rate placement beats round-robin on p99), and the
overload bits (usage-rate shedding beats FIFO shedding on goodput at
equal open-loop load; the door sheds instead of collapsing), the
model-zoo bits (every architecture class completes on the mixed fleet,
the router never places a request on an incapable replica, class-aware
routing's tail no worse than round-robin's), and the
elastic bits (a delta cutover ships fewer bytes than a full copy, a
checkpoint restore replays only the uncovered suffix, autoscaled
goodput holds against the static fleet), and the memory-ledger bit
(``ledger_matches_recount``: the class-stamped ledger's incremental
byte tallies equal a ground-truth recount after every policy run) as
hard pass/fail rows — those are correctness claims of the artifact, not
noisy timings, so they gate at any regression.

A policy that completed nothing reports ``None`` percentiles; ``None``
where the baseline had a number is a hard failure (the policy stopped
serving), and a missing baseline file passes with a notice (first run).

Usage:
    python benchmarks/gate.py [--current BENCH_serve.json]
                              [--baseline BENCH_baseline.json]
                              [--threshold 15] [--summary PATH]
"""

import argparse
import json
import os
import sys

#: (metric key, direction) — direction is which way REGRESSION points
GATED = [
    ("p50_ticks_to_finish", "lower_is_better"),
    ("p99_ticks_to_finish", "lower_is_better"),
    ("throughput_tokens_per_tick", "higher_is_better"),
]

#: tiered-leg metrics, gated per mode (reactive / proactive)
TIER_GATED = [
    ("spilled_bytes", "lower_is_better"),
    ("disk_spill_bytes", "lower_is_better"),
    ("throughput_tokens_per_tick", "higher_is_better"),
]

#: tiered-leg acceptance booleans (hard pass/fail, no threshold)
TIER_WIN_BITS = ("disk_spill_halved", "compression_measured")

#: cluster-leg metrics, gated per mode (round_robin / murs / straggler /
#: crash)
CLUSTER_GATED = [
    ("p99_ticks_to_finish", "lower_is_better"),
    ("throughput_tokens_per_tick", "higher_is_better"),
]

#: cluster-leg acceptance booleans (hard pass/fail, no threshold):
#: migration round-trips deliver with nothing lost, a crash loses no
#: requests, and usage-rate placement beats round-robin on tail latency
CLUSTER_WIN_BITS = (
    "migration_roundtrip",
    "crash_no_loss",
    "p99_beats_round_robin",
)

#: overload-leg metrics, gated per front-door mode (fair / murs)
OVERLOAD_GATED = [
    ("goodput", "higher_is_better"),
    ("completed", "higher_is_better"),
    ("throughput_tokens_per_tick", "higher_is_better"),
]

#: overload-leg acceptance booleans (hard pass/fail, no threshold):
#: usage-rate shedding yields more SLO goodput than FIFO shedding at
#: equal open-loop load, and the door sheds instead of collapsing
OVERLOAD_WIN_BITS = (
    "goodput_under_overload",
    "shed_not_collapse",
)

#: model-zoo-leg metrics, gated per routing mode (fair / murs)
MODEL_ZOO_GATED = [
    ("p99_ticks_to_finish", "lower_is_better"),
    ("completed", "higher_is_better"),
]

#: model-zoo-leg acceptance booleans (hard pass/fail, no threshold):
#: every architecture class completes its whole stream on the mixed
#: fleet, the router never places a request on a replica hosting a
#: different arch (zero misroutes / unroutable), and class-aware
#: routing's tail is no worse than round-robin's
MODEL_ZOO_WIN_BITS = (
    "mixed_fleet_completes_all_archs",
    "router_never_places_on_incapable_replica",
    "murs_p99_le_fair_p99",
)

#: elastic-leg acceptance booleans (hard pass/fail, no threshold): a
#: delta cutover ships strictly fewer bytes than the monolithic copy it
#: replaced, a crash restore replays only the checkpoint-uncovered
#: suffix, and autoscaling's fixed-horizon goodput does not fall below
#: the static fleet's at equal peak HBM
ELASTIC_WIN_BITS = (
    "delta_migration_bytes_below_full_copy",
    "checkpoint_restore_no_replay_from_zero",
    "elastic_goodput_ge_static",
)

#: memory-ledger acceptance booleans (hard pass/fail, no threshold):
#: the class-stamped ledger's incremental tallies must equal a
#: ground-truth recount at the end of every policy run — a drifting
#: byte counter is a correctness bug, not a noisy timing
MEMORY_WIN_BITS = ("ledger_matches_recount",)


def _delta_pct(base: float, cur: float) -> float:
    if base == 0:
        return 0.0 if cur == 0 else float("inf")
    return 100.0 * (cur - base) / base


def _compare_row(
    label, metric, direction, base, cur, threshold_pct, rows, failures,
    none_fails=False,
):
    """One gated comparison: appends to ``rows`` and, on regression, to
    ``failures`` — shared by the engine-policy and tiered-leg loops so
    the threshold semantics can never diverge between them."""
    if base is None:
        rows.append((label, metric, base, cur, None, "no baseline"))
        return
    if cur is None:
        if none_fails:
            rows.append((label, metric, base, cur, None, "FAIL"))
            failures.append(
                f"{label}.{metric}: baseline {base}, current None "
                "(policy completed nothing)"
            )
        return
    delta = _delta_pct(base, cur)
    if direction == "lower_is_better":
        regressed = delta > threshold_pct
    else:
        regressed = delta < -threshold_pct
    rows.append(
        (label, metric, base, cur, delta, "FAIL" if regressed else "ok")
    )
    if regressed:
        failures.append(
            f"{label}.{metric}: {base} → {cur} "
            f"({delta:+.1f}% vs ±{threshold_pct:.0f}% gate)"
        )


def compare(baseline: dict, current: dict, threshold_pct: float):
    """Returns (rows, failures): one row per policy×metric, failures as
    human-readable strings."""
    rows, failures = [], []
    policies = sorted(
        set(baseline.get("engine", {})) & set(current.get("engine", {}))
    )
    for pol in policies:
        b_row = baseline["engine"][pol]
        c_row = current["engine"][pol]
        for metric, direction in GATED:
            _compare_row(
                pol, metric, direction, b_row.get(metric),
                c_row.get(metric), threshold_pct, rows, failures,
                none_fails=True,
            )
    # tiered-leg metrics: same threshold semantics, per mode
    tiers_b = baseline.get("tiering", {})
    tiers_c = current.get("tiering", {})
    for mode in ("reactive", "proactive"):
        b_row, c_row = tiers_b.get(mode), tiers_c.get(mode)
        if not isinstance(b_row, dict) or not isinstance(c_row, dict):
            continue
        for metric, direction in TIER_GATED:
            _compare_row(
                f"tier.{mode}", metric, direction, b_row.get(metric),
                c_row.get(metric), threshold_pct, rows, failures,
            )
    # cluster-leg metrics: same threshold semantics, per routing mode
    cl_b = baseline.get("cluster", {})
    cl_c = current.get("cluster", {})
    for mode in ("round_robin", "murs", "straggler", "crash"):
        b_row, c_row = cl_b.get(mode), cl_c.get(mode)
        if not isinstance(b_row, dict) or not isinstance(c_row, dict):
            continue
        for metric, direction in CLUSTER_GATED:
            _compare_row(
                f"cluster.{mode}", metric, direction, b_row.get(metric),
                c_row.get(metric), threshold_pct, rows, failures,
                none_fails=True,
            )
    # overload-leg metrics: open-loop goodput per front-door mode
    ov_b = baseline.get("overload", {})
    ov_c = current.get("overload", {})
    for mode in ("fair", "murs"):
        b_row, c_row = ov_b.get(mode), ov_c.get(mode)
        if not isinstance(b_row, dict) or not isinstance(c_row, dict):
            continue
        for metric, direction in OVERLOAD_GATED:
            _compare_row(
                f"overload.{mode}", metric, direction, b_row.get(metric),
                c_row.get(metric), threshold_pct, rows, failures,
                none_fails=True,
            )
    # model-zoo-leg metrics: heterogeneous-fleet tail and completions
    mz_b = baseline.get("model_zoo", {})
    mz_c = current.get("model_zoo", {})
    for mode in ("fair", "murs"):
        b_row, c_row = mz_b.get(mode), mz_c.get(mode)
        if not isinstance(b_row, dict) or not isinstance(c_row, dict):
            continue
        for metric, direction in MODEL_ZOO_GATED:
            _compare_row(
                f"model_zoo.{mode}", metric, direction, b_row.get(metric),
                c_row.get(metric), threshold_pct, rows, failures,
                none_fails=True,
            )
    # model-zoo acceptance bits: all archs complete on the mixed fleet,
    # the router respects capability, MURS tail no worse — hard pass/fail
    mz_wins = mz_c.get("model_zoo_wins", {})
    for bit in MODEL_ZOO_WIN_BITS:
        if bit in mz_wins:
            ok = bool(mz_wins[bit])
            rows.append(
                ("model_zoo", bit, True, mz_wins[bit], None,
                 "ok" if ok else "FAIL")
            )
            if not ok:
                failures.append(f"model_zoo.{bit} is False")
    # overload acceptance bits: MURS shedding beats FIFO shedding on
    # goodput at equal load, and shedding prevents collapse — hard
    # pass/fail
    overload_wins = ov_c.get("overload_wins", {})
    for bit in OVERLOAD_WIN_BITS:
        if bit in overload_wins:
            ok = bool(overload_wins[bit])
            rows.append(
                ("overload", bit, True, overload_wins[bit], None,
                 "ok" if ok else "FAIL")
            )
            if not ok:
                failures.append(f"overload.{bit} is False")
    # cluster acceptance bits: live migration delivers, crashes lose
    # nothing, placement beats round-robin — hard pass/fail
    cluster_wins = cl_c.get("cluster_wins", {})
    for bit in CLUSTER_WIN_BITS:
        if bit in cluster_wins:
            ok = bool(cluster_wins[bit])
            rows.append(
                ("cluster", bit, True, cluster_wins[bit], None,
                 "ok" if ok else "FAIL")
            )
            if not ok:
                failures.append(f"cluster.{bit} is False")
    # elastic acceptance bits: delta cutover below full copy, checkpoint
    # restore beats replay-from-zero, elastic goodput holds — hard
    # pass/fail
    elastic_wins = current.get("elastic", {}).get("elastic_wins", {})
    for bit in ELASTIC_WIN_BITS:
        if bit in elastic_wins:
            ok = bool(elastic_wins[bit])
            rows.append(
                ("elastic", bit, True, elastic_wins[bit], None,
                 "ok" if ok else "FAIL")
            )
            if not ok:
                failures.append(f"elastic.{bit} is False")
    # memory-ledger acceptance bit: incremental class tallies equal the
    # ground-truth recount after every policy run — hard pass/fail
    mem_wins = current.get("memory", {}).get("memory_wins", {})
    for bit in MEMORY_WIN_BITS:
        if bit in mem_wins:
            ok = bool(mem_wins[bit])
            rows.append(
                ("memory", bit, True, mem_wins[bit], None,
                 "ok" if ok else "FAIL")
            )
            if not ok:
                failures.append(f"memory.{bit} is False")
    # prefix-cache acceptance bits: hard booleans, no threshold
    wins = current.get("prefix_cache", {}).get("sharing_wins", {})
    for bit in ("hit_rate_positive", "peak_pool_lower"):
        if bit in wins:
            ok = bool(wins[bit])
            rows.append(
                ("prefix_cache", bit, True, wins[bit], None,
                 "ok" if ok else "FAIL")
            )
            if not ok:
                failures.append(f"prefix_cache.{bit} is False")
    # tiering acceptance bits: the paper's spill claim is a hard gate
    tier_wins = tiers_c.get("tiering_wins", {})
    for bit in TIER_WIN_BITS:
        if bit in tier_wins:
            ok = bool(tier_wins[bit])
            rows.append(
                ("tiering", bit, True, tier_wins[bit], None,
                 "ok" if ok else "FAIL")
            )
            if not ok:
                failures.append(f"tiering.{bit} is False")
    # kernel-cost bit: overload and cluster legs must report tick costs
    # DERIVED from the roofline model (non-constant, in seconds) — a
    # present leg with a missing or constant tick_cost section means the
    # serving loop silently fell back to hand-set cost constants, a hard
    # FAIL.  Absent legs are skipped, same as the other hard bits.
    checked = [
        (f"overload.{mode}", ov_c.get(mode))
        for mode in ("fair", "murs")
    ] + [
        (f"cluster.{mode}", cl_c.get(mode))
        for mode in ("round_robin", "murs")
    ]
    checked = [(label, row) for label, row in checked
               if isinstance(row, dict)]
    derived, why = True, []
    for label, row in checked:
        tc = row.get("tick_cost")
        if not isinstance(tc, dict):
            derived, why = False, why + [f"{label}: no tick_cost"]
        elif tc.get("source") != "roofline":
            derived = False
            why = why + [f"{label}: source={tc.get('source')!r}"]
        elif tc.get("distinct", 0) <= 1:
            derived = False
            why = why + [f"{label}: constant ({tc.get('distinct')})"]
    if checked:
        rows.append(
            ("kernels", "kernel_costs_derived", True, derived, None,
             "ok" if derived else "FAIL")
        )
        if not derived:
            failures.append(
                "kernels.kernel_costs_derived is False: " + "; ".join(why)
            )
    return rows, failures


def markdown_table(rows, threshold_pct: float) -> str:
    lines = [
        "## Benchmark gate",
        "",
        f"Regression threshold: ±{threshold_pct:.0f}% "
        "(ticks-to-finish lower-is-better, throughput higher-is-better)",
        "",
        "| policy | metric | baseline | current | Δ% | status |",
        "|---|---|---:|---:|---:|---|",
    ]
    for pol, metric, base, cur, delta, status in rows:
        d = "—" if delta is None else f"{delta:+.1f}%"
        badge = "❌ FAIL" if status == "FAIL" else (
            "✅ ok" if status == "ok" else status
        )
        lines.append(f"| {pol} | {metric} | {base} | {cur} | {d} | {badge} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_serve.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument(
        "--threshold", type=float, default=15.0,
        help="regression threshold in percent (default 15)",
    )
    ap.add_argument(
        "--summary",
        default=os.environ.get("GITHUB_STEP_SUMMARY", ""),
        help="markdown summary file to append to "
        "(default: $GITHUB_STEP_SUMMARY when set)",
    )
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if not os.path.exists(args.baseline):
        msg = (
            f"## Benchmark gate\n\nNo baseline at `{args.baseline}` — "
            "first run passes; commit the current record as the baseline.\n"
        )
        print(msg)
        if args.summary:
            with open(args.summary, "a") as f:
                f.write(msg)
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    rows, failures = compare(baseline, current, args.threshold)
    table = markdown_table(rows, args.threshold)
    print(table)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(table)
    if failures:
        print("REGRESSIONS:", file=sys.stderr)
        for fail in failures:
            print(f"  {fail}", file=sys.stderr)
        return 1
    print(f"gate: {len(rows)} comparisons within ±{args.threshold:.0f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
