"""Paper Table III: spill counts/sizes, Spark vs MURS.

Paper: WC 9%→0%, PR 32%→2.5% of tasks spill; MURS cuts spills ~90%."""

from .common import emit, make_pr, make_wc, murs, run_service


def main() -> None:
    heap = 13.0  # pressure point where the baseline spills
    fair = run_service([make_pr(), make_wc()], heap_gb=heap, oom_is_fatal=False)
    m = run_service([make_pr(), make_wc()], heap_gb=heap, murs=murs(),
                    oom_is_fatal=False)
    total_f = total_m = 0
    for app in ("wc", "pr"):
        f, mm = fair.jobs[app], m.jobs[app]
        emit(f"table3.fair.{app}.spills", f.spills,
             f"{100.0 * f.spills / max(f.tasks_total, 1):.1f}% of tasks")
        emit(f"table3.murs.{app}.spills", mm.spills,
             f"{100.0 * mm.spills / max(mm.tasks_total, 1):.1f}% of tasks")
        emit(f"table3.fair.{app}.spill_mb", round(f.spilled_bytes / 1e6, 1))
        emit(f"table3.murs.{app}.spill_mb", round(mm.spilled_bytes / 1e6, 1))
        total_f += f.spills
        total_m += mm.spills
    red = 100.0 * (1 - total_m / total_f) if total_f else 0.0
    emit("table3.spill_reduction_pct", round(red, 1), "paper: ~90%")


if __name__ == "__main__":
    main()
