"""Paper Fig. 6: per-task peak memory — MURS lets running tasks use MORE."""

from .common import emit, make_pr, make_wc, murs, run_service


def main() -> None:
    heap = 15.0
    fair = run_service([make_pr(), make_wc()], heap_gb=heap, oom_is_fatal=False)
    m = run_service([make_pr(), make_wc()], heap_gb=heap, murs=murs(),
                    oom_is_fatal=False)
    for tag, res in (("fair", fair), ("murs", m)):
        peaks = sorted(res.peak_task_live.values())
        if peaks:
            emit(f"fig6.{tag}.peak_task_mb_p50",
                 round(peaks[len(peaks) // 2] / 1e6, 1))
            emit(f"fig6.{tag}.peak_task_mb_max", round(peaks[-1] / 1e6, 1))
        emit(f"fig6.{tag}.min_active", res.min_active_tasks)


if __name__ == "__main__":
    main()
