"""Paper Fig. 5: caching group (PR+WC) heap sweep; OOM floors.

Paper claims: Spark throws OME at ≤17 GB heaps while MURS still serves at
15 GB; where both work MURS improves exec by up to 23.4% and cuts memory
pressure (GC) by 65.4%.  We sweep the heap down to find each scheduler's
OOM floor and report exec/GC above it.
"""

from .common import emit, make_pr, make_wc, murs, pct_change, run_service

HEAPS = (20.0, 17.0, 15.0, 13.0, 12.0, 11.0, 10.0, 9.0)


def main() -> None:
    floor = {"fair": None, "murs": None}
    best_exec = best_gc = 0.0
    for heap in HEAPS:
        fair = run_service([make_pr(), make_wc()], heap_gb=heap,
                           oom_is_fatal=True)
        m = run_service([make_pr(), make_wc()], heap_gb=heap, murs=murs(),
                        oom_is_fatal=True)
        emit(f"fig5.h{heap:g}.fair_oom", int(fair.oom))
        emit(f"fig5.h{heap:g}.murs_oom", int(m.oom))
        if fair.oom and floor["fair"] is None:
            floor["fair"] = heap
        if m.oom and floor["murs"] is None:
            floor["murs"] = heap
        if not fair.oom and not m.oom:
            f_exec = max(j.exec_time for j in fair.jobs.values())
            m_exec = max(j.exec_time for j in m.jobs.values())
            f_gc = fair.total_gc_time
            m_gc = m.total_gc_time
            emit(f"fig5.h{heap:g}.exec_fair", round(f_exec, 1))
            emit(f"fig5.h{heap:g}.exec_murs", round(m_exec, 1))
            emit(f"fig5.h{heap:g}.gc_fair", round(f_gc, 1))
            emit(f"fig5.h{heap:g}.gc_murs", round(m_gc, 1))
            best_exec = max(best_exec, pct_change(f_exec, m_exec))
            best_gc = max(best_gc, pct_change(f_gc, m_gc))
    emit("fig5.oom_floor_fair_gb", floor["fair"] or "none",
         "paper: Spark OOM at <=17GB")
    emit("fig5.oom_floor_murs_gb", floor["murs"] or "none",
         "paper: MURS serves at 15GB")
    emit("fig5.best_exec_improvement_pct", round(best_exec, 1),
         "paper: up to 23.4%")
    emit("fig5.best_gc_reduction_pct", round(best_gc, 1), "paper: 65.4%")


if __name__ == "__main__":
    main()
