"""Paper Fig. 3: no-caching group (Sort+WC+Grep), heap sweep, FAIR vs MURS.

Paper claim: MURS improves submissions by 1.8×–2.9×, driven by GC
reduction.  We report per-heap exec/GC for both schedulers and the best
observed improvement ratios.
"""

from .common import emit, make_grep, make_sort, make_wc, murs, run_service

HEAPS = (5.0, 6.0, 8.0, 10.0)


def main() -> None:
    best_exec = best_gc = 0.0
    for heap in HEAPS:
        jobs = lambda: [make_sort(), make_wc(), make_grep()]
        fair = run_service(jobs(), heap_gb=heap, oom_is_fatal=False)
        m = run_service(jobs(), heap_gb=heap, murs=murs(), oom_is_fatal=False)
        for app in ("sort", "wc", "grep"):
            f, mm = fair.jobs[app], m.jobs[app]
            emit(f"fig3.h{heap:g}.exec_fair.{app}", round(f.exec_time, 1))
            emit(f"fig3.h{heap:g}.exec_murs.{app}", round(mm.exec_time, 1))
            emit(f"fig3.h{heap:g}.gc_fair.{app}", round(f.gc_time, 1))
            emit(f"fig3.h{heap:g}.gc_murs.{app}", round(mm.gc_time, 1))
            if mm.exec_time > 0:
                best_exec = max(best_exec, f.exec_time / mm.exec_time)
            if mm.gc_time > 0:
                best_gc = max(best_gc, 1 - mm.gc_time / f.gc_time)
    emit("fig3.best_exec_ratio", round(best_exec, 2), "paper: up to 2.9x")
    emit("fig3.best_gc_reduction_pct", round(100 * best_gc, 1), "paper: up to 81%")


if __name__ == "__main__":
    main()
