"""Live OOM-floor sweep (the engine analogue of paper Fig. 5).

Sweep the KV pool capacity downward with offload disabled (hard OOM
semantics) and find the smallest capacity at which each scheduler still
completes the whole workload — the paper's "MURS still provides service
when the heap is reduced" claim, measured on real JAX decodes.
"""

import jax

from repro.configs import ARCHS
from repro.sched import FairPolicy, MursConfig, MursPolicy
from repro.models import init_model
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import kv_bytes_per_token
from .common import emit

CAPACITIES_TOKENS = (160, 120, 100, 80, 70, 60, 50)


def _requests():
    reqs = [Request(f"A{i}", "A", list(range(10, 18)), 40) for i in range(3)]
    reqs += [Request(f"B{i}", "B", list(range(30, 34)), 6) for i in range(4)]
    return reqs


def main() -> None:
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    per_tok = kv_bytes_per_token(cfg)
    floor = {"fair": None, "murs": None}
    for tokens in CAPACITIES_TOKENS:
        policies = (("fair", FairPolicy),
                    ("murs", lambda: MursPolicy(MursConfig.for_serving(period=1.0))))
        for mode, make_policy in policies:
            eng = ServingEngine(
                cfg, params,
                EngineConfig(n_slots=4, max_seq=64,
                             hbm_capacity_bytes=per_tok * tokens,
                             policy=make_policy(), offload_enabled=False),
            )
            for r in _requests():
                eng.submit(r)
            rep = eng.run(max_ticks=600)
            ok = rep.failed == 0 and rep.completed == 7
            emit(f"sweep.cap{tokens}.{mode}.complete", int(ok),
                 f"failed={rep.failed} "
                 f"susp={rep.extras['suspensions']}")
            if ok:
                floor[mode] = tokens  # last (smallest) capacity that works
    emit("sweep.service_floor_fair_tokens", floor["fair"] or "never",
         "smallest pool (in KV tokens) where stock scheduling still serves")
    emit("sweep.service_floor_murs_tokens", floor["murs"] or "never",
         "paper Fig 5: MURS serves at smaller memory than the baseline")


if __name__ == "__main__":
    main()
