"""JAX serving engine under HBM pressure: MURS admission vs FAIR.

The paper's technique as a first-class serving feature: two tenants share
one engine; the KV pool is sized to force pressure.  FAIR OOM-evicts;
MURS suspends heavy decodes and completes everything (§VI-C scalability).
"""

import jax

from repro.configs import ARCHS
from repro.core.scheduler import MursConfig
from repro.models import init_model
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import kv_bytes_per_token
from .common import emit


def _requests():
    reqs = [Request(f"A{i}", "A", list(range(10, 18)), 40) for i in range(3)]
    reqs += [Request(f"B{i}", "B", list(range(30, 34)), 6) for i in range(4)]
    return reqs


def main() -> None:
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    cap = kv_bytes_per_token(cfg) * 80
    for mode, sched in (("fair", None), ("murs", MursConfig(period=1.0))):
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=4, max_seq=64, hbm_capacity_bytes=cap,
                         scheduler=sched),
        )
        for r in _requests():
            eng.submit(r)
        out = eng.run(max_ticks=400)
        emit(f"serve.{mode}.completed", out["completed"], "of 7 requests")
        emit(f"serve.{mode}.failed", out["failed"])
        emit(f"serve.{mode}.suspensions", out["suspensions"])
        emit(f"serve.{mode}.peak_used_fraction",
             round(out["peak_used_fraction"], 2))
        emit(f"serve.{mode}.tokens_generated", out["tokens_generated"])
        emit(f"serve.{mode}.offloads", out["offload_events"],
             "paper Table III: MURS avoids ~90% of spills")
    # online §III classification of a decode request (MURS engine)
    eng = ServingEngine(
        cfg, params,
        EngineConfig(n_slots=2, max_seq=64, hbm_capacity_bytes=cap * 100,
                     scheduler=MursConfig(period=1.0)),
    )
    eng.submit(Request("probe", "T", list(range(8)), 20))
    out = eng.run(max_ticks=200)
    emit("serve.murs.decode_memory_model", out["memory_models"]["probe"],
         "paper SIII online classification (attention decode = linear)")


if __name__ == "__main__":
    main()
