"""JAX serving engine under sustained HBM pressure: one workload, three
policies.

The paper's technique as a first-class serving feature, in the paper's own
SERVICE setting (§II): two tenants submit a sustained stream of requests
into one engine whose KV pool is sized to force pressure — tenant A sends
long decodes (linear KV growth), tenant B short interactive ones.  The
SAME engine runs under :class:`FairPolicy` (stock: fills the pool, pays
reactive offloads and residency stalls), :class:`MursPolicy` with the
serving-tuned config (admission control + suspension + frozen-KV swap;
zero reactive offloads) and :class:`PriorityPolicy` (tenant-weighted).
Policy swaps, not code paths.

A second leg exercises the PREFIX-SHARING cache in the paper's key
pressure shape: many tenants, one shared system prompt.  The same stream
runs with the prefix cache on (pages dedup'd by the token trie, prefill
skipped for cached tokens) and off (every request pays for its own copy),
at equal tenant load — recording hit rate, dedup'd bytes, time-to-first-
token, and the peak pool fraction both ways.

A fourth leg runs the CLUSTER: two engine replicas behind the
``placement_score`` router, identical load and straggler injection both
ways, round-robin vs usage-rate-aware placement — with live KV
migration off the throttled replica and a crash-requeue run (the
``cluster`` record and its ``cluster_wins`` acceptance bits).

An ELASTIC leg runs the diurnal trace against an autoscaled cluster
(scale-out on sustained pressure, drain via incremental pre-copy +
delta cutover on slack, periodic compressed KV checkpoints with a
mid-stream crash restore) vs a static fleet at equal peak HBM — the
``elastic`` record and its ``elastic_wins`` acceptance bits.

A fifth leg is OPEN-LOOP OVERLOAD: ≥1000 seeded Poisson arrivals pushed
through the admission :class:`FrontDoor` at a rate the engine cannot
absorb, fair vs MURS shedding at equal load.  The record's headline is
SLO goodput (the ``overload`` key and its ``overload_wins`` bits), plus
a paired tick-rate measurement of the engine's incremental vs legacy
per-request bookkeeping (``overload.bookkeeping``).

Besides the CSV rows every benchmark emits, :func:`collect` returns the
machine-readable record ``benchmarks/run.py`` writes to
``BENCH_serve.json``: throughput, p50/p99 ticks-to-finish, offload count,
prefix-cache trajectory, and the paired simulator GC time per policy —
plus the ``memory`` key: each policy run's class-stamped ledger summary
(per-:class:`~repro.serve.PageClass` bytes and peaks, per-tier bytes)
and the ``memory_wins.ledger_matches_recount`` hard bit asserting the
incremental tallies equal a ground-truth recount.
"""

import os
import tempfile
import time

import jax

from repro.configs import ARCHS
from repro.models import init_model
from repro.sched import (
    FairPolicy,
    MursConfig,
    MursPolicy,
    PriorityConfig,
    PriorityPolicy,
)
from repro.serve import (
    ClusterConfig,
    EngineConfig,
    FrontDoor,
    FrontDoorConfig,
    Request,
    ServingCluster,
    ServingEngine,
    SloSpec,
    TenantProfile,
    diurnal_trace,
    drive,
    poisson_trace,
)
from repro.serve.kv_cache import kv_bytes_per_token
from .common import emit, make_grep, make_sort, run_service


def _arrivals(debug: bool = False):
    """(submit_tick, request) stream: heavy tenant A + interactive tenant B."""
    n_waves, gen_a = (2, 16) if debug else (4, 40)
    evs, t = [], 0
    for i in range(n_waves):
        evs.append((t, Request(f"A{i}", "A", list(range(10, 18)), gen_a)))
        t += 10
        for j in range(2):
            evs.append((t, Request(f"B{i}_{j}", "B", list(range(30, 34)), 6)))
            t += 3
    return evs


def _percentile(sorted_vals, q: float):
    if not sorted_vals:
        return None
    idx = min(len(sorted_vals) - 1, round(q * (len(sorted_vals) - 1)))
    return sorted_vals[idx]


def _shared_prompt_arrivals(debug: bool = False):
    """Shared-system-prompt mix: one 32-token system prompt, many tenants.

    The first request warms the trie; the rest of the stream arrives a
    tick apart with unique one-token user suffixes and 12-token decodes,
    so several copies of the system prompt are live AT ONCE — the worst
    case for naive per-request KV and the best case for page-granular
    dedup."""
    system = list(range(100, 132))
    n = 4 if debug else 8
    evs = [(0, Request("S0", "T0", system + [200], 12))]
    t = 2
    for i in range(1, n):
        evs.append((t, Request(f"S{i}", f"T{i % 4}", system + [200 + i], 12)))
        t += 1
    return evs


def _collect_prefix_sharing(cfg, params, debug: bool = False) -> dict:
    """The dedup leg: identical tenant load, prefix cache on vs off.

    Runs under the stock FairPolicy (no admission clamp) so the peak is
    the workload's own footprint, not the scheduler's red line.  Two peaks
    are recorded: raw pool usage, and DEMAND — usage net of reclaimable
    (cold, instantly evictable) cached pages, the page-cache notion of
    available memory.  Dedup's claim is about demand: fewer live bytes for
    the same tenant load."""
    cap = kv_bytes_per_token(cfg) * 16 * 12  # 12-page pool
    out = {}
    for mode, enabled in (("shared", True), ("baseline_no_sharing", False)):
        eng = ServingEngine(
            cfg, params,
            EngineConfig(
                n_slots=6, max_seq=64, hbm_capacity_bytes=cap,
                policy=FairPolicy(),
                prefix_cache=enabled,
            ),
        )
        res = _run_stream(eng, _shared_prompt_arrivals(debug))
        ttft = res["ttft_ticks"]
        out[mode] = {
            "completed": res["completed"],
            "failed": res["failed"],
            "peak_used_fraction": round(res["peak_used_fraction"], 3),
            "peak_demand_fraction": round(res["peak_demand_fraction"], 3),
            "offload_count": res["offload_events"],
            "makespan_ticks": res["ticks"],
            "ttft_p50_ticks": _percentile(ttft, 0.50),
            "ttft_p99_ticks": _percentile(ttft, 0.99),
            "prefix": res["prefix_cache"],
        }
    pc = out["shared"]["prefix"]
    out["hit_rate"] = round(pc["token_hit_rate"], 3)
    out["dedup_bytes"] = pc["dedup_bytes"]
    out["prefill_tokens_skipped"] = pc["prefill_tokens_skipped"]
    out["cow_events"] = pc["cow_events"]
    out["sharing_wins"] = {
        # the ISSUE's acceptance criteria, recorded in the artifact
        "hit_rate_positive": pc["token_hit_rate"] > 0.0,
        "peak_pool_lower": (
            out["shared"]["peak_demand_fraction"]
            < out["baseline_no_sharing"]["peak_demand_fraction"]
        ),
    }
    return out


def _collect_tiering(cfg, params, debug: bool = False) -> dict:
    """The TIERED leg: reactive-only vs proactive tiering at equal load.

    Same arrival stream, same tier hierarchy (small host tier, modeled
    PCIe link, int8 compression), two policies: FAIR has no
    ``demotion_pressure`` so it only ever pays the REACTIVE spill path —
    big synchronous demotion bursts that overflow the host tier into
    disk; MURS suspends heavy tenants and proactively demotes their
    frozen KV page by page, so the same load fits the fast tiers.  The
    disk-tier traffic is the paper's "data spilling" metric (Table III:
    MURS cuts it ~90%).

    This leg always runs the same BURST stream (debug's shrunken waves
    are too light to pressure the hierarchy at all — both legs would
    record zero spill and the acceptance bit would be vacuous): four
    heavy decodes and six interactive requests arriving within three
    ticks of each other, the paper's service-burst shape.  FAIR admits
    the burst wholesale and its reactive demotions park the whole
    overcommit below HBM at once; MURS queues at the red line, suspends
    the heavy tail early (small frozen buffers), and parks only those."""
    del debug
    page_bytes = kv_bytes_per_token(cfg) * 16

    def _burst_arrivals():
        evs = [
            (0, Request(f"A{i}", "A", list(range(10, 18)), 40))
            for i in range(4)
        ]
        evs += [
            (i % 3, Request(f"B{i}", "B", list(range(30, 34)), 6))
            for i in range(6)
        ]
        return sorted(evs, key=lambda e: e[0])

    out = {}
    legs = (
        ("reactive", lambda: FairPolicy()),
        ("proactive", lambda: MursPolicy(MursConfig.for_serving(period=1.0))),
    )
    for mode, make_policy in legs:
        eng = ServingEngine(
            cfg, params,
            EngineConfig(
                n_slots=4, max_seq=64,
                hbm_capacity_bytes=page_bytes * 5,  # 5-page pool
                policy=make_policy(),
                # host tier ~4 compressed pages at rest: the reactive
                # burst parks more than it fits and overflows to disk;
                # early page-granular frozen demotion parks less at once
                host_capacity_bytes=page_bytes * 2.0,
                # one raw page per tick (half a tick per compressed
                # page): slow enough that reactive bursts pay visible
                # transfer stalls, fast enough that nobody livelocks
                pcie_bytes_per_tick=page_bytes * 1.0,
                # eager tiering: demote within the policy's own band
                # (the engine default only catches excursions above it)
                demote_threshold=0.8,
                # the dedup cache would blur the frozen-KV signal — this
                # leg isolates the demotion mechanism (the prefix leg
                # above measures sharing on its own)
                prefix_cache=False,
            ),
        )
        res = _run_stream(eng, _burst_arrivals())
        t = res["tiers"]
        out[mode] = {
            "completed": res["completed"],
            "failed": res["failed"],
            "suspensions": res["suspensions"],
            "offload_count": res["offload_events"],
            "proactive_demotions": res["proactive_demotions"],
            "spilled_bytes": t["spilled_bytes"],
            "wire_bytes": t["wire_bytes"],
            "disk_spill_bytes": t["disk_spill_bytes"],
            "disk_read_bytes": t["disk_read_bytes"],
            "host_peak_bytes": t["host_peak_bytes"],
            "compression_ratio": round(t["compression_ratio"], 3),
            "max_quant_error": t["max_quant_error"],
            "transfer_stall_ticks": res["transfer_stall_ticks"],
            "stall_ticks": res["stall_ticks"],
            "makespan_ticks": res["ticks"],
            "tokens_generated": res["tokens_generated"],
            "throughput_tokens_per_tick": round(
                res["tokens_generated"] / max(res["ticks"], 1), 3
            ),
        }
    rx, px = out["reactive"], out["proactive"]
    out["tiering_wins"] = {
        # the ISSUE's acceptance criteria, recorded in the artifact:
        # proactive tiering must at least HALVE disk spill at equal load
        "disk_spill_halved": (
            rx["disk_spill_bytes"] > 0
            and px["disk_spill_bytes"] <= 0.5 * rx["disk_spill_bytes"]
        ),
        "compression_measured": px["compression_ratio"] > 1.5
        or rx["compression_ratio"] > 1.5,
        "served_no_worse": px["completed"] >= rx["completed"],
    }
    return out


def _collect_cluster(cfg, params, debug: bool = False) -> dict:
    """The CLUSTER leg: usage-rate-aware placement vs round-robin across
    two replicas at equal load, with the fault substrate live.

    Same arrival stream (heavy decodes interleaved with interactive
    ones), same MURS engines on every replica — the only variable in the
    placement pair is the ROUTER: FairPolicy sprays round-robin (packing
    every heavy request onto one replica, which pays the tail),
    MursPolicy scores ``placement_score`` (least load, blended by the
    tenant usage-rate EMA) and splits them.

    Two fault legs run the same stream through the `repro.dist.fault`
    machinery: a STRAGGLER leg genuinely throttles replica 0 by 6× —
    the StragglerDetector pass over replica tick-service-times flags it
    and live-migrates its requests (extracted KV crosses a modeled
    network link compressed, re-installs on the healthy replica, same
    generated tokens) — and a CRASH leg kills replica 0 mid-stream: its
    requests lose their KV but are requeued (RestartManager-style
    bounded, capped backoff) and every submitted request still completes
    — the `crash_no_loss` acceptance bit."""
    del debug  # sized for signal, small enough for the CI smoke job
    cap = kv_bytes_per_token(cfg) * 80

    def engine_factory():
        return EngineConfig(
            n_slots=3, max_seq=64, hbm_capacity_bytes=cap,
            policy=MursPolicy(MursConfig.for_serving(period=1.0)),
        )

    def _arrival_stream():
        evs, t = [], 0
        for i in range(3):
            evs.append((t, Request(f"H{i}", "A", list(range(10, 18)), 32)))
            evs.append((t + 1, Request(f"L{i}", "B", list(range(30, 34)), 6)))
            t += 2
        return evs

    def _run(router, slow_at=None, crash_at=None):
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=engine_factory, n_replicas=2, router=router,
                straggler_min_samples=4,
                net_bytes_per_tick=kv_bytes_per_token(cfg) * 16,
            ),
        )
        evs, k = _arrival_stream(), 0
        while cl.tick < 600 and (k < len(evs) or cl.has_pending):
            while k < len(evs) and evs[k][0] <= cl.tick:
                cl.submit(evs[k][1])
                k += 1
            if slow_at is not None and cl.tick == slow_at:
                cl.set_slowdown(0, 6.0)
            if crash_at is not None and cl.tick == crash_at:
                cl.crash_replica(0)
            cl.step()
        return cl.run(max_ticks=600).extras

    def _row(out):
        lat = out["latency_ticks"]
        return {
            "completed": out["completed"],
            "failed": out["failed"],
            "lost": out["lost"],
            "crashes": out["crashes"],
            "requeued": out["requeued"],
            "straggler_flags": out["straggler_flags"],
            "migrations_started": out["migrations"]["started"],
            "migrations_completed": out["migrations"]["completed"],
            "migration_raw_bytes": out["migrations"]["raw_bytes"],
            "migration_wire_bytes": out["migrations"]["wire_bytes"],
            "makespan_ticks": out["ticks"],
            "tokens_generated": out["tokens_generated"],
            "throughput_tokens_per_tick": round(
                out["tokens_generated"] / max(out["ticks"], 1), 3
            ),
            "p50_ticks_to_finish": _percentile(lat, 0.50),
            "p99_ticks_to_finish": _percentile(lat, 0.99),
            # roofline-derived per-tick service time (seconds), merged
            # across replicas — the units straggler detection and
            # placement scoring now run in
            "tick_cost": out["tick_cost"],
        }

    murs_router = lambda: MursPolicy(MursConfig.for_serving(period=1.0))
    legs = {
        # placement comparison: identical load, healthy replicas — the
        # ONLY variable is the router (round-robin packs every heavy
        # request onto one replica; demand-aware placement splits them)
        "round_robin": _row(_run(FairPolicy())),
        "murs": _row(_run(murs_router())),
        # fault legs: same stream under a 6×-throttled replica (the
        # straggler pass live-migrates its requests off) and under a
        # mid-stream replica crash (bounded-retry requeue)
        "straggler": _row(_run(murs_router(), slow_at=6)),
        "crash": _row(_run(murs_router(), crash_at=8)),
    }
    n = len(_arrival_stream())
    rr, mu = legs["round_robin"], legs["murs"]
    sg, cr = legs["straggler"], legs["crash"]
    legs["n_requests"] = n
    legs["cluster_wins"] = {
        # the ISSUE's acceptance criteria, recorded in the artifact:
        # usage-rate placement beats round-robin on tail completion time
        # at equal load
        "p99_beats_round_robin": (
            mu["p99_ticks_to_finish"] is not None
            and rr["p99_ticks_to_finish"] is not None
            and mu["p99_ticks_to_finish"] < rr["p99_ticks_to_finish"]
        ),
        # at least one LIVE migration delivered (extracted KV crossed
        # the link, re-installed, request finished elsewhere) with
        # nothing lost under a genuinely throttled replica
        "migration_roundtrip": (
            sg["migrations_completed"] >= 1
            and sg["completed"] == n
            and sg["lost"] == 0
        ),
        # a replica crash requeues its requests instead of losing them
        "crash_no_loss": cr["completed"] == n and cr["lost"] == 0,
    }
    return legs


def _collect_model_zoo(debug: bool = False) -> dict:
    """The MODEL-ZOO leg: a heterogeneous fleet serving three architecture
    memory classes at once, fair vs MURS routing at equal load.

    Four replicas host three DIFFERENT models: two run a paged-KV
    transformer (internlm2 smoke), one a constant-state SSM (mamba2
    smoke), one a paged-KV MoE (granite smoke).  Every request carries
    ``Request.model`` and the router may only place it on a replica that
    hosts that architecture — the capability partition the tentpole
    added.  The transformer traffic has a real placement choice (two
    capable replicas); the SSM and MoE tenants each have exactly one, so
    the leg also proves single-capable routing never misroutes.

    The pair differs ONLY in the router: FairPolicy round-robins inside
    each capability set, MursPolicy blends slot load with the per-tenant
    usage-rate EMA — clamped by the DECLARED memory class, so the
    constant-state tenant's EMA never marks it heavy no matter how long
    its decodes run.  The acceptance bits: every arch completes all its
    requests, zero misroutes/unroutable rows ever happen, and the MURS
    tail is no worse than fair's."""
    del debug  # sized for signal, small enough for the CI smoke job
    zoo = [
        ("internlm2-1.8b", "T"),   # paged_kv — hosted twice (see below)
        ("mamba2-2.7b", "M"),      # constant_state
        ("granite-moe-3b-a800m", "E"),   # paged_kv, MoE routing weights
    ]
    cfgs = {name: ARCHS[name].smoke() for name, _ in zoo}
    prms = {
        name: init_model(cfg, jax.random.PRNGKey(i))
        for i, (name, cfg) in enumerate(cfgs.items())
    }
    tcfg = cfgs["internlm2-1.8b"]
    cap = max(
        kv_bytes_per_token(c) * 80 + c.constant_state_bytes()
        for c in cfgs.values()
    )
    models = [
        (cfgs["internlm2-1.8b"], prms["internlm2-1.8b"]),
        (cfgs["internlm2-1.8b"], prms["internlm2-1.8b"]),
        (cfgs["mamba2-2.7b"], prms["mamba2-2.7b"]),
        (cfgs["granite-moe-3b-a800m"], prms["granite-moe-3b-a800m"]),
    ]

    def engine_factory():
        return EngineConfig(
            n_slots=3, max_seq=64, hbm_capacity_bytes=cap,
            policy=MursPolicy(MursConfig.for_serving(period=1.0)),
        )

    def _arrival_stream():
        t_model = cfgs["internlm2-1.8b"].name
        m_model = cfgs["mamba2-2.7b"].name
        e_model = cfgs["granite-moe-3b-a800m"].name
        evs, t = [], 0
        for i in range(4):
            evs.append((t, Request(f"T{i}", "T", list(range(10, 18)), 24,
                                   model=t_model)))
            evs.append((t + 1, Request(f"M{i}", "M", list(range(30, 36)), 8,
                                       model=m_model)))
            if i < 3:
                evs.append((t + 1, Request(f"E{i}", "E", list(range(50, 56)),
                                           8, model=e_model)))
            t += 2
        return evs

    def _run(router):
        cl = ServingCluster(
            tcfg, prms["internlm2-1.8b"],
            ClusterConfig(
                engine=engine_factory, n_replicas=4, router=router,
                net_bytes_per_tick=kv_bytes_per_token(tcfg) * 16,
            ),
            models=models,
        )
        evs, k = _arrival_stream(), 0
        while cl.tick < 600 and (k < len(evs) or cl.has_pending):
            while k < len(evs) and evs[k][0] <= cl.tick:
                cl.submit(evs[k][1])
                k += 1
            cl.step()
        rep = cl.run(max_ticks=600)
        out = rep.extras
        lat = out["latency_ticks"]
        return {
            "completed": rep.completed,
            "failed": rep.failed,
            "unroutable": out["unroutable"],
            "misroutes": out["misroutes"],
            "hosted_models": out["hosted_models"],
            "makespan_ticks": out["ticks"],
            "p50_ticks_to_finish": _percentile(lat, 0.50),
            "p99_ticks_to_finish": _percentile(lat, 0.99),
            "per_model": rep.model_summary(),
        }

    legs = {
        "fair": _run(FairPolicy()),
        "murs": _run(MursPolicy(MursConfig.for_serving(period=1.0))),
    }
    n = len(_arrival_stream())
    arch_names = [cfgs[name].name for name, _ in zoo]
    legs["n_requests"] = n
    legs["fleet"] = {
        "replicas": [c.name for c, _ in models],
        "memory_classes": {
            cfgs[name].name: cfgs[name].memory_class() for name, _ in zoo
        },
    }
    fair, murs = legs["fair"], legs["murs"]

    def _all_archs_complete(row):
        per = row["per_model"]
        return row["completed"] == n and all(
            per.get(a, {}).get("completed", 0) > 0 for a in arch_names
        )

    legs["model_zoo_wins"] = {
        # the ISSUE's acceptance criteria, recorded in the artifact:
        # every architecture class completes its whole stream, both ways
        "mixed_fleet_completes_all_archs": (
            _all_archs_complete(fair) and _all_archs_complete(murs)
        ),
        # no request was ever handed to a replica hosting a different
        # arch (engine-level misroute counter) or dropped as unroutable
        "router_never_places_on_incapable_replica": (
            fair["misroutes"] == 0 and murs["misroutes"] == 0
            and fair["unroutable"] == 0 and murs["unroutable"] == 0
        ),
        # class-aware routing's tail is no worse than round-robin's
        "murs_p99_le_fair_p99": (
            murs["p99_ticks_to_finish"] is not None
            and fair["p99_ticks_to_finish"] is not None
            and murs["p99_ticks_to_finish"] <= fair["p99_ticks_to_finish"]
        ),
    }
    return legs


def _collect_elastic(cfg, params, debug: bool = False) -> dict:
    """The ELASTIC leg: autoscaling + delta migration + checkpointing
    against the diurnal trace, vs a static fleet at equal peak HBM.

    The elastic cluster starts at ONE replica with autoscaling on
    (``scale_pressure`` over ``replica_stats``, hysteresis + cooldown):
    the diurnal day spawns replicas up to the static fleet's size, the
    night drains them back via incremental pre-copy + delta cutover.
    Periodic compressed KV checkpoints run throughout, and a mid-stream
    replica crash restores from the latest checkpoint — replaying only
    the uncovered suffix, counted against the from-zero counterfactual.
    A planned maintenance drain (``drain_replica``) is issued at a busy
    tick so the delta path moves LIVE work: the pre-copy ships warm
    pages in the background and the cutover ships only pages dirtied
    since, recorded against the monolithic-copy counterfactual.

    The static fleet runs the SAME trace on ``max_replicas`` engines
    with identical per-replica HBM — equal peak capacity — so the
    goodput comparison isolates what elasticity costs (spin-up lag,
    migration traffic) against what it saves (``replica_ticks``, the
    replica-occupancy integral).  Goodput is scored over a FIXED horizon
    so a slower elastic makespan cannot inflate its own denominator."""
    del debug  # sized for signal, small enough for the CI smoke job
    cap = kv_bytes_per_token(cfg) * 80
    horizon = 400.0

    def engine_factory():
        return EngineConfig(
            n_slots=4, max_seq=64, hbm_capacity_bytes=cap,
            policy=MursPolicy(MursConfig.for_serving(period=1.0)),
        )

    tenants = [
        TenantProfile("interactive", weight=2.0, prompt_tokens=(2, 6),
                      output_tokens=(4, 8)),
        TenantProfile("batch", weight=1.0, prompt_tokens=(8, 14),
                      output_tokens=(24, 40)),
    ]
    evs = diurnal_trace(
        tenants, base_rate_per_tick=0.25, n_requests=60,
        period_ticks=100.0, amplitude=0.9, seed=42,
    )
    murs_router = lambda: MursPolicy(MursConfig.for_serving(period=1.0))

    def _run(elastic, drain_at=None, crash_at=None):
        ckpt_dir = tempfile.mkdtemp(prefix="bench_elastic_ckpt_")
        if elastic:
            cc = ClusterConfig(
                engine=engine_factory, router=murs_router(),
                net_bytes_per_tick=kv_bytes_per_token(cfg) * 16,
                n_replicas=1, autoscale=True,
                min_replicas=1, max_replicas=3,
                scale_up_pressure=0.6, scale_down_pressure=0.35,
                scale_sustain_ticks=5, scale_cooldown_ticks=10,
                checkpoint_every_ticks=10, checkpoint_dir=ckpt_dir,
            )
        else:
            cc = ClusterConfig(
                engine=engine_factory, router=murs_router(),
                net_bytes_per_tick=kv_bytes_per_token(cfg) * 16,
                n_replicas=3,
            )
        cl = ServingCluster(cfg, params, cc)
        k, replica_ticks, crashed, drained = 0, 0, False, False
        while cl.tick < 600 and (k < len(evs) or cl.has_pending):
            while k < len(evs) and evs[k].tick <= cl.tick:
                cl.submit(evs[k].request)
                k += 1
            if crash_at is not None and not crashed and cl.tick >= crash_at:
                crashed = True
                cl.crash_replica(0)
            if drain_at is not None and not drained and cl.tick >= drain_at:
                drained = True
                live = {
                    i: sum(
                        1 for r in cl.replicas[i].requests.values()
                        if r.state not in ("done", "failed")
                    )
                    for i in cl._active_indices()
                }
                cl.drain_replica(max(live, key=lambda i: live[i]))
            replica_ticks += len(cl._active_indices())
            cl.step()
        rep = cl.run(max_ticks=0)
        rep.apply_slo(default=SloSpec(latency_ticks=250.0))
        return cl, rep, replica_ticks

    def _row(cl, rep, replica_ticks):
        return {
            "completed": rep.completed,
            "lost": rep.extras.get("lost", 0),
            "makespan_ticks": cl.tick,
            "slo_good": rep.slo_good,
            "goodput_at_horizon": round(rep.slo_good / horizon, 4),
            "replica_ticks": replica_ticks,
        }

    e_cl, e_rep, e_rt = _run(True, drain_at=65, crash_at=40)
    s_cl, s_rep, s_rt = _run(False)
    legs = {
        "n_requests": len(evs),
        "horizon_ticks": horizon,
        "elastic": {
            **_row(e_cl, e_rep, e_rt),
            "scale_ups": e_cl.scale_ups,
            "scale_downs": e_cl.scale_downs,
            "peak_replicas": e_cl.peak_replicas,
            "precopies": e_cl.precopies_started,
            "delta_cutovers": e_cl.delta_cutovers,
            "precopy_wire_bytes": e_cl.migration_precopy_wire_bytes,
            "delta_wire_bytes": e_cl.migration_delta_wire_bytes,
            "full_wire_bytes": e_cl.migration_full_wire_bytes,
            "ckpt_saved": e_cl.ckpt_saved,
            "ckpt_restored_requests": e_cl.ckpt_restored_requests,
            "ckpt_restored_tokens": e_cl.ckpt_restored_tokens,
            "ckpt_replayed_tokens": e_cl.ckpt_replayed_tokens,
            "ckpt_from_zero_tokens": e_cl.ckpt_from_zero_tokens,
        },
        "static": _row(s_cl, s_rep, s_rt),
    }
    el, st = legs["elastic"], legs["static"]
    legs["elastic_wins"] = {
        # the delta cutover ships strictly fewer bytes than the
        # monolithic copy it replaced would have (and at least one ran)
        "delta_migration_bytes_below_full_copy": (
            el["delta_cutovers"] >= 1
            and 0 < el["delta_wire_bytes"] < el["full_wire_bytes"]
        ),
        # a crash restores from the checkpoint and replays only the
        # uncovered suffix — strictly below the from-zero counterfactual
        "checkpoint_restore_no_replay_from_zero": (
            el["ckpt_restored_requests"] >= 1
            and el["ckpt_replayed_tokens"] < el["ckpt_from_zero_tokens"]
        ),
        # at equal peak HBM, autoscaling's fixed-horizon goodput does not
        # fall below the always-on static fleet's
        "elastic_goodput_ge_static": (
            el["goodput_at_horizon"] >= st["goodput_at_horizon"]
        ),
    }
    return legs


def _overload_tenants():
    """Two tenants in the paper's service shape: a chatty INTERACTIVE
    tenant (3× the arrival weight, tiny requests, tight SLO) and a BATCH
    tenant whose rarer requests are ~6× the bytes — the group actually
    growing the pool fastest, and the one usage-rate shedding targets."""
    return (
        TenantProfile("interactive", weight=3.0, prompt_tokens=(2, 6),
                      output_tokens=(2, 6)),
        TenantProfile("batch", weight=1.0, prompt_tokens=(8, 16),
                      output_tokens=(24, 48)),
    )


def _overload_slos():
    return {
        "interactive": SloSpec(ttft_ticks=40.0, latency_ticks=80.0),
        "batch": SloSpec(latency_ticks=400.0),
    }


def _collect_overload(cfg, params, debug: bool = False) -> dict:
    """The OPEN-LOOP overload leg: ≥1000 Poisson arrivals against a pool
    sized for a fraction of them, fair vs MURS front doors at EQUAL load.

    Closed-loop legs (one in, one out) can never overload — the client
    self-throttles.  Here the seeded trace submits on ITS schedule; what
    differs per leg is only the policy, at the door (shed order: FIFO vs
    highest-usage-rate-first) and inside the engine (admission clamp +
    suspension).  The headline is GOODPUT — SLO-met completions per tick
    — the metric the paper's throughput collapses into once latency
    targets exist.  Fair sheds whatever group arrived first (the cheap
    interactive traffic); MURS sheds the batch tenant whose projected
    bytes grow the pool fastest, so the same rejection budget protects
    far more SLO-compliant completions.

    Always ≥1000 arrivals, debug included: overload is the one leg whose
    signal vanishes if the stream is shrunk below saturation."""
    del debug
    n_requests, max_ticks = 1000, 900
    cap = kv_bytes_per_token(cfg) * 16 * 6  # 6-page pool: ~a dozen live
    tenants = _overload_tenants()

    def run_mode(make_policy):
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=4, max_seq=64, hbm_capacity_bytes=cap,
                         policy=make_policy()),
        )
        door = FrontDoor(
            eng,
            FrontDoorConfig(
                pressure_threshold=0.9,
                default_bucket=(1.5, 24.0),  # generous: shedding decides
                slos=_overload_slos(),
            ),
        )
        trace = poisson_trace(
            tenants, rate_per_tick=2.0, n_requests=n_requests, seed=20260809
        )
        rep = drive(door, trace, max_ticks=max_ticks)
        lat, ttft, tpot = rep.latency, rep.ttft, rep.tpot
        return {
            "submitted": rep.submitted,
            "admitted": rep.extras["admitted"],
            "shed": rep.shed,
            "rate_limited": rep.rate_limited,
            "completed": rep.completed,
            "failed": rep.failed,
            "unfinished": sum(
                1 for o in rep.outcomes if o.outcome == "unfinished"
            ),
            "slo_good": rep.slo_good,
            "goodput": round(rep.goodput, 4),
            "throughput_tokens_per_tick": round(
                rep.tokens_generated / max(rep.ticks, 1), 3
            ),
            "ticks": rep.ticks,
            "latency_p50_ticks": lat.p50,
            "latency_p95_ticks": lat.p95,
            "latency_p99_ticks": lat.p99,
            "ttft_p50_ticks": ttft.p50,
            "ttft_p95_ticks": ttft.p95,
            "tpot_p50_ticks": tpot.p50,
            "shed_by_tenant": rep.extras["shed_by_tenant"],
            # roofline-derived per-tick cost stats (seconds): the gate's
            # kernel_costs_derived bit asserts these are non-constant
            "tick_cost": rep.extras["tick_cost"],
        }

    out = {
        "n_requests": n_requests,
        "max_ticks": max_ticks,
        "rate_per_tick": 2.0,
        "fair": run_mode(FairPolicy),
        "murs": run_mode(
            lambda: MursPolicy(MursConfig.for_serving(period=1.0))
        ),
    }
    fair, murs = out["fair"], out["murs"]
    out["overload_wins"] = {
        # the ISSUE's acceptance criteria, recorded in the artifact:
        # usage-rate shedding protects more SLO traffic per rejection
        "goodput_under_overload": murs["goodput"] > fair["goodput"],
        # the door sheds INSTEAD of collapsing: rejections happen, yet
        # the engine keeps completing work and nothing dies of OOM
        "shed_not_collapse": (
            murs["shed"] > 0
            and murs["completed"] > 0
            and murs["failed"] == 0
        ),
    }
    out["bookkeeping"] = _collect_bookkeeping(cfg, params)
    return out


def _collect_bookkeeping(cfg, params) -> dict:
    """Tick-rate cost of the per-request Python bookkeeping, isolated.

    The two bookkeeping modes make bit-identical decisions (the test
    suite asserts it), so their decode compute is common mode — and at
    smoke scale that JAX compute is ~99% of a busy tick, burying the
    Python delta in noise.  This run therefore holds the decode path
    idle (zero slots: the 2000-deep queue is the open-loop leg's regime,
    nothing ever admits) so a tick costs exactly the per-request
    bookkeeping the open-loop leg pays ON TOP of model compute every
    tick: legacy mode rescans the queue and live set (O(queue) per
    tick), the default incremental maps read them off directly."""
    n_requests, n_ticks = 2000, 200

    def ticks_per_sec(legacy: bool) -> float:
        eng = ServingEngine(
            cfg, params,
            EngineConfig(
                n_slots=0, max_seq=64, hbm_capacity_bytes=1e9,
                policy=MursPolicy(MursConfig.for_serving(period=1.0)),
                legacy_bookkeeping=legacy,
            ),
        )
        # fresh Request objects per run — the engine mutates them
        trace = poisson_trace(
            _overload_tenants(), rate_per_tick=4.0, n_requests=n_requests,
            seed=7,
        )
        for arrival in trace:
            eng.submit(arrival.request)
        for _ in range(5):  # settle any first-tick laziness off the clock
            eng.step()
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            eng.step()
        return n_ticks / max(time.perf_counter() - t0, 1e-9)

    # best-of-3 per mode minimizes container scheduling noise
    legacy = max(ticks_per_sec(True) for _ in range(3))
    fast = max(ticks_per_sec(False) for _ in range(3))
    return {
        "queued_requests": n_requests,
        "timed_ticks": n_ticks,
        "legacy_ticks_per_sec": round(legacy, 2),
        "vectorized_ticks_per_sec": round(fast, 2),
        "tick_rate_speedup": round(fast / max(legacy, 1e-9), 3),
    }


def _policies():
    return (
        ("fair", lambda: FairPolicy()),
        ("murs", lambda: MursPolicy(MursConfig.for_serving(period=1.0))),
        (
            "priority",
            lambda: PriorityPolicy(
                PriorityConfig(weights={"B": 4.0, "A": 1.0})
            ),
        ),
    )


def _run_stream(eng: ServingEngine, arrivals, max_ticks: int = 800) -> dict:
    k = 0
    while eng.tick < max_ticks and k < len(arrivals):
        while k < len(arrivals) and arrivals[k][0] <= eng.tick:
            eng.submit(arrivals[k][1])
            k += 1
        eng.step()
    # legacy-shaped payload: these legs predate ServeReport and read the
    # flat dict keys (the typed fields feed the overload leg below)
    return eng.run(max_ticks=max_ticks).extras


def collect(debug: bool = False) -> dict:
    """Run the pressure stream under every policy; JSON-ready record."""
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    cap = kv_bytes_per_token(cfg) * 80
    record = {
        "workload": {
            "arch": "internlm2-1.8b (smoke)",
            "n_requests": len(_arrivals(debug)),
            "hbm_capacity_tokens": 80,
            "service_mode": "sustained stream (paper SII)",
            "debug": debug,
        },
        "engine": {},
        "sim": {},
    }
    mem_by_mode = {}
    for mode, make_policy in _policies():
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=4, max_seq=64, hbm_capacity_bytes=cap,
                         policy=make_policy()),
        )
        # fresh Request objects per run — the engine mutates them
        out = _run_stream(eng, _arrivals(debug))
        mem_by_mode[mode] = out["memory"]
        lat = out["latency_ticks"]
        record["engine"][mode] = {
            "completed": out["completed"],
            "failed": out["failed"],
            "suspensions": out["suspensions"],
            "offload_count": out["offload_events"],
            "swap_count": out["swap_events"],
            "stall_ticks": out["stall_ticks"],
            "peak_used_fraction": round(out["peak_used_fraction"], 3),
            "makespan_ticks": out["ticks"],
            "tokens_generated": out["tokens_generated"],
            "throughput_tokens_per_tick": round(
                out["tokens_generated"] / max(out["ticks"], 1), 3
            ),
            "mean_ticks_to_finish": (
                round(sum(lat) / len(lat), 2) if lat else None
            ),
            "p50_ticks_to_finish": _percentile(lat, 0.50),
            "p99_ticks_to_finish": _percentile(lat, 0.99),
            "ttft_p50_ticks": _percentile(out["ttft_ticks"], 0.50),
            "chunked_prefill_ticks": out["chunked_prefill_ticks"],
            "prefix_token_hit_rate": round(
                out["prefix_cache"].get("token_hit_rate", 0.0), 3
            ),
        }
    # the paired simulator run supplies the GC-time axis the engine has no
    # analogue for (stop-the-world collector pauses, paper Table III)
    if not debug:
        for mode, kwargs in (("fair", {}), ("murs", {"murs": MursConfig()})):
            m = run_service(
                [make_sort(), make_grep()], heap_gb=6.0, oom_is_fatal=False,
                **kwargs,
            )
            record["sim"][mode] = {
                "gc_time_s": round(m.total_gc_time, 3),
                "makespan_s": round(m.sim_time, 2),
                "full_gcs": m.full_gcs,
                "spills": sum(j.spills for j in m.jobs.values()),
            }
    # class-stamped ledger leg (DESIGN.md §13): the per-class memory
    # breakdown each policy run ended with, plus the self-check hard
    # bit — the incremental tallies must equal a ground-truth recount
    record["memory"] = dict(mem_by_mode)
    record["memory"]["memory_wins"] = {
        "ledger_matches_recount": all(
            bool(m.get("ledger_matches_recount"))
            for m in mem_by_mode.values()
        ),
    }
    # prefix-sharing leg: shared system prompt, cache on vs off at equal
    # tenant load (the ISSUE acceptance record)
    record["prefix_cache"] = _collect_prefix_sharing(cfg, params, debug)
    # tiered leg: reactive-only vs proactive demotion at equal load — the
    # paper's data-spilling claim, measured as disk-tier traffic
    record["tiering"] = _collect_tiering(cfg, params, debug)
    # cluster leg: usage-rate placement vs round-robin across replicas,
    # with live migration off a straggler and crash-requeue recovery
    record["cluster"] = _collect_cluster(cfg, params, debug)
    # model-zoo leg: a heterogeneous fleet (paged-KV transformer + MoE +
    # constant-state SSM) behind the capability-aware router, fair vs MURS
    record["model_zoo"] = _collect_model_zoo(debug)
    # elastic leg: autoscaling + delta migration + checkpoint restore on
    # the diurnal trace, vs a static fleet at equal peak HBM
    record["elastic"] = _collect_elastic(cfg, params, debug)
    # open-loop overload leg: ≥1000 Poisson arrivals through the front
    # door, fair vs MURS shedding at equal load — goodput is the headline
    record["overload"] = _collect_overload(cfg, params, debug)
    # online §III classification of a decode request (MURS engine, no
    # pressure) — reuses the already-initialized model
    probe_eng = ServingEngine(
        cfg, params,
        EngineConfig(n_slots=2, max_seq=64, hbm_capacity_bytes=cap * 100,
                     policy=MursPolicy(MursConfig(period=1.0))),
    )
    probe_eng.submit(Request("probe", "T", list(range(8)), 20))
    probe_out = probe_eng.run(max_ticks=200).extras
    record["probe_memory_model"] = probe_out["memory_models"]["probe"]
    fair, murs = record["engine"]["fair"], record["engine"]["murs"]
    murs_p50, fair_p50 = murs["p50_ticks_to_finish"], fair["p50_ticks_to_finish"]
    record["murs_beats_fair"] = {
        # median request completion time — the serving SLO metric.  (FAIR
        # wins raw makespan in this cheap-offload regime by overcommitting
        # into host memory; see DESIGN.md §5 for the regime discussion.)
        # None = that policy completed nothing: it cannot win the axis.
        "completion_time_p50": (
            murs_p50 is not None
            and (fair_p50 is None or murs_p50 < fair_p50)
        ),
        "offload_count": murs["offload_count"] < fair["offload_count"],
        "completed": murs["completed"] >= fair["completed"],
    }
    return record


def main() -> dict:
    debug = bool(os.environ.get("BENCH_DEBUG"))
    record = collect(debug=debug)
    for mode, row in record["engine"].items():
        emit(f"serve.{mode}.completed", row["completed"],
             f"of {record['workload']['n_requests']} requests")
        emit(f"serve.{mode}.failed", row["failed"])
        emit(f"serve.{mode}.suspensions", row["suspensions"])
        emit(f"serve.{mode}.peak_used_fraction", row["peak_used_fraction"])
        emit(f"serve.{mode}.tokens_generated", row["tokens_generated"])
        emit(f"serve.{mode}.throughput", row["throughput_tokens_per_tick"],
             "tokens/tick")
        emit(f"serve.{mode}.p50_ticks", row["p50_ticks_to_finish"],
             "median request completion time")
        emit(f"serve.{mode}.p99_ticks", row["p99_ticks_to_finish"])
        emit(f"serve.{mode}.offloads", row["offload_count"],
             "paper Table III: MURS avoids ~90% of spills")
        emit(f"serve.{mode}.swaps", row["swap_count"],
             "policy-driven frozen-KV swap-outs")
    for mode, row in record["sim"].items():
        emit(f"serve.sim.{mode}.gc_time_s", row["gc_time_s"])
    for mode in record["engine"]:
        mem = record["memory"][mode]
        for cls, v in sorted(mem["peak_by_class"].items()):
            emit(f"serve.memory.{mode}.peak.{cls}", round(v),
                 "per-class HBM high-water mark (ledger)")
    emit("serve.memory.ledger_matches_recount",
         int(record["memory"]["memory_wins"]["ledger_matches_recount"]),
         "incremental class tallies equal a ground-truth recount")
    pc = record["prefix_cache"]
    emit("serve.prefix.hit_rate", pc["hit_rate"],
         "shared-system-prompt stream, token-level")
    emit("serve.prefix.dedup_bytes", pc["dedup_bytes"],
         "KV bytes served by refcount instead of allocation")
    emit("serve.prefix.prefill_tokens_skipped", pc["prefill_tokens_skipped"])
    emit("serve.prefix.cow_events", pc["cow_events"],
         "appends into shared pages split first — never mutated")
    emit("serve.prefix.peak_demand_fraction.shared",
         pc["shared"]["peak_demand_fraction"],
         "pool usage net of reclaimable cold cache")
    emit("serve.prefix.peak_demand_fraction.baseline",
         pc["baseline_no_sharing"]["peak_demand_fraction"],
         "same tenant load, no sharing")
    emit("serve.prefix.peak_used_fraction.shared",
         pc["shared"]["peak_used_fraction"])
    emit("serve.prefix.peak_used_fraction.baseline",
         pc["baseline_no_sharing"]["peak_used_fraction"])
    emit("serve.prefix.ttft_p50.shared", pc["shared"]["ttft_p50_ticks"])
    emit("serve.prefix.ttft_p50.baseline",
         pc["baseline_no_sharing"]["ttft_p50_ticks"])
    tr = record["tiering"]
    for mode in ("reactive", "proactive"):
        row = tr[mode]
        emit(f"serve.tier.{mode}.spilled_bytes", row["spilled_bytes"],
             "raw bytes demoted HBM→host")
        emit(f"serve.tier.{mode}.disk_spill_bytes", row["disk_spill_bytes"],
             "paper Table III data spilling: traffic past the host tier")
        emit(f"serve.tier.{mode}.compression_ratio", row["compression_ratio"],
             "int8 host tier: raw/wire bytes")
        emit(f"serve.tier.{mode}.transfer_stall_ticks",
             row["transfer_stall_ticks"], "request-ticks waiting on tier DMA")
        emit(f"serve.tier.{mode}.completed", row["completed"])
    emit("serve.tier.disk_spill_halved",
         int(tr["tiering_wins"]["disk_spill_halved"]),
         "proactive tiering halves disk spill at equal load")
    cluster = record["cluster"]
    for mode in ("round_robin", "murs", "straggler", "crash"):
        row = cluster[mode]
        emit(f"serve.cluster.{mode}.completed", row["completed"],
             f"of {cluster['n_requests']} requests, 2 replicas")
        emit(f"serve.cluster.{mode}.p99_ticks", row["p99_ticks_to_finish"])
        emit(f"serve.cluster.{mode}.throughput",
             row["throughput_tokens_per_tick"], "tokens/tick, cluster-wide")
        emit(f"serve.cluster.{mode}.migrations",
             row["migrations_completed"],
             "live migrations delivered off the straggler")
    emit("serve.cluster.crash.requeued", cluster["crash"]["requeued"],
         "crash-requeued requests (RestartManager-style bounded retry)")
    wins = cluster["cluster_wins"]
    emit("serve.cluster.p99_beats_round_robin",
         int(wins["p99_beats_round_robin"]),
         "usage-rate placement beats round-robin at equal load")
    emit("serve.cluster.migration_roundtrip",
         int(wins["migration_roundtrip"]),
         "KV extracted, moved compressed, re-installed — nothing lost")
    emit("serve.cluster.crash_no_loss", int(wins["crash_no_loss"]),
         "replica crash requeues its requests instead of losing them")
    mz = record["model_zoo"]
    for mode in ("fair", "murs"):
        row = mz[mode]
        emit(f"serve.model_zoo.{mode}.completed", row["completed"],
             f"of {mz['n_requests']} requests across 3 architectures")
        emit(f"serve.model_zoo.{mode}.p99_ticks", row["p99_ticks_to_finish"])
        emit(f"serve.model_zoo.{mode}.unroutable", row["unroutable"],
             "requests with no capable replica (must be 0 here)")
        emit(f"serve.model_zoo.{mode}.misroutes", row["misroutes"],
             "requests landed on a replica hosting a different arch")
    mw = mz["model_zoo_wins"]
    emit("serve.model_zoo.mixed_fleet_completes_all_archs",
         int(mw["mixed_fleet_completes_all_archs"]),
         "every architecture class completes its whole stream, both routers")
    emit("serve.model_zoo.router_never_places_on_incapable_replica",
         int(mw["router_never_places_on_incapable_replica"]),
         "zero misroutes and zero unroutable rows in either leg")
    emit("serve.model_zoo.murs_p99_le_fair_p99",
         int(mw["murs_p99_le_fair_p99"]),
         "class-aware routing's tail no worse than round-robin's")
    el = record["elastic"]
    for mode in ("elastic", "static"):
        row = el[mode]
        emit(f"serve.elastic.{mode}.completed", row["completed"],
             f"of {el['n_requests']} diurnal arrivals")
        emit(f"serve.elastic.{mode}.goodput_at_horizon",
             row["goodput_at_horizon"],
             f"SLO-met completions / {el['horizon_ticks']:.0f}-tick horizon")
        emit(f"serve.elastic.{mode}.replica_ticks", row["replica_ticks"],
             "replica-occupancy integral (what elasticity saves)")
    er = el["elastic"]
    emit("serve.elastic.scale_ups", er["scale_ups"])
    emit("serve.elastic.scale_downs", er["scale_downs"])
    emit("serve.elastic.delta_cutovers", er["delta_cutovers"],
         "drain cutovers that shipped only dirty pages")
    emit("serve.elastic.delta_wire_bytes", er["delta_wire_bytes"],
         f"vs {er['full_wire_bytes']} monolithic-copy counterfactual")
    emit("serve.elastic.ckpt_replayed_tokens", er["ckpt_replayed_tokens"],
         f"vs {er['ckpt_from_zero_tokens']} replay-from-zero counterfactual")
    ew = el["elastic_wins"]
    emit("serve.elastic.delta_migration_bytes_below_full_copy",
         int(ew["delta_migration_bytes_below_full_copy"]),
         "delta cutover ships strictly fewer bytes than a full copy")
    emit("serve.elastic.checkpoint_restore_no_replay_from_zero",
         int(ew["checkpoint_restore_no_replay_from_zero"]),
         "crash restore replays only the uncovered suffix")
    emit("serve.elastic.goodput_ge_static",
         int(ew["elastic_goodput_ge_static"]),
         "autoscaling matches the static fleet at equal peak HBM")
    ov = record["overload"]
    for mode in ("fair", "murs"):
        row = ov[mode]
        emit(f"serve.overload.{mode}.goodput", row["goodput"],
             "SLO-met completions per tick — the headline under overload")
        emit(f"serve.overload.{mode}.completed", row["completed"],
             f"of {ov['n_requests']} open-loop Poisson arrivals")
        emit(f"serve.overload.{mode}.shed", row["shed"],
             "rejected at the door by projected-demand shedding")
        emit(f"serve.overload.{mode}.rate_limited", row["rate_limited"])
        emit(f"serve.overload.{mode}.slo_good", row["slo_good"])
        emit(f"serve.overload.{mode}.ttft_p95_ticks", row["ttft_p95_ticks"])
        emit(f"serve.overload.{mode}.latency_p99_ticks",
             row["latency_p99_ticks"])
    ow = ov["overload_wins"]
    emit("serve.overload.goodput_under_overload",
         int(ow["goodput_under_overload"]),
         "usage-rate shedding beats FIFO shedding on goodput at equal load")
    emit("serve.overload.shed_not_collapse", int(ow["shed_not_collapse"]),
         "the door sheds instead of collapsing (no OOM failures)")
    bk = ov["bookkeeping"]
    emit("serve.overload.legacy_ticks_per_sec", bk["legacy_ticks_per_sec"],
         f"{bk['queued_requests']}-deep queue, per-tick rescan bookkeeping")
    emit("serve.overload.vectorized_ticks_per_sec",
         bk["vectorized_ticks_per_sec"], "same workload, incremental maps")
    emit("serve.overload.tick_rate_speedup", bk["tick_rate_speedup"],
         "engine ticks/sec, vectorized / legacy")
    emit("serve.murs.decode_memory_model", record["probe_memory_model"],
         "paper SIII online classification (attention decode = linear)")
    return record


if __name__ == "__main__":
    main()
