"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

from repro.sched import MursConfig
from repro.core.spark_sim import (
    make_grep,
    make_pr,
    make_sort,
    make_wc,
    run_batch,
    run_service,
)

__all__ = [
    "MursConfig",
    "emit",
    "make_grep",
    "make_pr",
    "make_sort",
    "make_wc",
    "murs",
    "pct_change",
    "run_batch",
    "run_service",
]


def emit(name: str, value, derived: str = "") -> None:
    """CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")


def murs() -> MursConfig:
    return MursConfig()


def pct_change(base: float, new: float) -> float:
    if base <= 0:
        return 0.0
    return 100.0 * (base - new) / base
