"""Paper Fig. 4: minimum active tasks under MURS (suspension depth)."""

from .common import emit, make_grep, make_sort, make_wc, murs, run_service


def main() -> None:
    for heap in (5.0, 6.0):
        jobs = lambda: [make_sort(), make_wc(), make_grep()]
        fair = run_service(jobs(), heap_gb=heap, oom_is_fatal=False)
        m = run_service(jobs(), heap_gb=heap, murs=murs(), oom_is_fatal=False)
        emit(f"fig4.h{heap:g}.min_active_fair", fair.min_active_tasks)
        emit(f"fig4.h{heap:g}.min_active_murs", m.min_active_tasks)
        emit(f"fig4.h{heap:g}.suspensions_murs", m.suspensions)


if __name__ == "__main__":
    main()
