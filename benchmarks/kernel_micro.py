"""Pallas kernel microbenchmarks: allclose vs oracle + wall time per call.

On this CPU container the kernels run in interpret mode, so the wall time
is the *interpreter's*, not the TPU's — correctness (max |err|) is the
meaningful column; the FLOPs-derived TPU-bound is reported alongside.
"""

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from .common import emit

PEAK = 197e12


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6  # µs


def _paged_decode_leg(key) -> dict:
    """Paged-decode sweep over (active batch × pages): the serving hot
    path's kernel at the shapes the engine actually batches.  Returns the
    rows recorded under BENCH_serve.json's ``kernels.paged_decode`` key."""
    rows = {}
    page, hd = 16, 64
    for bh, n_pages in ((1, 2), (4, 4), (8, 8), (16, 16)):
        ks = jax.random.split(key, 5)
        pool_pages = n_pages * 2  # pool larger than any one table
        k_pool = jax.random.normal(ks[0], (pool_pages, page, hd), jnp.bfloat16)
        v_pool = jax.random.normal(ks[1], (pool_pages, page, hd), jnp.bfloat16)
        q = jax.random.normal(ks[2], (bh, hd), jnp.bfloat16)
        table = jax.random.randint(ks[3], (bh, n_pages), 0, pool_pages)
        lens = jax.random.randint(ks[4], (bh,), 1, n_pages * page + 1)
        out, us = _time(
            ops.paged_decode_attention, q, k_pool, v_pool, table, lens,
            reps=1,
        )
        gold = ref.paged_decode_attention_ref(q, k_pool, v_pool, table, lens)
        err = float(
            jnp.abs(out.astype(jnp.float32) - gold.astype(jnp.float32)).max()
        )
        label = f"b{bh}_p{n_pages}"
        emit(f"kernel.paged_decode.{label}.us_per_call", round(us, 1),
             f"interpret-mode; max_err={err:.4f}")
        rows[label] = {"us_per_call": round(us, 1), "max_err": err}
    return rows


def _paged_decode_int8_leg(key) -> dict:
    """int8-KV variant: per-page ``dist/compression`` codes dequantized
    inside the page sweep (the compressed host tier's promotion-free
    read path)."""
    from repro.dist.compression import quantize

    page, hd, bh, n_pages = 16, 64, 8, 8
    ks = jax.random.split(key, 5)
    pool_pages = n_pages * 2
    kf = jax.random.normal(ks[0], (pool_pages, page, hd), jnp.float32)
    vf = jax.random.normal(ks[1], (pool_pages, page, hd), jnp.float32)
    kq, ksc = jax.vmap(quantize)(kf)
    vq, vsc = jax.vmap(quantize)(vf)
    q = jax.random.normal(ks[2], (bh, hd), jnp.float32)
    table = jax.random.randint(ks[3], (bh, n_pages), 0, pool_pages)
    lens = jax.random.randint(ks[4], (bh,), 1, n_pages * page + 1)
    out, us = _time(
        ops.paged_decode_attention_int8, q, kq, vq, ksc, vsc, table, lens,
        reps=1,
    )
    gold = ref.paged_decode_attention_int8_ref(
        q, kq, vq, ksc, vsc, table, lens
    )
    err = float(jnp.abs(out - gold).max())
    emit("kernel.paged_decode_int8.us_per_call", round(us, 1),
         f"interpret-mode; max_err={err:.5f} (vs dequantized oracle)")
    return {
        f"b{bh}_p{n_pages}": {"us_per_call": round(us, 1), "max_err": err}
    }


def main() -> dict:
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)

    # flash attention
    BH, S, HD = 4, 512, 128
    q = jax.random.normal(k1, (BH, S, HD), jnp.bfloat16)
    k = jax.random.normal(k2, (BH, S, HD), jnp.bfloat16)
    v = jax.random.normal(k3, (BH, S, HD), jnp.bfloat16)
    out, us = _time(ops.flash_attention, q, k, v, causal=True, reps=1)
    gold = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.abs(out.astype(jnp.float32) - gold.astype(jnp.float32)).max())
    flops = 4 * BH * S * S * HD * 0.5
    emit("kernel.flash_attention.us_per_call", round(us, 1),
         f"interpret-mode; max_err={err:.4f}; tpu_bound_us={flops / PEAK * 1e6:.2f}")

    # decode attention
    qd = jax.random.normal(k1, (BH, HD), jnp.bfloat16)
    out, us = _time(ops.decode_attention, qd, k, v, 300, reps=1)
    gold = ref.decode_attention_ref(qd, k, v, 300)
    err = float(jnp.abs(out.astype(jnp.float32) - gold.astype(jnp.float32)).max())
    emit("kernel.decode_attention.us_per_call", round(us, 1),
         f"interpret-mode; max_err={err:.4f}")

    # grouped matmul
    E, C, D, F = 8, 128, 512, 256
    x = jax.random.normal(k1, (E, C, D), jnp.bfloat16)
    w = jax.random.normal(k2, (E, D, F), jnp.bfloat16)
    out, us = _time(ops.grouped_matmul, x, w, reps=1)
    gold = ref.grouped_matmul_ref(x, w)
    rel = float(
        (jnp.abs(out.astype(jnp.float32) - gold.astype(jnp.float32)).max()
         / jnp.abs(gold.astype(jnp.float32)).max())
    )
    flops = 2 * E * C * D * F
    emit("kernel.grouped_matmul.us_per_call", round(us, 1),
         f"interpret-mode; rel_err={rel:.5f}; tpu_bound_us={flops / PEAK * 1e6:.2f}")

    # ssd scan
    B, S2, NH, HD2, DS = 2, 256, 4, 64, 32
    xs = jax.random.normal(k1, (B, S2, NH, HD2), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k2, (B, S2, NH), jnp.float32))
    A = -jnp.exp(jax.random.normal(k3, (NH,), jnp.float32) * 0.3)
    Bm = jax.random.normal(k1, (B, S2, DS), jnp.float32) * 0.5
    Cm = jax.random.normal(k2, (B, S2, DS), jnp.float32) * 0.5
    out, us = _time(ops.ssd_scan, xs, dt, A, Bm, Cm, chunk=64, reps=1)
    gold = ref.ssd_scan_ref(xs, dt, A, Bm, Cm)
    err = float(jnp.abs(out - gold).max())
    emit("kernel.ssd_scan.us_per_call", round(us, 1),
         f"interpret-mode; max_err={err:.5f}")

    # paged decode (the serving hot path) + its int8-KV variant: these
    # rows land in BENCH_serve.json under the "kernels" key
    return {
        "paged_decode": _paged_decode_leg(k2),
        "paged_decode_int8": _paged_decode_int8_leg(k3),
    }


if __name__ == "__main__":
    main()
