"""Pallas kernel microbenchmarks: allclose vs oracle + wall time per call.

On this CPU container the kernels run in interpret mode, so the wall time
is the *interpreter's*, not the TPU's — correctness (max |err|) is the
meaningful column; the FLOPs-derived TPU-bound is reported alongside.
"""

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from .common import emit

PEAK = 197e12


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6  # µs


def main() -> None:
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)

    # flash attention
    BH, S, HD = 4, 512, 128
    q = jax.random.normal(k1, (BH, S, HD), jnp.bfloat16)
    k = jax.random.normal(k2, (BH, S, HD), jnp.bfloat16)
    v = jax.random.normal(k3, (BH, S, HD), jnp.bfloat16)
    out, us = _time(ops.flash_attention, q, k, v, causal=True, reps=1)
    gold = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.abs(out.astype(jnp.float32) - gold.astype(jnp.float32)).max())
    flops = 4 * BH * S * S * HD * 0.5
    emit("kernel.flash_attention.us_per_call", round(us, 1),
         f"interpret-mode; max_err={err:.4f}; tpu_bound_us={flops / PEAK * 1e6:.2f}")

    # decode attention
    qd = jax.random.normal(k1, (BH, HD), jnp.bfloat16)
    out, us = _time(ops.decode_attention, qd, k, v, 300, reps=1)
    gold = ref.decode_attention_ref(qd, k, v, 300)
    err = float(jnp.abs(out.astype(jnp.float32) - gold.astype(jnp.float32)).max())
    emit("kernel.decode_attention.us_per_call", round(us, 1),
         f"interpret-mode; max_err={err:.4f}")

    # grouped matmul
    E, C, D, F = 8, 128, 512, 256
    x = jax.random.normal(k1, (E, C, D), jnp.bfloat16)
    w = jax.random.normal(k2, (E, D, F), jnp.bfloat16)
    out, us = _time(ops.grouped_matmul, x, w, reps=1)
    gold = ref.grouped_matmul_ref(x, w)
    rel = float(
        (jnp.abs(out.astype(jnp.float32) - gold.astype(jnp.float32)).max()
         / jnp.abs(gold.astype(jnp.float32)).max())
    )
    flops = 2 * E * C * D * F
    emit("kernel.grouped_matmul.us_per_call", round(us, 1),
         f"interpret-mode; rel_err={rel:.5f}; tpu_bound_us={flops / PEAK * 1e6:.2f}")

    # ssd scan
    B, S2, NH, HD2, DS = 2, 256, 4, 64, 32
    xs = jax.random.normal(k1, (B, S2, NH, HD2), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k2, (B, S2, NH), jnp.float32))
    A = -jnp.exp(jax.random.normal(k3, (NH,), jnp.float32) * 0.3)
    Bm = jax.random.normal(k1, (B, S2, DS), jnp.float32) * 0.5
    Cm = jax.random.normal(k2, (B, S2, DS), jnp.float32) * 0.5
    out, us = _time(ops.ssd_scan, xs, dt, A, Bm, Cm, chunk=64, reps=1)
    gold = ref.ssd_scan_ref(xs, dt, A, Bm, Cm)
    err = float(jnp.abs(out - gold).max())
    emit("kernel.ssd_scan.us_per_call", round(us, 1),
         f"interpret-mode; max_err={err:.5f}")


if __name__ == "__main__":
    main()
