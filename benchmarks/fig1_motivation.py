"""Paper Fig. 1: WC suffers PR's memory pressure in service mode.

Runs PR+WC concurrently (service, FAIR) vs each alone (batch) and reports
exec/GC per app — the motivation result: exec-service(WC) >> exec-batch(WC)
entirely through pressure created by PR.
"""

from .common import emit, make_pr, make_wc, run_batch, run_service

HEAP_GB = 15.0


def main() -> None:
    service = run_service([make_pr(), make_wc()], heap_gb=HEAP_GB,
                          oom_is_fatal=False)
    batch = run_batch([make_pr(), make_wc()], heap_gb=HEAP_GB)
    for app in ("pr", "wc"):
        s = service.jobs[app]
        b = batch[app].jobs[app]
        emit(f"fig1.exec_service.{app}", round(s.exec_time, 1), "seconds")
        emit(f"fig1.exec_batch.{app}", round(b.exec_time, 1), "seconds")
        emit(f"fig1.gc_service.{app}", round(s.gc_time, 1), "seconds")
        emit(f"fig1.gc_batch.{app}", round(b.gc_time, 1), "seconds")
    wc_ratio = service.jobs["wc"].exec_time / max(batch["wc"].jobs["wc"].exec_time, 1e-9)
    emit("fig1.wc_service_over_batch", round(wc_ratio, 2),
         "paper: service-mode WC markedly slower than batch WC")


if __name__ == "__main__":
    main()
