"""Paper Fig. 7 / §VI-D: no starvation — the heavy job also finishes (and
the FIFO resume bounds its delay)."""

from .common import emit, make_pr, make_wc, murs, pct_change, run_service


def main() -> None:
    heap = 15.0
    fair = run_service([make_pr(), make_wc()], heap_gb=heap, oom_is_fatal=False)
    m = run_service([make_pr(), make_wc()], heap_gb=heap, murs=murs(),
                    oom_is_fatal=False)
    for app in ("pr", "wc"):
        emit(f"fig7.exec_fair.{app}", round(fair.jobs[app].exec_time, 1))
        emit(f"fig7.exec_murs.{app}", round(m.jobs[app].exec_time, 1))
        emit(f"fig7.{app}_finished_murs", int(m.jobs[app].finish_time > 0),
             "1 = no starvation")
        emit(f"fig7.{app}_improvement_pct",
             round(pct_change(fair.jobs[app].exec_time,
                              m.jobs[app].exec_time), 1),
             "paper: PR +24.4%, WC +29.8%")


if __name__ == "__main__":
    main()
