"""Reproduce the paper's Spark evaluation scenarios (discrete-event env).

    PYTHONPATH=src python examples/murs_spark_repro.py
"""

from repro.sched import MursConfig
from repro.core.spark_sim import (
    make_grep, make_pr, make_sort, make_wc, run_batch, run_service,
)


def show(tag, m):
    jobs = "  ".join(
        f"{j}: exec={jm.exec_time:.0f}s gc={jm.gc_time:.0f}s spills={jm.spills}"
        for j, jm in m.jobs.items()
    )
    print(f"{tag:28s} {jobs}")


def main() -> None:
    print("— Fig 1 motivation: WC suffers PR's pressure in service mode —")
    show("service (FAIR):", run_service([make_pr(), make_wc()], heap_gb=15,
                                        oom_is_fatal=False))
    batch = run_batch([make_pr(), make_wc()], heap_gb=15)
    for j, m in batch.items():
        show(f"batch ({j} alone):", m)

    print("\n— no-caching group (Sort+WC+Grep), 6 GB heap —")
    jobs = lambda: [make_sort(), make_wc(), make_grep()]
    show("FAIR:", run_service(jobs(), heap_gb=6, oom_is_fatal=False))
    show("MURS:", run_service(jobs(), heap_gb=6, murs=MursConfig(),
                              oom_is_fatal=False))


if __name__ == "__main__":
    main()
