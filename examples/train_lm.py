"""End-to-end training driver: a ~100M-parameter LM, a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300   # full run
    PYTHONPATH=src python examples/train_lm.py --steps 10    # smoke

Features exercised: synthetic sharded data pipeline with prefetch, remat,
microbatch gradient accumulation, int8 error-feedback gradient compression,
async checkpointing, straggler telemetry, crash-resume.
"""

import argparse
import dataclasses

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.train import Trainer, TrainerConfig


def build_100m():
    """~110 M params: a scaled-down internlm2-family decoder."""
    base = ARCHS["internlm2-1.8b"]
    return dataclasses.replace(
        base,
        name="lm-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab=32_000,
        d_head=64,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = build_100m()
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")
    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    trainer = Trainer(
        cfg, shape,
        TrainerConfig(
            steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=50,
            log_every=10,
            grad_compression=args.grad_compression,
            opt=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        ),
    )
    out = trainer.run()
    print(f"done: step {out['final_step']}  final loss {out['final_loss']:.4f}")
    for m in out["log"][-5:]:
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"{m['step_time_s'] * 1e3:.0f} ms/step  "
              f"stragglers={m['stragglers']}")


if __name__ == "__main__":
    main()
