"""Quickstart: build a tiny model, train it, checkpoint, resume.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main() -> None:
    cfg = ARCHS["internlm2-1.8b"].smoke()  # reduced same-family config
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            cfg, shape,
            TrainerConfig(
                steps=20, ckpt_dir=ckpt_dir, ckpt_every=10, log_every=5,
                opt=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=20),
            ),
        )
        out = trainer.run()
        print("trained to step", out["final_step"])
        for m in out["log"]:
            print(f"  step {m['step']:3d}  loss {m['loss']:.4f}  "
                  f"{m['step_time_s'] * 1e3:.0f} ms/step")
        # crash-recovery demo: a fresh trainer resumes from the checkpoint
        resumed = Trainer(
            cfg, shape,
            TrainerConfig(
                steps=25, ckpt_dir=ckpt_dir, ckpt_every=10, log_every=5,
                opt=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=25),
            ),
        )
        out2 = resumed.run()
        print("resumed from ckpt →", out2["final_step"])


if __name__ == "__main__":
    main()
