"""Multi-tenant serving under HBM pressure: MURS vs FAIR (the paper's
service-mode scenario as a first-class JAX serving feature).

    PYTHONPATH=src python examples/serve_multitenant.py
"""

import jax

from repro.configs import ARCHS
from repro.models import init_model
from repro.sched import FairPolicy, MursConfig, MursPolicy
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import kv_bytes_per_token


def workload():
    """Tenant A: long heavy generations; tenant B: short interactive ones."""
    reqs = [Request(f"A{i}", "A", list(range(10, 18)), 40) for i in range(3)]
    reqs += [Request(f"B{i}", "B", list(range(30, 34)), 6) for i in range(4)]
    return reqs


def main() -> None:
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    capacity = kv_bytes_per_token(cfg) * 80  # KV pool ≈ 80 tokens → pressure

    policies = (
        ("FAIR (stock)", FairPolicy()),
        ("MURS", MursPolicy(MursConfig.for_serving(period=1.0))),
    )
    for name, policy in policies:
        engine = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=4, max_seq=64,
                         hbm_capacity_bytes=capacity, policy=policy),
        )
        for r in workload():
            engine.submit(r)
        rep = engine.run(max_ticks=400)
        print(f"{name:14s} completed {rep.completed}/7  "
              f"failed {rep.failed}  "
              f"suspensions {rep.extras['suspensions']}  "
              f"tokens {rep.tokens_generated}  "
              f"peak pool {rep.extras['peak_used_fraction']:.2f}")


if __name__ == "__main__":
    main()
