"""Typed serving reports: per-request outcomes, SLO latency summaries, goodput.

``ServingEngine.run()`` / ``ServingCluster.run()`` (and the
:class:`repro.serve.frontdoor.FrontDoor` wrapping them) return a
:class:`ServeReport` instead of a loose ``Dict[str, Any]``.  The report
carries:

* per-request :class:`RequestOutcome` rows — every submission ends in
  exactly one of ``completed / failed / shed / rate_limited / lost /
  unfinished`` (the conservation property the front-door tests check);
* :class:`LatencySummary` percentiles for end-to-end latency, TTFT
  (time-to-first-token) and TPOT (time-per-output-token);
* **goodput** — completions that met their tenant's :class:`SloSpec`,
  per tick.  Under overload this replaces raw throughput as the headline
  metric: a system that "completes" every request 50× past its latency
  target has throughput but no goodput.

The legacy dict payload lives in :attr:`ServeReport.extras`.  (The
one-release ``__getitem__`` dict-access shim has been removed: use the
typed fields, or ``report.extras[...]`` for legacy keys.)
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence

__all__ = [
    "COMPLETED",
    "FAILED",
    "LOST",
    "RATE_LIMITED",
    "SHED",
    "UNFINISHED",
    "LatencySummary",
    "RequestOutcome",
    "ServeReport",
    "SloSpec",
    "percentile",
]

# terminal outcomes — every submission ends in exactly one of these
COMPLETED = "completed"
FAILED = "failed"
SHED = "shed"  # rejected at the front door by projected-demand shedding
RATE_LIMITED = "rate_limited"  # rejected by the tenant's token bucket
LOST = "lost"  # cluster: in flight on a crashed replica, retries exhausted
UNFINISHED = "unfinished"  # still live when the tick budget ran out


def percentile(sorted_vals: Sequence[float], q: float) -> Optional[float]:
    """Nearest-rank percentile over an ascending-sorted sequence."""
    if not sorted_vals:
        return None
    idx = min(len(sorted_vals) - 1, round(q * (len(sorted_vals) - 1)))
    return sorted_vals[idx]


@dataclass(frozen=True)
class SloSpec:
    """Per-tenant service-level objective, in engine ticks.  A ``None``
    bound is unconstrained; an outcome missing the measurement for a set
    bound (e.g. cluster rows carry no TTFT) skips that dimension rather
    than failing it."""

    ttft_ticks: Optional[float] = None
    tpot_ticks: Optional[float] = None
    latency_ticks: Optional[float] = None

    def met(self, outcome: "RequestOutcome") -> bool:
        """True if a COMPLETED outcome satisfies every configured bound
        (TTFT / TPOT / end-to-end, in ticks)."""
        if outcome.outcome != COMPLETED:
            return False
        for bound, value in (
            (self.ttft_ticks, outcome.ttft_ticks),
            (self.tpot_ticks, outcome.tpot_ticks),
            (self.latency_ticks, outcome.latency_ticks),
        ):
            if bound is not None and value is not None and value > bound:
                return False
        return True


@dataclass
class RequestOutcome:
    """How one submission ended — the conservation unit: every request a
    front door ever saw maps to exactly one row."""

    request_id: str
    tenant: str
    outcome: str  # one of the module-level terminal constants
    submit_tick: int = 0
    finish_tick: int = -1
    first_token_tick: int = -1  # -1 = never emitted a token
    tokens: int = 0  # tokens actually generated
    reason: str = ""  # optional detail (shed reason, failure mode)
    #: arch name the request targeted ("" = the run's single implicit
    #: model) — heterogeneous-fleet runs key per-model goodput on this
    model: str = ""

    @property
    def latency_ticks(self) -> Optional[int]:
        """End-to-end submit→finish ticks; None while unfinished."""
        if self.finish_tick < 0:
            return None
        return self.finish_tick - self.submit_tick

    @property
    def ttft_ticks(self) -> Optional[int]:
        """Submit→first-token ticks; None before the first token."""
        if self.first_token_tick < 0:
            return None
        return self.first_token_tick - self.submit_tick

    @property
    def tpot_ticks(self) -> Optional[float]:
        """Mean ticks per generated token after the first (decode cadence)."""
        if self.first_token_tick < 0 or self.finish_tick < 0 or self.tokens < 1:
            return None
        return (self.finish_tick - self.first_token_tick) / max(
            1, self.tokens - 1
        )


@dataclass
class LatencySummary:
    """Count / mean / tail percentiles of one latency distribution."""

    count: int = 0
    mean: Optional[float] = None
    p50: Optional[float] = None
    p95: Optional[float] = None
    p99: Optional[float] = None

    @classmethod
    def from_values(cls, values: Sequence[float]) -> "LatencySummary":
        """Percentile summary of the non-None values (empty-safe)."""
        vals = sorted(v for v in values if v is not None)
        if not vals:
            return cls()
        return cls(
            count=len(vals),
            mean=sum(vals) / len(vals),
            p50=percentile(vals, 0.50),
            p95=percentile(vals, 0.95),
            p99=percentile(vals, 0.99),
        )


@dataclass
class ServeReport:
    """Typed result of one serving run (engine, cluster, or front door).

    ``goodput`` is completions-within-SLO per tick; with no SLO applied
    every completion counts, so ``goodput`` degenerates to the completion
    rate.  Call :meth:`apply_slo` to re-score against per-tenant
    :class:`SloSpec` bounds (the front door does this automatically).
    """

    policy: str = ""
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    shed: int = 0
    rate_limited: int = 0
    ticks: int = 0
    tokens_generated: int = 0
    throughput_tokens_per_tick: float = 0.0
    slo_good: int = 0
    goodput: float = 0.0
    latency: LatencySummary = field(default_factory=LatencySummary)
    ttft: LatencySummary = field(default_factory=LatencySummary)
    tpot: LatencySummary = field(default_factory=LatencySummary)
    outcomes: List[RequestOutcome] = field(default_factory=list, repr=False)
    #: sub-reports (plain dicts, shape-stable with the legacy payloads)
    tiering: Optional[Dict[str, Any]] = None
    prefix: Optional[Dict[str, Any]] = None
    cluster: Optional[Dict[str, Any]] = None
    #: the ledger's class-stamped memory breakdown (``MemoryLedger.stats()``
    #: shape: per-class / per-tier bytes, peaks, spill, the recount bit)
    memory: Optional[Dict[str, Any]] = None
    #: the full legacy dict payload (reach it explicitly: ``.extras``)
    extras: Dict[str, Any] = field(default_factory=dict, repr=False)

    # ------------------------------------------------------------- scoring
    def refresh_summaries(self) -> "ServeReport":
        """Recompute latency/TTFT/TPOT summaries and counts from
        :attr:`outcomes` (call after merging front-door rows in)."""
        done = [o for o in self.outcomes if o.outcome == COMPLETED]
        self.completed = len(done)
        self.failed = sum(1 for o in self.outcomes if o.outcome == FAILED)
        self.shed = sum(1 for o in self.outcomes if o.outcome == SHED)
        self.rate_limited = sum(
            1 for o in self.outcomes if o.outcome == RATE_LIMITED
        )
        self.latency = LatencySummary.from_values(
            [o.latency_ticks for o in done]
        )
        self.ttft = LatencySummary.from_values([o.ttft_ticks for o in done])
        self.tpot = LatencySummary.from_values([o.tpot_ticks for o in done])
        return self

    def apply_slo(
        self,
        slos: Optional[Mapping[str, SloSpec]] = None,
        default: Optional[SloSpec] = None,
    ) -> "ServeReport":
        """Score completions against per-tenant SLOs and recompute
        ``slo_good`` / ``goodput``.  Tenants absent from ``slos`` use
        ``default``; with neither, every completion is good."""
        slos = slos or {}
        good = 0
        for o in self.outcomes:
            if o.outcome != COMPLETED:
                continue
            spec = slos.get(o.tenant, default)
            if spec is None or spec.met(o):
                good += 1
        self.slo_good = good
        self.goodput = good / max(1, self.ticks)
        return self

    def tenant_summary(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant outcome counts (diagnosing who shedding hit)."""
        out: Dict[str, Dict[str, int]] = {}
        for o in self.outcomes:
            row = out.setdefault(o.tenant, {})
            row[o.outcome] = row.get(o.outcome, 0) + 1
        return out

    def model_summary(self) -> Dict[str, Dict[str, Any]]:
        """Per-model outcome counts, goodput, and completed-latency p99 —
        the heterogeneous-fleet view (which architecture class is being
        starved / shed / failed).  Rows with no model tag group under
        ``""``.  ``goodput`` here counts completions per tick (the SLO
        scoring, if any, already happened in :meth:`apply_slo` — per-model
        ``slo_good`` splits that same population)."""
        out: Dict[str, Dict[str, Any]] = {}
        for o in self.outcomes:
            row = out.setdefault(
                o.model,
                {"outcomes": {}, "completed_latency": []},
            )
            counts = row["outcomes"]
            counts[o.outcome] = counts.get(o.outcome, 0) + 1
            if o.outcome == COMPLETED and o.latency_ticks is not None:
                row["completed_latency"].append(o.latency_ticks)
        for model, row in out.items():
            lat = sorted(row.pop("completed_latency"))
            done = row["outcomes"].get(COMPLETED, 0)
            row["completed"] = done
            row["goodput"] = done / max(1, self.ticks)
            row["latency_p99"] = percentile(lat, 0.99)
        return out

    # --------------------------------------------------------------- (de)ser
    def to_json(self, include_outcomes: bool = False) -> Dict[str, Any]:
        """Plain-JSON dict (what the benchmarks record).  Outcome rows are
        omitted by default — thousands of them would swamp the bench
        artifact."""
        out = {
            "policy": self.policy,
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "shed": self.shed,
            "rate_limited": self.rate_limited,
            "ticks": self.ticks,
            "tokens_generated": self.tokens_generated,
            "throughput_tokens_per_tick": self.throughput_tokens_per_tick,
            "slo_good": self.slo_good,
            "goodput": self.goodput,
            "latency": asdict(self.latency),
            "ttft": asdict(self.ttft),
            "tpot": asdict(self.tpot),
            "tiering": self.tiering,
            "prefix": self.prefix,
            "cluster": self.cluster,
            "memory": self.memory,
        }
        if include_outcomes:
            out["outcomes"] = [asdict(o) for o in self.outcomes]
        return out

    @classmethod
    def from_json(cls, payload: Mapping[str, Any]) -> "ServeReport":
        """Rebuild a report from :meth:`to_json` output (artifact
        round-trip; unknown keys are ignored)."""
        rep = cls(
            policy=payload.get("policy", ""),
            submitted=payload.get("submitted", 0),
            completed=payload.get("completed", 0),
            failed=payload.get("failed", 0),
            shed=payload.get("shed", 0),
            rate_limited=payload.get("rate_limited", 0),
            ticks=payload.get("ticks", 0),
            tokens_generated=payload.get("tokens_generated", 0),
            throughput_tokens_per_tick=payload.get(
                "throughput_tokens_per_tick", 0.0
            ),
            slo_good=payload.get("slo_good", 0),
            goodput=payload.get("goodput", 0.0),
            latency=LatencySummary(**payload.get("latency", {}) or {}),
            ttft=LatencySummary(**payload.get("ttft", {}) or {}),
            tpot=LatencySummary(**payload.get("tpot", {}) or {}),
            tiering=payload.get("tiering"),
            prefix=payload.get("prefix"),
            cluster=payload.get("cluster"),
            memory=payload.get("memory"),
        )
        rep.outcomes = [
            RequestOutcome(**row) for row in payload.get("outcomes", [])
        ]
        return rep

    def json_str(self, include_outcomes: bool = False) -> str:
        return json.dumps(self.to_json(include_outcomes), sort_keys=True)
