"""Open-loop arrival traces: seeded Poisson / diurnal / bursty traffic.

The benchmark legs before this module drove ~6 closed-loop requests —
one in, one out — which never exercises the paper's failure mode: a
service-oriented system breaks when *many concurrently submitted tasks*
share one memory context (MURS §II).  An OPEN-LOOP generator submits on
the trace's schedule regardless of completions, so queue depth and
projected demand grow without bound unless admission control sheds.

Every trace is a deterministic function of its seed (``random.Random``;
no wall clock), so benchmark runs are reproducible bit-for-bit.  Traces
are thinned from a max-rate Poisson process, which makes the diurnal and
bursty shapes exact (not per-tick approximations) and keeps all three
generators on one code path.

:func:`drive` pushes a trace through anything satisfying
:class:`repro.serve.server.Server` — engine, cluster, or the admission
front door — and returns the run's :class:`ServeReport`.
"""

from __future__ import annotations

import math
import random
from collections import deque
from dataclasses import dataclass
from typing import Callable, List, Sequence, Tuple

from repro.serve.engine import Request
from repro.serve.report import ServeReport
from repro.serve.server import Server

__all__ = [
    "Arrival",
    "TenantProfile",
    "bursty_trace",
    "diurnal_trace",
    "drive",
    "poisson_trace",
]


@dataclass(frozen=True)
class TenantProfile:
    """Per-tenant request-shape distribution.

    ``weight`` is the tenant's share of arrivals; prompt/output lengths
    draw uniformly from the inclusive ranges.  ``vocab`` bounds the
    synthetic token ids (kept small so prompts rarely collide with the
    prefix cache unless a test wants them to).
    """

    name: str
    weight: float = 1.0
    prompt_tokens: Tuple[int, int] = (4, 8)
    output_tokens: Tuple[int, int] = (4, 16)
    vocab: int = 31

    def make_request(self, rnd: random.Random, index: int) -> Request:
        """Draw one request from the tenant's prompt/output ranges."""
        prompt = [
            1 + rnd.randrange(self.vocab)
            for _ in range(rnd.randint(*self.prompt_tokens))
        ]
        return Request(
            request_id=f"{self.name}-{index}",
            tenant=self.name,
            prompt=prompt,
            max_new_tokens=rnd.randint(*self.output_tokens),
        )


@dataclass(frozen=True)
class Arrival:
    """One traced arrival: the tick it lands and the request itself."""

    tick: int
    request: Request


def _thinned_trace(
    tenants: Sequence[TenantProfile],
    n_requests: int,
    seed: int,
    rate_fn: Callable[[float], float],
    rate_max: float,
    start_tick: int,
) -> List[Arrival]:
    """Draw ``n_requests`` arrivals from an inhomogeneous Poisson process
    with instantaneous rate ``rate_fn(t) <= rate_max`` via thinning."""
    if not tenants:
        raise ValueError("at least one TenantProfile required")
    if rate_max <= 0:
        raise ValueError(f"rate must be positive, got {rate_max}")
    rnd = random.Random(seed)
    total_w = sum(t.weight for t in tenants)
    counts = {t.name: 0 for t in tenants}
    t_now = float(start_tick)
    out: List[Arrival] = []
    while len(out) < n_requests:
        t_now += rnd.expovariate(rate_max)
        if rnd.random() * rate_max > rate_fn(t_now):
            continue
        x = rnd.random() * total_w
        profile = tenants[-1]
        for tp in tenants:
            x -= tp.weight
            if x <= 0:
                profile = tp
                break
        req = profile.make_request(rnd, counts[profile.name])
        counts[profile.name] += 1
        out.append(Arrival(int(t_now), req))
    return out


def poisson_trace(
    tenants: Sequence[TenantProfile],
    *,
    rate_per_tick: float,
    n_requests: int,
    seed: int = 0,
    start_tick: int = 0,
) -> List[Arrival]:
    """Homogeneous Poisson arrivals at ``rate_per_tick``."""
    return _thinned_trace(
        tenants,
        n_requests,
        seed,
        lambda _t: rate_per_tick,
        rate_per_tick,
        start_tick,
    )


def diurnal_trace(
    tenants: Sequence[TenantProfile],
    *,
    base_rate_per_tick: float,
    n_requests: int,
    period_ticks: float = 200.0,
    amplitude: float = 0.5,
    seed: int = 0,
    start_tick: int = 0,
) -> List[Arrival]:
    """Sinusoidal day/night load: rate(t) = base·(1 + amplitude·sin)."""
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")

    def rate(t: float) -> float:
        return base_rate_per_tick * (
            1.0 + amplitude * math.sin(2.0 * math.pi * t / period_ticks)
        )

    return _thinned_trace(
        tenants,
        n_requests,
        seed,
        rate,
        base_rate_per_tick * (1.0 + amplitude),
        start_tick,
    )


def bursty_trace(
    tenants: Sequence[TenantProfile],
    *,
    rate_per_tick: float,
    n_requests: int,
    burst_factor: float = 4.0,
    burst_ticks: float = 20.0,
    gap_ticks: float = 80.0,
    seed: int = 0,
    start_tick: int = 0,
) -> List[Arrival]:
    """Square-wave load: ``burst_factor``× the base rate for
    ``burst_ticks``, then the base rate for ``gap_ticks``, repeating."""
    if burst_factor < 1.0:
        raise ValueError(f"burst_factor must be >= 1, got {burst_factor}")
    cycle = burst_ticks + gap_ticks

    def rate(t: float) -> float:
        in_burst = (t - start_tick) % cycle < burst_ticks
        return rate_per_tick * (burst_factor if in_burst else 1.0)

    return _thinned_trace(
        tenants,
        n_requests,
        seed,
        rate,
        rate_per_tick * burst_factor,
        start_tick,
    )


def drive(
    server: Server, arrivals: Sequence[Arrival], *, max_ticks: int = 5000
) -> ServeReport:
    """Open-loop driver: submit each arrival at its trace tick — never
    waiting on completions — then drain the server within the remaining
    tick budget and return its typed report.

    Arrivals whose tick falls past ``max_ticks`` are never submitted
    (the run ended before they "happened"); everything submitted is
    accounted for in the report's outcome rows.
    """
    pending = deque(sorted(arrivals, key=lambda a: a.tick))  # stable: same-tick order kept
    while pending and server.tick <= max_ticks:
        while pending and pending[0].tick <= server.tick:
            server.submit(pending.popleft().request)
        if not pending:
            break
        server.step()
    return server.run(max_ticks=max_ticks)
