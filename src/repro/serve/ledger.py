"""Class-stamped memory ledger: one place where bytes are born, move, die.

DESIGN.md §6 names the lifetime classes of serving memory in prose; this
module promotes them into code.  Every page, state registration, and
tier-resident block is stamped ``(tenant, page_class, tier)`` and the
:class:`MemoryLedger` is the *single writer* of byte tallies.  The five
historical ad-hoc counters — ``ServingEngine._projected_bytes`` /
``_frozen_bytes()``, allocator ``owner_share`` products,
``PrefixCache.reclaimable_bytes``, ``TieredKVStore.host_used_bytes``,
cluster demand surfaces — all become *queries* against this ledger, so
they can no longer silently disagree (the ``settle on empty`` drift
reset this file replaces was the tell).

Layering: this module imports nothing from ``repro.serve`` (the
allocator, cache, tiers, and engine all import *it*), so it sits at the
bottom of the serving stack.  ``CACHE_OWNER`` lives here for the same
reason — both the allocator and the ledger need the sentinel.

Self-check: :meth:`MemoryLedger.recount` walks the attached allocator
and tier store from scratch and must equal the incremental state;
``benchmarks/gate.py`` holds that as the ``ledger_matches_recount``
hard bit and the hypothesis suite fuzzes it over random
alloc/share/COW/freeze/demote/promote/evict/free streams.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from enum import Enum
from typing import (
    Any,
    Callable,
    Dict,
    Hashable,
    Iterable,
    List,
    Mapping,
    Optional,
    Tuple,
)

__all__ = [
    "CACHE_OWNER",
    "HBM",
    "TO_HOST",
    "HOST",
    "DISK",
    "TO_HBM",
    "PageClass",
    "LedgerView",
    "PressurePlan",
    "MemoryLedger",
]

#: reserved owner id under which the prefix cache holds pages it alone
#: references (re-exported by ``repro.serve.kv_cache`` for compatibility)
CACHE_OWNER = "__prefix_cache__"

#: tier location names — mirror ``TieredKVStore`` states, plus "hbm" for
#: pages that never left the accelerator
HBM = "hbm"
TO_HOST = "to_host"
HOST = "host"
DISK = "disk"
TO_HBM = "to_hbm"


class PageClass(Enum):
    """DESIGN.md §6 lifetime classes, as first-class allocation stamps.

    The first four are the §6 rows; ``FIXED_STATE`` covers per-request
    constant state (attention sinks, encoder memory, recurrent state)
    that lives exactly as long as the request, and ``SCRATCH`` is the
    short-living class speculative decoding's draft pages will use —
    eviction prefers it over everything else by construction.
    """

    SHARED_PREFIX = "shared_prefix"
    PRIVATE_SUFFIX = "private_suffix"
    FROZEN = "frozen"
    COLD_CACHED = "cold_cached"
    FIXED_STATE = "fixed_state"
    SCRATCH = "scratch"


@dataclass
class _Owner:
    """Registration record for one byte-owning entity (request, the
    prefix cache, or a scratch region)."""

    tenant: str = ""
    kind: str = "request"  # "request" | "cache" | "scratch"
    page_bytes: float = 0.0
    state_bytes: float = 0.0
    frozen: bool = False


@dataclass
class _TierEntry:
    """One block resident somewhere in the HBM→host→disk hierarchy."""

    owner: str
    tenant: str
    cls: PageClass
    raw_bytes: float
    stored_bytes: float
    location: str


@dataclass(frozen=True)
class LedgerView:
    """Immutable snapshot of the ledger for policy decisions.

    ``SchedulingPolicy.pressure(view)`` receives one of these: per-class
    HBM byte totals, per-tier totals, per-tenant projections, and the
    replica's capacity, all read-only.
    """

    class_bytes: Mapping[PageClass, float]
    tier_bytes: Mapping[str, float]
    tenant_projected: Mapping[str, float]
    capacity_bytes: float

    def fraction(self, cls: PageClass) -> float:
        """HBM bytes of ``cls`` as a fraction of capacity (0 if no cap)."""
        if self.capacity_bytes <= 0:
            return 0.0
        return self.class_bytes.get(cls, 0.0) / self.capacity_bytes


@dataclass(frozen=True)
class PressurePlan:
    """A policy's complete answer to "memory is tight — what goes first?".

    Collapses the three historical hooks (``cache_pressure``,
    ``demotion_pressure``, ``shed_order``) onto one surface:

    - ``reclaim_order``: class order for synchronous reclaim when an
      admission or overcommit needs bytes *now*.  The stock order evicts
      ``SCRATCH`` (free by definition), then ``COLD_CACHED`` (still
      reconstructible), and only then demotes ``FROZEN`` (costs a PCIe
      round-trip to resume) — MURS evicts cold cache before touching
      frozen state *by construction*, not by call-site accident.
    - ``proactive_order``: class order for the background demotion pass
      (frozen first mirrors the paper: long-living suspended state is
      the pressure source worth moving early).
    - ``scores``: per-class group-scoring callables — the old
      ``cache_pressure(group)`` / ``demotion_pressure(group)`` pair,
      keyed by the class being reclaimed.
    - ``shed_key``: sort key for front-door shedding, given
      ``(group, stats_row)``; lower sorts first (shed first).
    """

    reclaim_order: Tuple[PageClass, ...] = (
        PageClass.SCRATCH,
        PageClass.COLD_CACHED,
        PageClass.FROZEN,
    )
    proactive_order: Tuple[PageClass, ...] = (
        PageClass.FROZEN,
        PageClass.COLD_CACHED,
    )
    scores: Mapping[PageClass, Callable[[str], float]] = field(
        default_factory=dict
    )
    shed_key: Callable[[str, Mapping[str, Any]], tuple] = (
        lambda group, row: (row.get("arrival_seq", 0.0),)
    )

    def score(self, cls: PageClass, group: str) -> float:
        """Eviction-priority score for ``group`` under class ``cls``
        (higher = evict this group's pages of that class sooner)."""
        fn = self.scores.get(cls)
        return fn(group) if fn is not None else 1.0


class MemoryLedger:
    """Single writer of byte tallies, stamped ``(tenant, class, tier)``.

    Incremental totals are kept alongside entry *counts*; when a
    bucket's count reaches zero the float is dropped entirely, so empty
    buckets are exactly ``0.0`` — no settle-on-empty resets.  The
    ground-truth :meth:`recount` walk over the attached allocator and
    tier store must always match, and :meth:`matches_recount` is a CI
    hard bit.
    """

    def __init__(self) -> None:
        """Create an empty ledger (attach collaborators afterwards)."""
        self._owners: Dict[str, _Owner] = {}
        # (tenant, PageClass, tier) -> running float total + entry count
        self._totals: Dict[Tuple[str, PageClass, str], float] = {}
        self._counts: Dict[Tuple[str, PageClass, str], int] = {}
        # owner -> HBM bytes (page fractions + fixed state), same scheme
        self._owner_hbm: Dict[str, float] = {}
        self._owner_hbm_counts: Dict[str, int] = {}
        # page id -> [(owner, PageClass, bytes), ...] one per holder slot
        self._page_entries: Dict[int, List[Tuple[str, PageClass, float]]] = {}
        # tier-resident blocks and a reverse index by owner
        self._tier: Dict[Hashable, _TierEntry] = {}
        self._tier_by_owner: Dict[str, set] = {}
        # cumulative byte flows between locations, e.g. ("host","disk")
        self._flows: Dict[Tuple[str, str], float] = {}
        # admission projections: owner -> (tenant, estimated bytes)
        self._proj: Dict[str, Tuple[str, float]] = {}
        self._proj_by_tenant: Dict[str, float] = {}
        self._proj_counts: Dict[str, int] = {}
        # per-class HBM peaks, sampled by the engine
        self._peaks: Dict[PageClass, float] = {}
        self._alloc: Any = None
        self._tiers: Any = None

    # ------------------------------------------------------------------
    # wiring

    def attach_allocator(self, alloc: Any) -> None:
        """Remember the :class:`PageBlockAllocator` for recounts and
        frozen restamps."""
        self._alloc = alloc

    def attach_tiers(self, tiers: Any) -> None:
        """Remember the :class:`TieredKVStore` for recounts."""
        self._tiers = tiers

    # ------------------------------------------------------------------
    # owners

    def register_owner(
        self,
        owner: str,
        tenant: str = "",
        kind: str = "request",
        page_bytes: float = 0.0,
        state_bytes: float = 0.0,
    ) -> None:
        """Declare an owner (request / cache / scratch) before its first
        page lands; ``state_bytes`` is stamped ``FIXED_STATE`` at HBM."""
        old = self._owners.get(owner)
        if old is not None and old.state_bytes:
            self._sub_total(old.tenant, PageClass.FIXED_STATE, HBM,
                            old.state_bytes)
            self._sub_owner(owner, old.state_bytes)
        self._owners[owner] = _Owner(
            tenant=tenant, kind=kind,
            page_bytes=float(page_bytes),
            state_bytes=float(state_bytes),
        )
        if state_bytes:
            self._add_total(tenant, PageClass.FIXED_STATE, HBM,
                            float(state_bytes))
            self._add_owner(owner, float(state_bytes))

    def release_owner(self, owner: str) -> None:
        """Retire an owner after its pages are freed and tier copies
        dropped; its fixed state leaves the ledger here."""
        rec = self._owners.pop(owner, None)
        if rec is None:
            return
        if rec.state_bytes:
            self._sub_total(rec.tenant, PageClass.FIXED_STATE, HBM,
                            rec.state_bytes)
            self._sub_owner(owner, rec.state_bytes)
        for key in list(self._tier_by_owner.get(owner, ())):
            self.tier_drop(key)

    def has_owner(self, owner: str) -> bool:
        """True while ``owner`` is registered."""
        return owner in self._owners

    def owner_tenant(self, owner: str) -> str:
        """Tenant stamped on ``owner`` ("" when unknown)."""
        rec = self._owners.get(owner)
        return rec.tenant if rec is not None else ""

    def _owner(self, owner: str) -> _Owner:
        rec = self._owners.get(owner)
        if rec is None:
            kind = "cache" if owner == CACHE_OWNER else "request"
            rec = _Owner(kind=kind)
            self._owners[owner] = rec
        return rec

    def set_frozen(self, owner: str, frozen: bool) -> None:
        """Mark ``owner`` suspended (or resumed): its sole-held HBM
        pages and tier-resident blocks restamp between
        ``PRIVATE_SUFFIX`` and ``FROZEN``."""
        rec = self._owner(owner)
        if rec.frozen == frozen:
            return
        rec.frozen = frozen
        if self._alloc is not None:
            table = self._alloc._tables.get(owner)
            if table:
                for pid in set(p for p in table if p >= 0):
                    holders = self._alloc._holders.get(pid, ())
                    self.page_update(pid, holders)
        for key in list(self._tier_by_owner.get(owner, ())):
            entry = self._tier[key]
            if entry.cls in (PageClass.PRIVATE_SUFFIX, PageClass.FROZEN):
                new_cls = PageClass.FROZEN if frozen else PageClass.PRIVATE_SUFFIX
                if new_cls is not entry.cls:
                    self._sub_total(entry.tenant, entry.cls,
                                    entry.location, entry.stored_bytes)
                    entry.cls = new_cls
                    self._add_total(entry.tenant, entry.cls,
                                    entry.location, entry.stored_bytes)

    def is_frozen(self, owner: str) -> bool:
        """True while ``owner`` is stamped suspended."""
        rec = self._owners.get(owner)
        return bool(rec is not None and rec.frozen)

    # ------------------------------------------------------------------
    # bucket arithmetic (exact settle: drop the float when count hits 0)

    def _add_total(self, tenant: str, cls: PageClass, tier: str,
                   b: float) -> None:
        key = (tenant, cls, tier)
        self._totals[key] = self._totals.get(key, 0.0) + b
        self._counts[key] = self._counts.get(key, 0) + 1

    def _sub_total(self, tenant: str, cls: PageClass, tier: str,
                   b: float) -> None:
        key = (tenant, cls, tier)
        n = self._counts.get(key, 0) - 1
        if n <= 0:
            self._counts.pop(key, None)
            self._totals.pop(key, None)
        else:
            self._counts[key] = n
            self._totals[key] = self._totals.get(key, 0.0) - b

    def _add_owner(self, owner: str, b: float) -> None:
        self._owner_hbm[owner] = self._owner_hbm.get(owner, 0.0) + b
        self._owner_hbm_counts[owner] = (
            self._owner_hbm_counts.get(owner, 0) + 1
        )

    def _sub_owner(self, owner: str, b: float) -> None:
        n = self._owner_hbm_counts.get(owner, 0) - 1
        if n <= 0:
            self._owner_hbm_counts.pop(owner, None)
            self._owner_hbm.pop(owner, None)
        else:
            self._owner_hbm_counts[owner] = n
            self._owner_hbm[owner] = self._owner_hbm.get(owner, 0.0) - b

    # ------------------------------------------------------------------
    # page accounting (driven by the allocator)

    def _class_of(self, owner: str, ref: int) -> PageClass:
        if ref > 1:
            return PageClass.SHARED_PREFIX
        rec = self._owner(owner)
        if rec.kind == "cache":
            return PageClass.COLD_CACHED
        if rec.kind == "scratch":
            return PageClass.SCRATCH
        if rec.frozen:
            return PageClass.FROZEN
        return PageClass.PRIVATE_SUFFIX

    def page_update(self, pid: int, holders: Iterable[str]) -> None:
        """Re-stamp page ``pid`` after any allocator mutation.

        ``holders`` is the allocator's current holder list for the page
        (one entry per table slot referencing it, so multiplicity is
        preserved); empty means the page was freed.  Fractional
        shared-page attribution lives here: each holder is charged
        ``page_bytes / ref``, reproducing the old ``owner_share``
        arithmetic exactly.
        """
        for owner, cls, b in self._page_entries.pop(pid, ()):
            tenant = self._owner(owner).tenant
            self._sub_total(tenant, cls, HBM, b)
            self._sub_owner(owner, b)
        holders = list(holders)
        if not holders:
            return
        ref = len(holders)
        entries: List[Tuple[str, PageClass, float]] = []
        for owner in holders:
            rec = self._owner(owner)
            cls = self._class_of(owner, ref)
            b = rec.page_bytes / ref
            entries.append((owner, cls, b))
            self._add_total(rec.tenant, cls, HBM, b)
            self._add_owner(owner, b)
        self._page_entries[pid] = entries

    def page_class(self, pid: int) -> Optional[PageClass]:
        """Class currently stamped on page ``pid`` (None if untracked).

        A page has exactly one class: shared pages are
        ``SHARED_PREFIX`` for every holder, sole pages take the
        holder's class.
        """
        entries = self._page_entries.get(pid)
        if not entries:
            return None
        return entries[0][1]

    def pages_of_class(self, owner: str, cls: PageClass) -> List[int]:
        """Page ids held by ``owner`` whose current stamp is ``cls``."""
        out = []
        for pid, entries in self._page_entries.items():
            for holder, c, _b in entries:
                if holder == owner and c is cls:
                    out.append(pid)
                    break
        return out

    # ------------------------------------------------------------------
    # tier accounting (driven by TieredKVStore)

    def _tier_owner(self, key: Hashable) -> str:
        if isinstance(key, tuple) and len(key) >= 2:
            if key[0] == "req":
                return str(key[1])
            if key[0] == "cache":
                return CACHE_OWNER
        return str(key)

    def tier_demote(self, key: Hashable, raw_bytes: float,
                    stored_bytes: float) -> None:
        """A block left HBM for the hierarchy: stamp it with its owner's
        class at demote time and account it at ``TO_HOST``."""
        if key in self._tier:
            self.tier_drop(key)
        owner = self._tier_owner(key)
        rec = self._owner(owner)
        if rec.kind == "cache":
            cls = PageClass.COLD_CACHED
        elif rec.kind == "scratch":
            cls = PageClass.SCRATCH
        elif rec.frozen:
            cls = PageClass.FROZEN
        else:
            cls = PageClass.PRIVATE_SUFFIX
        entry = _TierEntry(
            owner=owner, tenant=rec.tenant, cls=cls,
            raw_bytes=float(raw_bytes), stored_bytes=float(stored_bytes),
            location=TO_HOST,
        )
        self._tier[key] = entry
        self._tier_by_owner.setdefault(owner, set()).add(key)
        self._add_total(entry.tenant, cls, TO_HOST, entry.stored_bytes)

    def tier_move(self, key: Hashable, location: str) -> None:
        """Move a tracked block between locations, recording the flow
        (``flow("host", "disk")`` *is* the disk-spill metric)."""
        entry = self._tier.get(key)
        if entry is None or entry.location == location:
            return
        self._sub_total(entry.tenant, entry.cls, entry.location,
                        entry.stored_bytes)
        fkey = (entry.location, location)
        self._flows[fkey] = self._flows.get(fkey, 0.0) + entry.stored_bytes
        entry.location = location
        self._add_total(entry.tenant, entry.cls, location,
                        entry.stored_bytes)

    def tier_drop(self, key: Hashable) -> None:
        """A block left the hierarchy (promoted home, discarded, or
        extracted)."""
        entry = self._tier.pop(key, None)
        if entry is None:
            return
        self._sub_total(entry.tenant, entry.cls, entry.location,
                        entry.stored_bytes)
        keys = self._tier_by_owner.get(entry.owner)
        if keys is not None:
            keys.discard(key)
            if not keys:
                del self._tier_by_owner[entry.owner]

    def flow(self, src: str, dst: str) -> float:
        """Cumulative bytes that moved ``src`` → ``dst``."""
        return self._flows.get((src, dst), 0.0)

    # ------------------------------------------------------------------
    # projections (admission estimates, satellite-1 drift fix)

    def note_projection(self, owner: str, tenant: str, est: float) -> None:
        """Record an admission-time demand estimate for ``owner``."""
        if owner in self._proj:
            self.drop_projection(owner)
        self._proj[owner] = (tenant, float(est))
        self._proj_by_tenant[tenant] = (
            self._proj_by_tenant.get(tenant, 0.0) + float(est)
        )
        self._proj_counts[tenant] = self._proj_counts.get(tenant, 0) + 1

    def drop_projection(self, owner: str) -> None:
        """Retire ``owner``'s demand estimate; the per-tenant float is
        dropped entirely when its last estimate leaves (exact settle —
        this replaces the old settle-on-empty reset)."""
        rec = self._proj.pop(owner, None)
        if rec is None:
            return
        tenant, est = rec
        n = self._proj_counts.get(tenant, 0) - 1
        if n <= 0:
            self._proj_counts.pop(tenant, None)
            self._proj_by_tenant.pop(tenant, None)
        else:
            self._proj_counts[tenant] = n
            self._proj_by_tenant[tenant] = (
                self._proj_by_tenant.get(tenant, 0.0) - est
            )

    def projected_bytes(self) -> float:
        """Total live demand estimate across tenants."""
        return sum(self._proj_by_tenant.values())

    def projected_by_tenant(self) -> Dict[str, float]:
        """Copy of the per-tenant demand estimates."""
        return dict(self._proj_by_tenant)

    def projected_tenants(self) -> List[str]:
        """Tenants with at least one live projection."""
        return list(self._proj_by_tenant.keys())

    def projected_recount(self) -> float:
        """Ground-truth projection total (``math.fsum`` over entries) —
        the regression oracle for incremental projection bookkeeping."""
        return math.fsum(est for _t, est in self._proj.values())

    # ------------------------------------------------------------------
    # queries

    def owner_bytes(self, owner: str) -> float:
        """HBM bytes attributed to ``owner`` (page fractions + fixed
        state) — the old ``owner_share × page_bytes + state_bytes``."""
        return self._owner_hbm.get(owner, 0.0)

    def class_bytes(self, cls: PageClass, tier: str = HBM) -> float:
        """Bytes of ``cls`` resident at ``tier``."""
        return sum(
            v for (t, c, loc), v in self._totals.items()
            if c is cls and loc == tier
        )

    def tier_bytes(self, tier: str) -> float:
        """Bytes resident at ``tier`` across all classes."""
        return sum(
            v for (_t, _c, loc), v in self._totals.items() if loc == tier
        )

    def hbm_bytes(self) -> float:
        """Total HBM-resident bytes (all classes)."""
        return self.tier_bytes(HBM)

    def tenant_class_bytes(self, tenant: str, cls: PageClass,
                           tier: str = HBM) -> float:
        """Bytes of ``cls`` at ``tier`` attributed to ``tenant``."""
        return self._totals.get((tenant, cls, tier), 0.0)

    def class_breakdown(self, tier: str = HBM) -> Dict[PageClass, float]:
        """Per-class byte totals at ``tier``."""
        out: Dict[PageClass, float] = {}
        for (_t, cls, loc), v in self._totals.items():
            if loc == tier:
                out[cls] = out.get(cls, 0.0) + v
        return out

    def tier_breakdown(self) -> Dict[str, float]:
        """Per-location byte totals across all classes."""
        out: Dict[str, float] = {}
        for (_t, _c, loc), v in self._totals.items():
            out[loc] = out.get(loc, 0.0) + v
        return out

    def sample_peaks(self) -> None:
        """Record the running per-class HBM high-water marks."""
        for cls, v in self.class_breakdown(HBM).items():
            if v > self._peaks.get(cls, 0.0):
                self._peaks[cls] = v

    def peak_class_bytes(self) -> Dict[PageClass, float]:
        """Per-class HBM peaks seen since construction."""
        return dict(self._peaks)

    def view(self, capacity_bytes: float = 0.0) -> LedgerView:
        """Snapshot for policy consumption."""
        return LedgerView(
            class_bytes=self.class_breakdown(HBM),
            tier_bytes=self.tier_breakdown(),
            tenant_projected=self.projected_by_tenant(),
            capacity_bytes=float(capacity_bytes),
        )

    # ------------------------------------------------------------------
    # ground truth

    def recount(self) -> Dict[Tuple[str, PageClass, str], float]:
        """Recompute every ``(tenant, class, tier)`` total from scratch
        by walking the attached allocator and tier store.

        This is the ground truth the incremental state must equal; the
        gate's ``ledger_matches_recount`` bit and the hypothesis suite
        both assert it.
        """
        totals: Dict[Tuple[str, PageClass, str], List[float]] = {}

        def put(tenant: str, cls: PageClass, tier: str, b: float) -> None:
            totals.setdefault((tenant, cls, tier), []).append(b)

        if self._alloc is not None:
            for pid, holders in self._alloc._holders.items():
                if not holders:
                    continue
                ref = len(holders)
                for owner in holders:
                    rec = self._owner(owner)
                    put(rec.tenant, self._class_of(owner, ref), HBM,
                        rec.page_bytes / ref)
        for owner, rec in self._owners.items():
            if rec.state_bytes:
                put(rec.tenant, PageClass.FIXED_STATE, HBM,
                    rec.state_bytes)
        for entry in self._tier.values():
            put(entry.tenant, entry.cls, entry.location,
                entry.stored_bytes)
        return {k: math.fsum(v) for k, v in totals.items()}

    def matches_recount(self) -> bool:
        """True when the incremental totals equal :meth:`recount` within
        float tolerance — the gate hard bit."""
        truth = self.recount()
        for key in set(truth) | set(self._totals):
            a = self._totals.get(key, 0.0)
            b = truth.get(key, 0.0)
            if not math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-6):
                return False
        return True

    def stats(self) -> Dict[str, Any]:
        """Serializable summary: per-class and per-tier bytes, peaks,
        projections, and the self-check bit (the bench ``memory`` key)."""
        by_class = {
            cls.value: self.class_bytes(cls, HBM) for cls in PageClass
        }
        peaks = self.peak_class_bytes()
        return {
            "by_class": by_class,
            "peak_by_class": {
                cls.value: peaks.get(cls, 0.0) for cls in PageClass
            },
            "by_tier": self.tier_breakdown(),
            "hbm_bytes": self.hbm_bytes(),
            "projected_bytes": self.projected_bytes(),
            "disk_spill_bytes": self.flow(HOST, DISK),
            "ledger_matches_recount": self.matches_recount(),
        }
