"""The :class:`Server` protocol — one front door shape for every runtime.

:class:`repro.serve.engine.ServingEngine` (one replica),
:class:`repro.serve.cluster.ServingCluster` (many replicas) and
:class:`repro.serve.frontdoor.FrontDoor` (admission control wrapping
either) all satisfy this structural type, so the open-loop traffic
driver (:func:`repro.serve.traffic.drive`) and every benchmark leg
target the protocol, never a concrete class:

    submit(request) → bool      accept a request (False = rejected at
                                the door; only the FrontDoor rejects)
    step()                      advance one engine tick
    run(max_ticks) → ServeReport   drive to completion, typed report
    replica_stats() → mapping   the load surface (capacity / projected
                                bytes / slots / queue depths)
    has_pending → bool          work still needs ticks
    tick → int                  the current simulation tick
"""

from __future__ import annotations

from typing import Any, Dict, Protocol, runtime_checkable

from repro.serve.report import ServeReport

__all__ = ["Server"]


@runtime_checkable
class Server(Protocol):
    """Structural type every serving front door satisfies."""

    def submit(self, req: Any) -> bool: ...

    def step(self) -> None: ...

    def run(self, max_ticks: int = 1000) -> ServeReport: ...

    def replica_stats(self) -> Dict[str, float]: ...

    @property
    def has_pending(self) -> bool: ...
