"""ServingCluster: N engine replicas behind a usage-rate-aware router.

The paper's setting is a *service*: many tenants' traffic lands on shared
servers at once, and pressure on one server degrades everyone on it.  A
single :class:`~repro.serve.engine.ServingEngine` mitigates pressure
WITHIN one HBM pool; this module is the step the ROADMAP calls "from a
server to a service" — the same pluggable policy layer applied ACROSS
replicas:

* **Routing** goes through ``SchedulingPolicy.placement_score(group,
  replica_stats)``: the router scores every (queued request, replica)
  pair against live replica stats (byte demand net of reclaimable cache,
  slot occupancy — both including the bytes already routed this pass) and
  places best-score-first.  The base score of 0.0 everywhere makes FAIR
  pure round-robin; :class:`MursPolicy` blends demand vs slot load by the
  tenant's usage-rate EMA (§III applied across machines); PriorityPolicy
  divides its aversion by tenant weight so heavy-weight traffic claims
  the emptiest replica on contended passes.

* **Straggler detection** reuses :class:`repro.dist.fault.
  StragglerDetector` verbatim over each replica's modeled tick service
  time (``ServingEngine.last_tick_cost`` × any injected slowdown — a
  deterministic stand-in for wall clock).  A flagged replica triggers
  **live request migration**: the victim's KV leaves the replica via
  :meth:`ServingEngine.export_request` (slot-cache subtree for running
  work, frozen payloads for suspended work, compressed tier blocks for
  demoted pages), crosses a modeled inter-replica link (the same
  :class:`~repro.serve.tiers.PcieLink` FIFO-drain semantics, at network
  rate, compressed bytes), and lands on the best target at delivery time
  via :meth:`ServingEngine.import_request`.

* **Crash recovery** is a fault-injection hook (:meth:`crash_replica`):
  the replica's live requests lose their KV (that is what a crash means)
  but not their identity — each is requeued through a per-request
  :class:`repro.dist.fault.RestartManager` (bounded retries, capped
  exponential backoff in ticks) and replays on whichever replica the
  router picks; only a request that exhausts its retry budget is lost.

Migration traffic is NOT spill (DESIGN.md §8): ``migration.wire_bytes``
crosses the inter-replica link to keep a request alive somewhere better,
while spill parks bytes below HBM on the same machine.  The two are
recorded separately and gated separately.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.configs.base import ArchConfig
from repro.dist.fault import RestartManager, StragglerDetector
from repro.sched import FairPolicy, SchedulingPolicy
from repro.serve.engine import EngineConfig, Request, ServingEngine
from repro.serve.report import (
    COMPLETED,
    FAILED,
    LOST,
    UNFINISHED,
    RequestOutcome,
    ServeReport,
)
from repro.serve.tiers import PcieLink

__all__ = ["ClusterConfig", "ReplicaCrash", "ServingCluster"]


class ReplicaCrash(RuntimeError):
    """The failure a crashed replica's requests are retried against."""


def _merge_tick_costs(stats: List[dict]) -> dict:
    """Cluster view of the replicas' roofline tick-cost distributions
    (same shape as ``ServingEngine.tick_cost_stats``: modeled seconds,
    tick-weighted mean, min/max envelope, distinct-value count)."""
    ticks = sum(s["ticks"] for s in stats)
    return {
        "source": "roofline",
        "ticks": ticks,
        "mean_s": (
            sum(s["mean_s"] * s["ticks"] for s in stats) / ticks
            if ticks else 0.0
        ),
        "min_s": min(
            (s["min_s"] for s in stats if s["ticks"]), default=0.0
        ),
        "max_s": max((s["max_s"] for s in stats), default=0.0),
        "distinct": max((s["distinct"] for s in stats), default=0),
        "paged_decode_ticks": sum(s["paged_decode_ticks"] for s in stats),
    }


@dataclass
class ClusterConfig:
    """Replica count, routing policy, link model, and fault knobs."""

    #: engine-config FACTORY — called once per replica (and per restart),
    #: because a policy instance is stateful and must never be shared
    engine: Callable[[], EngineConfig] = EngineConfig
    n_replicas: int = 2
    #: cluster-level routing policy (placement_score / assign); None →
    #: FairPolicy, i.e. pure round-robin spraying
    router: Optional[SchedulingPolicy] = None
    #: inter-replica link rate in bytes/tick (migrations FIFO-drain at
    #: this rate; compressed bytes cross, same arithmetic as the PCIe
    #: model).  inf → migration lands next tick.
    net_bytes_per_tick: float = float("inf")
    # ---- straggler pass (repro.dist.fault.StragglerDetector)
    straggler_min_samples: int = 8
    straggler_ratio: float = 1.5
    straggler_window: int = 32
    #: max live migrations initiated per straggler per pass
    migrate_batch: int = 2
    #: ticks a replica is left alone after migrations were pulled off it
    #: (its window mean needs time to reflect the lighter load)
    migration_cooldown_ticks: int = 8
    # ---- crash recovery (RestartManager-style bounded retry)
    max_retries: int = 3
    retry_backoff_ticks: float = 2.0
    max_backoff_ticks: float = 16.0

    def __post_init__(self) -> None:
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.net_bytes_per_tick <= 0:
            raise ValueError("net_bytes_per_tick must be > 0")


class ServingCluster:
    """N :class:`ServingEngine` replicas, one router, one straggler pass.

    The cluster owns its own clock: every :meth:`step` routes queued
    requests, drains the inter-replica link, ticks every live replica in
    lockstep, feeds the straggler detector, and harvests completions.
    Request latency is measured in CLUSTER ticks from first submission —
    a crash-requeued request keeps its original submit stamp, so retries
    show up as tail latency, never as amnesia.
    """

    def __init__(
        self, cfg: ArchConfig, params: Any, ccfg: ClusterConfig
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.ccfg = ccfg
        self.router: SchedulingPolicy = ccfg.router or FairPolicy()
        self.replicas: List[ServingEngine] = [
            ServingEngine(cfg, params, ccfg.engine())
            for _ in range(ccfg.n_replicas)
        ]
        self.link = PcieLink()  # the inter-replica network, same semantics
        self.detector = StragglerDetector(
            min_samples=ccfg.straggler_min_samples,
            ratio=ccfg.straggler_ratio,
            window=ccfg.straggler_window,
        )
        self.tick = 0
        self.queue: List[Request] = []  # cluster-level admission queue
        self._rr_cursor = 0  # round-robin tie-break over replicas
        #: rid → replica index (or -1 while its bytes are on the wire)
        self._home: Dict[str, int] = {}
        self._inflight: Dict[str, Any] = {}  # rid → MigrationTicket
        self._submit_tick: Dict[str, int] = {}
        self._finish_tick: Dict[str, int] = {}
        #: per-request crash-retry budget (RestartManager reused verbatim;
        #: its backoff seconds are read as cluster ticks)
        self._retry: Dict[str, RestartManager] = {}
        #: (due_tick, request) — crash-requeued work waiting out backoff
        self._requeue: List[Tuple[int, Request]] = []
        self._slowdown: List[float] = [1.0] * ccfg.n_replicas
        self._last_migration: List[int] = [-(10**9)] * ccfg.n_replicas
        self._done_seen: List[int] = [0] * ccfg.n_replicas
        self._failed_seen: List[int] = [0] * ccfg.n_replicas
        self._tokens_from_dead = 0.0
        self.completed: List[str] = []
        self.failed: List[str] = []
        self.lost: List[str] = []  # retry budget exhausted after crashes
        self.crashes = 0
        self.requeued = 0
        self.migrations_started = 0
        self.migrations_completed = 0
        self.migration_raw_bytes = 0.0
        self.migration_wire_bytes = 0.0
        self.straggler_flags = 0  # straggler-pass detections

    # -------------------------------------------------------------- tenants
    def submit(self, req: Request) -> bool:
        """Accept one request for routing; always True (the cluster never
        rejects — wrap it in a FrontDoor for admission control)."""
        self._submit_tick.setdefault(req.request_id, self.tick)
        self.queue.append(req)
        return True

    @property
    def policy(self) -> SchedulingPolicy:
        """The cluster-scope policy a wrapping FrontDoor sheds with."""
        return self.router

    def estimate_request_bytes(self, req: Request) -> float:
        """Page-rounded peak bytes (all replicas share one ArchConfig)."""
        return self.replicas[0].estimate_request_bytes(req)

    def group_demand(self) -> Dict[str, float]:
        """Projected peak bytes per tenant across the whole cluster:
        every replica's live demand plus everything routed but not yet
        placed (cluster queue, crash-requeued work, migrations in
        flight)."""
        out: Dict[str, float] = {}
        for eng in self.replicas:
            for tenant, nbytes in eng.group_demand().items():
                out[tenant] = out.get(tenant, 0.0) + nbytes
        waiting = [r for r in self.queue]
        waiting.extend(r for _, r in self._requeue)
        waiting.extend(t.request for t, _ in self._inflight.values())
        for req in waiting:
            out[req.tenant] = (
                out.get(req.tenant, 0.0) + self.estimate_request_bytes(req)
            )
        return out

    def replica_stats(self) -> Dict[str, float]:
        """Cluster-aggregate load surface, same keys as the engine's —
        capacity and projected bytes sum across replicas (plus unplaced
        work), fractions are byte-weighted over the summed capacity."""
        per = [eng.replica_stats() for eng in self.replicas]
        cap = sum(s["capacity_bytes"] for s in per)
        projected_bytes = sum(s["projected_bytes"] for s in per)
        unplaced = (
            len(self.queue) + len(self._requeue) + len(self._inflight)
        )
        for req in self.queue:
            projected_bytes += self.estimate_request_bytes(req)
        for _, req in self._requeue:
            projected_bytes += self.estimate_request_bytes(req)
        for ticket, _ in self._inflight.values():
            projected_bytes += self.estimate_request_bytes(ticket.request)
        demand_bytes = sum(
            s["demand_fraction"] * s["capacity_bytes"] for s in per
        )
        used_bytes = sum(
            s["used_fraction"] * s["capacity_bytes"] for s in per
        )
        n_slots = sum(eng.ecfg.n_slots for eng in self.replicas)
        return {
            "demand_fraction": demand_bytes / cap if cap > 0 else 0.0,
            "projected_fraction": projected_bytes / cap if cap > 0 else 0.0,
            "used_fraction": used_bytes / cap if cap > 0 else 0.0,
            "slot_load": (
                sum(s["slot_load"] * eng.ecfg.n_slots
                    for s, eng in zip(per, self.replicas))
                + unplaced
            ) / max(n_slots, 1),
            "free_slots": float(sum(s["free_slots"] for s in per)),
            "queued": float(
                sum(s["queued"] for s in per) + len(self.queue)
                + len(self._requeue)
            ),
            "live": float(sum(s["live"] for s in per) + unplaced),
            "suspended": float(sum(s["suspended"] for s in per)),
            "tick_cost": max(s["tick_cost"] for s in per),
            "capacity_bytes": float(cap),
            "projected_bytes": float(projected_bytes),
        }

    # ------------------------------------------------------- fault injection
    def set_slowdown(self, replica: int, factor: float) -> None:
        """Throttle a replica by ``factor`` (models a noisy neighbour /
        thermal throttle / failing host — the straggler the detector
        exists to catch).  The slowdown is REAL, not just observed: a
        replica at factor f steps only every ~f cluster ticks, so its
        requests genuinely crawl and migrating them off genuinely helps;
        the detector sees the matching f× service time."""
        if factor <= 0:
            raise ValueError("slowdown factor must be > 0")
        self._slowdown[replica] = factor

    def crash_replica(self, replica: int) -> int:
        """Kill and restart one replica.  Its KV is gone; its requests are
        not: each live/queued request is reset to a cold start and
        requeued after a bounded, capped backoff — unless its retry
        budget is exhausted, in which case it is recorded as lost (and
        failed).  Returns the number of requests requeued."""
        eng = self.replicas[replica]
        self._harvest_replica(replica)  # terminal states survive a crash
        # only DELIVERED work survives in the token count: a live
        # victim's pre-crash tokens die with the KV and are regenerated
        # elsewhere — counting them too would let a crash inflate the
        # gated cluster throughput above what was actually served
        self._tokens_from_dead += sum(
            len(r.generated)
            for r in eng.requests.values()
            if r.state in ("done", "failed")
        )
        victims = [rid for rid, _ in eng.migratable_requests()]
        requeued = 0
        for rid in victims:
            req = eng.requests[rid]
            self._home.pop(rid, None)
            rm = self._retry.setdefault(
                rid,
                RestartManager(
                    "",
                    max_retries=self.ccfg.max_retries,
                    backoff_s=self.ccfg.retry_backoff_ticks,
                    max_backoff_s=self.ccfg.max_backoff_ticks,
                ),
            )
            if not rm.should_retry():
                self.lost.append(rid)
                self.failed.append(rid)
                self._finish_tick[rid] = self.tick
                continue
            delay = rm.on_failure(ReplicaCrash(f"replica {replica} died"))
            self._reset_request(req)
            self._requeue.append((self.tick + int(round(delay)), req))
            requeued += 1
        self.requeued += requeued
        # restart: a fresh engine (fresh policy state, empty pool); the
        # detector forgets the dead process's samples
        self.replicas[replica] = ServingEngine(
            self.cfg, self.params, self.ccfg.engine()
        )
        self.detector.forget(self._host(replica))
        self._slowdown[replica] = 1.0
        self._done_seen[replica] = 0
        self._failed_seen[replica] = 0
        self.crashes += 1
        return requeued

    @staticmethod
    def _reset_request(req: Request) -> None:
        """Back to a cold start: the crash took the KV and every token
        generated so far; identity and the prompt survive."""
        req.slot = -1
        req.pos = 0
        req.generated = []
        req.state = "queued"
        req.finish_tick = -1
        req.first_token_tick = -1
        req.cached_tokens = 0
        req.snap_key = None
        req.hit_counted = False

    # -------------------------------------------------------------- routing
    def _host(self, replica: int) -> str:
        return f"r{replica}"

    def _route(self) -> None:
        """Place every queued request: score each (request, replica) pair
        via the router policy's ``placement_score``, place best-first,
        and fold each placement's estimated bytes/slot back into the
        stats so one routing pass cannot stack a burst onto the replica
        that merely LOOKED emptiest when the pass began."""
        if not self.queue:
            return
        stats = {
            i: dict(eng.replica_stats())
            for i, eng in enumerate(self.replicas)
        }
        caps = {
            i: max(eng.pool.capacity, 1.0)
            for i, eng in enumerate(self.replicas)
        }
        flagged = self._flagged_indices()
        if flagged and len(flagged) < len(self.replicas):
            # never route NEW work onto a detected straggler while a
            # healthy replica exists — placement_score has no straggler
            # axis, so the router enforces this exclusion itself
            stats = {i: s for i, s in stats.items() if i not in flagged}
        pending, self.queue = self.queue, []
        while pending:
            best: Optional[Tuple[float, int, int]] = None  # score, qpos, -i
            for qpos, req in enumerate(pending):
                for i in stats:
                    s = self.router.placement_score(req.tenant, stats[i])
                    # ties (score AND queue order) break round-robin via
                    # the cursor distance, so the base policy's all-zero
                    # scores reproduce classic round-robin spraying
                    rr = (i - self._rr_cursor) % len(self.replicas)
                    cand = (s, -qpos, -rr, i)
                    if best is None or cand > best:
                        best = cand
            _, nqpos, _, target = best
            req = pending.pop(-nqpos)
            eng = self.replicas[target]
            inbound = eng.estimate_request_bytes(req)
            stats[target]["demand_fraction"] += inbound / caps[target]
            stats[target]["projected_fraction"] = (
                stats[target].get("projected_fraction", 0.0)
                + inbound / caps[target]
            )
            stats[target]["slot_load"] += 1.0 / max(eng.ecfg.n_slots, 1)
            stats[target]["queued"] += 1.0
            eng.submit(req)
            self._home[req.request_id] = target
            self._rr_cursor = (target + 1) % len(self.replicas)

    def _flagged_indices(self) -> Set[int]:
        return {int(h[1:]) for h in self.detector.stragglers()}

    def _pick_target(self, group: str, exclude: Set[int]) -> int:
        """Best replica for a migrating request, at DELIVERY time — so a
        target that crashed (or started straggling) while the bytes were
        in flight is simply never chosen."""
        best: Optional[Tuple[float, int, int]] = None
        for i, eng in enumerate(self.replicas):
            if i in exclude and len(exclude) < len(self.replicas):
                continue
            s = self.router.placement_score(group, eng.replica_stats())
            rr = (i - self._rr_cursor) % len(self.replicas)
            cand = (s, -rr, i)
            if best is None or cand > best:
                best = cand
        return best[2]

    # ------------------------------------------------------------ migration
    def migrate(self, request_id: str, source: int) -> bool:
        """Begin live migration of one request off ``source``: extract its
        state, put the compressed bytes on the inter-replica link, and
        deliver to the best target when the transfer completes.  Returns
        False when the request is not there / not migratable."""
        ticket = self.replicas[source].export_request(request_id)
        if ticket is None:
            return False
        self._inflight[request_id] = (ticket, source)
        self._home[request_id] = -1
        self.migrations_started += 1
        self.migration_raw_bytes += ticket.raw_bytes
        self.migration_wire_bytes += ticket.wire_bytes
        self.link.send(
            request_id, ticket.wire_bytes, self.ccfg.net_bytes_per_tick
        )
        return True

    def _deliver_migrations(self) -> None:
        for tr in self.link.tick():
            entry = self._inflight.pop(tr.key, None)
            if entry is None:
                continue
            ticket, source = entry
            # exclude the source AND every currently-flagged straggler:
            # with 3+ replicas a victim must land on a healthy one, not
            # hop between two slow machines paying wire bytes each time
            target = self._pick_target(
                ticket.request.tenant,
                exclude={source} | self._flagged_indices(),
            )
            self.replicas[target].import_request(ticket)
            self._home[tr.key] = target
            self.migrations_completed += 1

    def _straggler_pass(self) -> None:
        flagged = self.detector.stragglers()
        if not flagged:
            return
        healthy = {
            i
            for i in range(len(self.replicas))
            if self._host(i) not in flagged
        }
        if not healthy:
            return  # everyone is slow: migration would just churn
        for host in flagged:
            i = int(host[1:])
            if (
                self.tick - self._last_migration[i]
                < self.ccfg.migration_cooldown_ticks
            ):
                continue
            victims = self.replicas[i].migratable_requests()
            moved = 0
            for rid, _state in victims:
                if moved >= self.ccfg.migrate_batch:
                    break
                if self.migrate(rid, i):
                    moved += 1
            if moved:
                self.straggler_flags += 1
                self._last_migration[i] = self.tick

    # ------------------------------------------------------------- harvest
    def _harvest_replica(self, i: int) -> None:
        eng = self.replicas[i]
        for rid in eng.completed[self._done_seen[i]:]:
            self.completed.append(rid)
            self._finish_tick[rid] = self.tick
            self._retry.pop(rid, None)
        self._done_seen[i] = len(eng.completed)
        for rid in eng.failed[self._failed_seen[i]:]:
            self.failed.append(rid)
            self._finish_tick[rid] = self.tick
            self._retry.pop(rid, None)
        self._failed_seen[i] = len(eng.failed)

    # ----------------------------------------------------------------- tick
    def step(self) -> None:
        # crash-requeued work whose backoff expired rejoins the queue
        due = [r for t, r in self._requeue if t <= self.tick]
        self._requeue = [(t, r) for t, r in self._requeue if t > self.tick]
        self.queue.extend(due)
        self._route()
        self._deliver_migrations()
        for i, eng in enumerate(self.replicas):
            # a throttled replica loses real ticks, not just face: at
            # slowdown f it advances once every ~f cluster ticks
            period = max(int(round(self._slowdown[i])), 1)
            if self.tick % period == 0:
                eng.step()
            self.detector.observe(
                self._host(i), eng.last_tick_cost * self._slowdown[i]
            )
            self._harvest_replica(i)
            # forward each replica policy's usage-rate EMAs into the
            # router: placement_score sees the SAME §III signal the
            # replica-local schedulers measured (a router never runs
            # propose, so this is its only rate feed)
            for g, r in eng.policy.group_rates().items():
                self.router.note_group_rate(g, r, float(self.tick))
        self._straggler_pass()
        self.tick += 1

    @property
    def has_pending(self) -> bool:
        return bool(
            self.queue
            or self._inflight
            or self._requeue
            or any(eng.has_pending for eng in self.replicas)
        )

    def run(self, max_ticks: int = 2000) -> ServeReport:
        """Tick until drained or out of budget; returns the typed
        :class:`~repro.serve.report.ServeReport` (the legacy dict payload
        rides in ``report.extras`` and through the deprecation shim).
        Cluster outcome rows carry cluster-tick latency only — TTFT/TPOT
        are engine-tick quantities and stay unset (-1/0), which the SLO
        scorer treats as unmeasured, not failed."""
        while self.tick < max_ticks and self.has_pending:
            self.step()
        lat = sorted(
            self._finish_tick[rid] - self._submit_tick[rid]
            for rid in self.completed
            if rid in self._submit_tick
        )
        tokens = self._tokens_from_dead + sum(
            len(r.generated)
            for eng in self.replicas
            for r in eng.requests.values()
        )
        legacy = {
            "policy": self.router.name,
            "n_replicas": len(self.replicas),
            "submitted": len(self._submit_tick),
            "completed": len(self.completed),
            "failed": len(self.failed),
            "lost": len(self.lost),
            "in_flight_unfinished": len(self._inflight),
            "crashes": self.crashes,
            "requeued": self.requeued,
            "straggler_flags": self.straggler_flags,
            "migrations": {
                "started": self.migrations_started,
                "completed": self.migrations_completed,
                "raw_bytes": self.migration_raw_bytes,
                "wire_bytes": self.migration_wire_bytes,
            },
            "latency_ticks": lat,
            "ticks": self.tick,
            "tokens_generated": tokens,
            "tick_cost": _merge_tick_costs(
                [eng.tick_cost_stats() for eng in self.replicas]
            ),
            "replicas": [
                {
                    "completed": len(eng.completed),
                    "failed": len(eng.failed),
                    "suspensions": eng.suspensions,
                    "offload_events": eng.reactive_offloads,
                    "migrations_in": eng.migrations_in,
                    "migrations_out": eng.migrations_out,
                    "peak_used_fraction": eng.peak_used_fraction,
                }
                for eng in self.replicas
            ],
        }
        # tokens each still-known request generated (crashed replicas'
        # histories are gone; their rows keep tokens=0)
        tok_by_rid: Dict[str, int] = {}
        for eng in self.replicas:
            for rid, r in eng.requests.items():
                tok_by_rid[rid] = len(r.generated)
        tenant_of: Dict[str, str] = {}
        for eng in self.replicas:
            for rid, r in eng.requests.items():
                tenant_of[rid] = r.tenant
        for source in (self.queue, [r for _, r in self._requeue]):
            for req in source:
                tenant_of[req.request_id] = req.tenant
        for ticket, _ in self._inflight.values():
            tenant_of[ticket.request.request_id] = ticket.request.tenant
        lost_set = set(self.lost)
        terminal: Dict[str, str] = {}
        for rid in self.completed:
            terminal[rid] = COMPLETED
        for rid in self.failed:
            # lost rids are recorded in both lists; LOST wins
            terminal[rid] = LOST if rid in lost_set else FAILED
        outcomes = []
        for rid, t0 in self._submit_tick.items():
            kind = terminal.get(rid, UNFINISHED)
            outcomes.append(
                RequestOutcome(
                    request_id=rid,
                    tenant=tenant_of.get(rid, ""),
                    outcome=kind,
                    submit_tick=t0,
                    finish_tick=self._finish_tick.get(rid, -1),
                    tokens=tok_by_rid.get(rid, 0),
                    reason=(
                        "crash retries exhausted" if kind == LOST else ""
                    ),
                )
            )
        rep = ServeReport(
            policy=self.router.name,
            submitted=len(self._submit_tick),
            ticks=self.tick,
            tokens_generated=int(tokens),
            throughput_tokens_per_tick=tokens / max(1, self.tick),
            outcomes=outcomes,
            cluster={
                k: legacy[k]
                for k in (
                    "n_replicas",
                    "crashes",
                    "requeued",
                    "straggler_flags",
                    "migrations",
                    "replicas",
                )
            },
            extras=legacy,
        )
        rep.refresh_summaries()
        # LOST rows count as failed in the headline (they ARE failures —
        # refresh_summaries only tallies FAILED, so fold them back in)
        rep.failed = len(self.failed)
        rep.apply_slo()
        return rep
