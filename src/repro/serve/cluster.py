"""ServingCluster: N engine replicas behind a usage-rate-aware router.

The paper's setting is a *service*: many tenants' traffic lands on shared
servers at once, and pressure on one server degrades everyone on it.  A
single :class:`~repro.serve.engine.ServingEngine` mitigates pressure
WITHIN one HBM pool; this module is the step the ROADMAP calls "from a
server to a service" — the same pluggable policy layer applied ACROSS
replicas:

* **Routing** goes through ``SchedulingPolicy.placement_score(group,
  replica_stats)``: the router scores every (queued request, replica)
  pair against live replica stats (byte demand net of reclaimable cache,
  slot occupancy — both including the bytes already routed this pass) and
  places best-score-first.  The base score of 0.0 everywhere makes FAIR
  pure round-robin; :class:`MursPolicy` blends demand vs slot load by the
  tenant's usage-rate EMA (§III applied across machines); PriorityPolicy
  divides its aversion by tenant weight so heavy-weight traffic claims
  the emptiest replica on contended passes.

* **Straggler detection** reuses :class:`repro.dist.fault.
  StragglerDetector` verbatim over each replica's modeled tick service
  time (``ServingEngine.last_tick_cost`` × any injected slowdown — a
  deterministic stand-in for wall clock).  A flagged replica triggers
  **live request migration**: the victim's KV leaves the replica via
  :meth:`ServingEngine.export_request` (slot-cache subtree for running
  work, frozen payloads for suspended work, compressed tier blocks for
  demoted pages), crosses a modeled inter-replica link (the same
  :class:`~repro.serve.tiers.PcieLink` FIFO-drain semantics, at network
  rate, compressed bytes), and lands on the best target at delivery time
  via :meth:`ServingEngine.import_request`.

* **Crash recovery** is a fault-injection hook (:meth:`crash_replica`):
  the replica's live requests lose their KV (that is what a crash means)
  but not their identity — each is requeued through a per-request
  :class:`repro.dist.fault.RestartManager` (bounded retries, capped
  exponential backoff in ticks) and replays on whichever replica the
  router picks; only a request that exhausts its retry budget is lost.

* **Elastic autoscaling** (DESIGN.md §11) thresholds the routing
  policy's ``scale_pressure`` — the same projected-demand surfaces
  placement scoring reads, folded to one fleet-level number in [0, 1]
  (MURS scales on where the BYTES are going, FAIR on slot occupancy).
  Sustained pressure above ``scale_up_pressure`` spawns a replica
  (unparking a drained slot before growing the fleet); sustained slack
  below ``scale_down_pressure`` **drains** one: new work stops routing
  to it, and each live request leaves via an *incremental* migration —
  a :meth:`ServingEngine.precopy_request` snapshot ships in the
  background while the replica keeps serving, then the cutover
  :meth:`ServingEngine.export_request` re-ships only the pages the
  write-epoch ledger marks dirty since the pre-copy.  The cutover
  (service-interrupting) bytes are gated below the monolithic full-copy
  counterfactual the ticket records alongside.

* **KV checkpointing** closes the loop with the disk tier: every
  ``checkpoint_every_ticks`` the cluster packs each replica's
  :meth:`ServingEngine.snapshot_kv` (shared-prefix pages first, §6
  lifetime order) into a self-describing ``repro.checkpoint`` file —
  manifest leaf first, one array leaf per page.  :meth:`crash_replica`
  then restores victims found in the newest checkpoint via
  :meth:`ServingEngine.restore_request` and replays only the suffix the
  checkpoint did not cover, instead of the from-zero reset un-covered
  victims still get.  Checkpoint bytes are their own stream
  (``TieredKVStore.note_checkpoint``), distinct from spill AND from
  migration wire bytes.

Migration traffic is NOT spill (DESIGN.md §8): ``migration.wire_bytes``
crosses the inter-replica link to keep a request alive somewhere better,
while spill parks bytes below HBM on the same machine.  The two are
recorded separately and gated separately.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import msgpack
import numpy as np

from repro.checkpoint import latest_step_path, restore_leaves
from repro.checkpoint import save as checkpoint_save
from repro.configs.base import ArchConfig
from repro.dist.fault import RestartManager, StragglerDetector
from repro.sched import FairPolicy, SchedulingPolicy
from repro.serve.engine import (
    EngineConfig,
    PrecopySnapshot,
    Request,
    ServingEngine,
)
from repro.serve.ledger import PageClass
from repro.serve.report import (
    COMPLETED,
    FAILED,
    LOST,
    UNFINISHED,
    RequestOutcome,
    ServeReport,
)
from repro.serve.tiers import PcieLink

__all__ = ["ClusterConfig", "ReplicaCrash", "ServingCluster"]


class ReplicaCrash(RuntimeError):
    """The failure a crashed replica's requests are retried against."""


def _merge_tick_costs(stats: List[dict]) -> dict:
    """Cluster view of the replicas' roofline tick-cost distributions
    (same shape as ``ServingEngine.tick_cost_stats``: modeled seconds,
    tick-weighted mean, min/max envelope, distinct-value count)."""
    ticks = sum(s["ticks"] for s in stats)
    return {
        "source": "roofline",
        "ticks": ticks,
        "mean_s": (
            sum(s["mean_s"] * s["ticks"] for s in stats) / ticks
            if ticks else 0.0
        ),
        "min_s": min(
            (s["min_s"] for s in stats if s["ticks"]), default=0.0
        ),
        "max_s": max((s["max_s"] for s in stats), default=0.0),
        "distinct": max((s["distinct"] for s in stats), default=0),
        "paged_decode_ticks": sum(s["paged_decode_ticks"] for s in stats),
    }


@dataclass
class ClusterConfig:
    """Replica count, routing policy, link model, and fault knobs."""

    #: engine-config FACTORY — called once per replica (and per restart),
    #: because a policy instance is stateful and must never be shared
    engine: Callable[[], EngineConfig] = EngineConfig
    n_replicas: int = 2
    #: cluster-level routing policy (placement_score / assign); None →
    #: FairPolicy, i.e. pure round-robin spraying
    router: Optional[SchedulingPolicy] = None
    #: inter-replica link rate in bytes/tick (migrations FIFO-drain at
    #: this rate; compressed bytes cross, same arithmetic as the PCIe
    #: model).  inf → migration lands next tick.
    net_bytes_per_tick: float = float("inf")
    # ---- straggler pass (repro.dist.fault.StragglerDetector)
    straggler_min_samples: int = 8
    straggler_ratio: float = 1.5
    straggler_window: int = 32
    #: max live migrations initiated per straggler per pass
    migrate_batch: int = 2
    #: ticks a replica is left alone after migrations were pulled off it
    #: (its window mean needs time to reflect the lighter load)
    migration_cooldown_ticks: int = 8
    # ---- crash recovery (RestartManager-style bounded retry)
    max_retries: int = 3
    retry_backoff_ticks: float = 2.0
    max_backoff_ticks: float = 16.0
    # ---- elastic autoscaling (DESIGN.md §11)
    #: threshold the router policy's ``scale_pressure`` every tick;
    #: False → the fleet stays at ``n_replicas`` forever
    autoscale: bool = False
    min_replicas: int = 1
    max_replicas: int = 4
    #: pressure in [0,1] that must hold for ``scale_sustain_ticks``
    #: before a replica is added / drained (hysteresis band between)
    scale_up_pressure: float = 0.75
    scale_down_pressure: float = 0.30
    scale_sustain_ticks: int = 25
    #: ticks between scaling actions (lets the routed load re-settle)
    scale_cooldown_ticks: int = 50
    #: drain via incremental pre-copy + dirty-page delta cutover;
    #: False → monolithic one-shot exports at cutover
    precopy_drain: bool = True
    # ---- periodic KV checkpointing (crash restore; 0 → disabled)
    checkpoint_every_ticks: int = 0
    checkpoint_dir: Optional[str] = None
    #: page cap per snapshot — truncates AFTER the §6 shared-first
    #: ordering, so a tight budget still holds the longest-lived pages
    checkpoint_page_budget: Optional[int] = None
    #: newest files kept per replica directory
    checkpoint_keep: int = 2

    def __post_init__(self) -> None:
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.net_bytes_per_tick <= 0:
            raise ValueError("net_bytes_per_tick must be > 0")
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if not self.scale_down_pressure <= self.scale_up_pressure:
            raise ValueError(
                "scale_down_pressure must be <= scale_up_pressure"
            )
        if self.checkpoint_every_ticks > 0 and not self.checkpoint_dir:
            raise ValueError(
                "checkpoint_every_ticks needs a checkpoint_dir"
            )


class ServingCluster:
    """N :class:`ServingEngine` replicas, one router, one straggler pass.

    The cluster owns its own clock: every :meth:`step` routes queued
    requests, drains the inter-replica link, ticks every live replica in
    lockstep, feeds the straggler detector, and harvests completions.
    Request latency is measured in CLUSTER ticks from first submission —
    a crash-requeued request keeps its original submit stamp, so retries
    show up as tail latency, never as amnesia.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        ccfg: ClusterConfig,
        models: Optional[List[Tuple[ArchConfig, Any]]] = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.ccfg = ccfg
        self.router: SchedulingPolicy = ccfg.router or FairPolicy()
        #: per-replica hosted model — ``models[i]`` is the (ArchConfig,
        #: params) replica ``i`` serves.  Default: every replica hosts
        #: the cluster's single model (the homogeneous fleet).  A
        #: heterogeneous model zoo passes one entry per replica; the
        #: router then only places a request on replicas hosting its
        #: declared ``Request.model``.
        if models is None:
            models = [(cfg, params)] * ccfg.n_replicas
        if len(models) != ccfg.n_replicas:
            raise ValueError(
                f"models must have one (cfg, params) entry per replica: "
                f"got {len(models)} for {ccfg.n_replicas} replicas"
            )
        self._models: List[Tuple[ArchConfig, Any]] = list(models)
        self.replicas: List[ServingEngine] = [
            ServingEngine(mcfg, mparams, ccfg.engine())
            for mcfg, mparams in self._models
        ]
        self.link = PcieLink()  # the inter-replica network, same semantics
        self.detector = StragglerDetector(
            min_samples=ccfg.straggler_min_samples,
            ratio=ccfg.straggler_ratio,
            window=ccfg.straggler_window,
        )
        self.tick = 0
        self.queue: List[Request] = []  # cluster-level admission queue
        self._rr_cursor = 0  # round-robin tie-break over replicas
        #: rid → replica index (or -1 while its bytes are on the wire)
        self._home: Dict[str, int] = {}
        self._inflight: Dict[str, Any] = {}  # rid → MigrationTicket
        self._submit_tick: Dict[str, int] = {}
        self._finish_tick: Dict[str, int] = {}
        #: per-request crash-retry budget (RestartManager reused verbatim;
        #: its backoff seconds are read as cluster ticks)
        self._retry: Dict[str, RestartManager] = {}
        #: (due_tick, request) — crash-requeued work waiting out backoff
        self._requeue: List[Tuple[int, Request]] = []
        self._slowdown: List[float] = [1.0] * ccfg.n_replicas
        self._last_migration: List[int] = [-(10**9)] * ccfg.n_replicas
        self._done_seen: List[int] = [0] * ccfg.n_replicas
        self._failed_seen: List[int] = [0] * ccfg.n_replicas
        self._tokens_from_dead = 0.0
        self.completed: List[str] = []
        self.failed: List[str] = []
        self.lost: List[str] = []  # retry budget exhausted after crashes
        #: typed router rejections: no active (or revivable) replica
        #: hosts the request's model — recorded in ``failed`` too, with
        #: the reason on the outcome row (never a silent drop)
        self.unroutable: List[str] = []
        #: requests that died at the router (never reached an engine) —
        #: kept so their outcome rows still carry tenant/model/reason
        self._unrouted: Dict[str, Request] = {}
        self.crashes = 0
        self.requeued = 0
        self.migrations_started = 0
        self.migrations_completed = 0
        self.migration_raw_bytes = 0.0
        self.migration_wire_bytes = 0.0
        self.straggler_flags = 0  # straggler-pass detections
        # ---- elastic autoscaling state
        #: drained replica slots: excluded from routing, stepping, and
        #: stats; the parallel per-replica lists stay index-stable and a
        #: scale-up UNPARKS the lowest slot before growing the fleet
        self._parked: Set[int] = set()
        #: replica index → tick its drain began (no new work routes
        #: there; live work leaves via pre-copy + delta cutover)
        self._draining: Dict[int, int] = {}
        #: "pre:<rid>" → (PrecopySnapshot, source) while the background
        #: copy is on the link; cutover fires at delivery
        self._precopy: Dict[str, Tuple[PrecopySnapshot, int]] = {}
        self._pressure_high = 0  # consecutive ticks above the up line
        self._pressure_low = 0  # consecutive ticks below the down line
        self._last_scale_tick = -(10**9)
        self.last_scale_pressure = 0.0
        self.scale_ups = 0
        self.scale_downs = 0
        self.peak_replicas = ccfg.n_replicas
        self.precopies_started = 0
        self.delta_cutovers = 0
        self.migration_delta_wire_bytes = 0.0
        self.migration_full_wire_bytes = 0.0  # monolithic counterfactual
        self.migration_precopy_wire_bytes = 0.0
        # ---- checkpoint / restore state
        self.ckpt_saved = 0
        self.ckpt_restored_requests = 0
        self.ckpt_restored_tokens = 0  # token positions restore kept
        self.ckpt_replayed_tokens = 0  # uncovered suffix actually redone
        self.ckpt_from_zero_tokens = 0  # what a cold reset would redo
        self.ckpt_outcomes: Dict[str, int] = {}

    # -------------------------------------------------------------- tenants
    def submit(self, req: Request) -> bool:
        """Accept one request for routing; always True (the cluster never
        rejects — wrap it in a FrontDoor for admission control)."""
        self._submit_tick.setdefault(req.request_id, self.tick)
        self.queue.append(req)
        return True

    @property
    def policy(self) -> SchedulingPolicy:
        """The cluster-scope policy a wrapping FrontDoor sheds with."""
        return self.router

    def hosted_models(self) -> List[str]:
        """Arch name each replica slot hosts (parked slots included —
        an unpark revives the same model)."""
        return [mcfg.name for mcfg, _ in self._models]

    def _capable(self, replica: int, req: Request) -> bool:
        """True when ``replica`` hosts the request's declared model (an
        untagged request runs anywhere — the homogeneous-fleet case)."""
        return (
            not req.model or self._models[replica][0].name == req.model
        )

    def _capable_for_model(self, replica: int, model: str) -> bool:
        return not model or self._models[replica][0].name == model

    def estimate_request_bytes(self, req: Request) -> float:
        """Page-rounded peak bytes, sized by a replica that HOSTS the
        request's model — a mamba request's demand must never be priced
        with a transformer's per-token geometry."""
        for i in range(len(self.replicas)):
            if self._capable(i, req):
                return self.replicas[i].estimate_request_bytes(req)
        return self.replicas[0].estimate_request_bytes(req)

    def group_demand(self) -> Dict[str, float]:
        """Projected peak bytes per tenant across the whole cluster:
        every replica's live demand plus everything routed but not yet
        placed (cluster queue, crash-requeued work, migrations in
        flight)."""
        out: Dict[str, float] = {}
        for i in self._active_indices():
            for tenant, nbytes in self.replicas[i].group_demand().items():
                out[tenant] = out.get(tenant, 0.0) + nbytes
        waiting = [r for r in self.queue]
        waiting.extend(r for _, r in self._requeue)
        waiting.extend(t.request for t, _ in self._inflight.values())
        for req in waiting:
            out[req.tenant] = (
                out.get(req.tenant, 0.0) + self.estimate_request_bytes(req)
            )
        return out

    def _active_indices(self) -> List[int]:
        """Replica indices that are on (not parked).  Draining replicas
        stay active — they are still serving what they are migrating."""
        return [
            i for i in range(len(self.replicas)) if i not in self._parked
        ]

    def replica_stats(self) -> Dict[str, float]:
        """Cluster-aggregate load surface, same keys as the engine's —
        capacity and projected bytes sum across ACTIVE replicas (plus
        unplaced work), fractions byte-weighted over summed capacity.
        Parked replicas contribute nothing: their capacity is off."""
        active = [self.replicas[i] for i in self._active_indices()]
        per = [eng.replica_stats() for eng in active]
        cap = sum(s["capacity_bytes"] for s in per)
        projected_bytes = sum(s["projected_bytes"] for s in per)
        unplaced = (
            len(self.queue) + len(self._requeue) + len(self._inflight)
        )
        for req in self.queue:
            projected_bytes += self.estimate_request_bytes(req)
        for _, req in self._requeue:
            projected_bytes += self.estimate_request_bytes(req)
        for ticket, _ in self._inflight.values():
            projected_bytes += self.estimate_request_bytes(ticket.request)
        demand_bytes = sum(
            s["demand_fraction"] * s["capacity_bytes"] for s in per
        )
        used_bytes = sum(
            s["used_fraction"] * s["capacity_bytes"] for s in per
        )
        n_slots = sum(eng.ecfg.n_slots for eng in active)
        out = {
            "demand_fraction": demand_bytes / cap if cap > 0 else 0.0,
            "projected_fraction": projected_bytes / cap if cap > 0 else 0.0,
            "used_fraction": used_bytes / cap if cap > 0 else 0.0,
            "slot_load": (
                sum(s["slot_load"] * eng.ecfg.n_slots
                    for s, eng in zip(per, active))
                + unplaced
            ) / max(n_slots, 1),
            "free_slots": float(sum(s["free_slots"] for s in per)),
            "queued": float(
                sum(s["queued"] for s in per) + len(self.queue)
                + len(self._requeue)
            ),
            "live": float(sum(s["live"] for s in per) + unplaced),
            "suspended": float(sum(s["suspended"] for s in per)),
            "tick_cost": max(s["tick_cost"] for s in per),
            "capacity_bytes": float(cap),
            "projected_bytes": float(projected_bytes),
        }
        # the class-aware fleet view: per-lifetime-class HBM bytes summed
        # across active replicas (each replica's row is its ledger's
        # breakdown) — what placement and scale_pressure read per-class
        for cls in PageClass:
            key = f"{cls.value}_bytes"
            out[key] = float(sum(s.get(key, 0.0) for s in per))
        out["frozen_fraction"] = (
            out[f"{PageClass.FROZEN.value}_bytes"] / cap if cap > 0 else 0.0
        )
        out["reclaimable_fraction"] = (
            sum(
                s.get("reclaimable_fraction", 0.0) * s["capacity_bytes"]
                for s in per
            )
            / cap
            if cap > 0
            else 0.0
        )
        return out

    def memory_stats(self) -> Dict[str, Any]:
        """Fleet memory view: every replica's ledger stats summed
        key-wise (per-class, per-tier, peaks, projected, spill), with
        ``ledger_matches_recount`` the AND across replicas — ONE replica
        drifting fails the fleet's hard bit."""
        per = [eng.memory_stats() for eng in self.replicas]
        out: Dict[str, Any] = {
            "by_class": {},
            "peak_by_class": {},
            "by_tier": {},
            "hbm_bytes": 0.0,
            "projected_bytes": 0.0,
            "disk_spill_bytes": 0.0,
            "ledger_matches_recount": True,
        }
        for s in per:
            for key in ("by_class", "peak_by_class", "by_tier"):
                agg = out[key]
                for k, v in s.get(key, {}).items():
                    agg[k] = agg.get(k, 0.0) + v
            for key in ("hbm_bytes", "projected_bytes", "disk_spill_bytes"):
                out[key] += float(s.get(key, 0.0))
            out["ledger_matches_recount"] = out[
                "ledger_matches_recount"
            ] and bool(s.get("ledger_matches_recount", True))
        return out

    # ------------------------------------------------------- fault injection
    def set_slowdown(self, replica: int, factor: float) -> None:
        """Throttle a replica by ``factor`` (models a noisy neighbour /
        thermal throttle / failing host — the straggler the detector
        exists to catch).  The slowdown is REAL, not just observed: a
        replica at factor f steps only every ~f cluster ticks, so its
        requests genuinely crawl and migrating them off genuinely helps;
        the detector sees the matching f× service time."""
        if factor <= 0:
            raise ValueError("slowdown factor must be > 0")
        self._slowdown[replica] = factor

    def crash_replica(self, replica: int) -> int:
        """Kill and restart one replica.  Its HBM KV is gone; its
        requests are not.  A victim found in the replica's newest disk
        checkpoint restores onto the fresh engine immediately
        (:meth:`ServingEngine.restore_request`) with its checkpointed
        position and tokens — only the suffix the checkpoint did not
        cover replays.  Every other live/queued victim is reset to a
        cold start and requeued after a bounded, capped backoff —
        unless its retry budget is exhausted, in which case it is
        recorded as lost (and failed).  Returns the number of requests
        requeued (restored victims are not requeued — they never left
        the replica)."""
        eng = self.replicas[replica]
        self._harvest_replica(replica)  # terminal states survive a crash
        # only DELIVERED work survives in the token count: a live
        # victim's pre-crash tokens die with the KV and are regenerated
        # elsewhere — counting them too would let a crash inflate the
        # gated cluster throughput above what was actually served
        self._tokens_from_dead += sum(
            len(r.generated)
            for r in eng.requests.values()
            if r.state in ("done", "failed")
        )
        # a drain mid-flight dies with the process: pending pre-copies
        # reference the dead engine's pages and epochs — a cutover
        # against them after restart would merge stale baselines
        self._draining.pop(replica, None)
        self._precopy = {
            k: v for k, v in self._precopy.items() if v[1] != replica
        }
        ckpt = self._read_checkpoint(replica)
        mcfg, mparams = self._models[replica]
        fresh = ServingEngine(mcfg, mparams, self.ccfg.engine())
        victims = [rid for rid, _ in eng.migratable_requests()]
        requeued = 0
        for rid in victims:
            req = eng.requests[rid]
            entry = ckpt.get(rid)
            if entry is not None:
                self._restore_victim(fresh, req, entry, replica)
                continue
            self._home.pop(rid, None)
            rm = self._retry.setdefault(
                rid,
                RestartManager(
                    "",
                    max_retries=self.ccfg.max_retries,
                    backoff_s=self.ccfg.retry_backoff_ticks,
                    max_backoff_s=self.ccfg.max_backoff_ticks,
                ),
            )
            if not rm.should_retry():
                self.lost.append(rid)
                self.failed.append(rid)
                self._finish_tick[rid] = self.tick
                continue
            delay = rm.on_failure(ReplicaCrash(f"replica {replica} died"))
            self._reset_request(req)
            self._requeue.append((self.tick + int(round(delay)), req))
            requeued += 1
        self.requeued += requeued
        # restart: a fresh engine (fresh policy state, empty pool); the
        # detector forgets the dead process's samples
        self.replicas[replica] = fresh
        self.detector.forget(self._host(replica))
        self._slowdown[replica] = 1.0
        self._done_seen[replica] = 0
        self._failed_seen[replica] = 0
        self.crashes += 1
        return requeued

    @staticmethod
    def _reset_request(req: Request) -> None:
        """Back to a cold start: the crash took the KV and every token
        generated so far; identity and the prompt survive."""
        req.slot = -1
        req.pos = 0
        req.generated = []
        req.state = "queued"
        req.finish_tick = -1
        req.first_token_tick = -1
        req.cached_tokens = 0
        req.snap_key = None
        req.hit_counted = False

    # -------------------------------------------------------- checkpointing
    def _ckpt_dir(self, replica: int) -> str:
        return os.path.join(
            str(self.ccfg.checkpoint_dir), self._host(replica)
        )

    def _checkpoint_pass(self) -> None:
        """Every ``checkpoint_every_ticks``: snapshot each active
        replica's KV (shared-prefix pages first, §6 lifetime order) into
        a self-describing checkpoint file under the replica's directory,
        pruning all but the newest ``checkpoint_keep``."""
        cc = self.ccfg
        if cc.checkpoint_every_ticks <= 0 or not cc.checkpoint_dir:
            return
        if self.tick == 0 or self.tick % cc.checkpoint_every_ticks:
            return
        for i in self._active_indices():
            snap = self.replicas[i].snapshot_kv(cc.checkpoint_page_budget)
            if snap is not None:
                self._write_checkpoint(i, snap)

    def _write_checkpoint(self, replica: int, snap: Dict[str, Any]) -> None:
        """Pack one :meth:`ServingEngine.snapshot_kv` result into the
        flat self-describing format :func:`repro.checkpoint.
        restore_leaves` reads back: leaf 0 is a msgpack manifest
        (epoch + per-request rid/pos/generated/page-index list), then
        one array leaf per checkpointed page in manifest order."""
        manifest: Dict[str, Any] = {"epoch": int(snap["epoch"]), "reqs": []}
        leaves: List[np.ndarray] = [np.zeros(0, dtype=np.uint8)]
        for entry in snap["reqs"]:
            idxs = sorted(entry["pages"])
            manifest["reqs"].append(
                {
                    "rid": entry["rid"],
                    "pos": int(entry["pos"]),
                    "generated": [int(t) for t in entry["generated"]],
                    "pages": idxs,
                }
            )
            leaves.extend(np.asarray(entry["pages"][j]) for j in idxs)
        blob = msgpack.packb(manifest, use_bin_type=True)
        leaves[0] = np.frombuffer(blob, dtype=np.uint8)
        d = self._ckpt_dir(replica)
        checkpoint_save(
            os.path.join(d, f"ckpt_{self.tick}.ckpt"),
            leaves,
            step=self.tick,
        )
        self.ckpt_saved += 1
        keep = max(self.ccfg.checkpoint_keep, 1)
        names = sorted(
            (
                n
                for n in os.listdir(d)
                if n.startswith("ckpt_") and n.endswith(".ckpt")
            ),
            key=lambda n: int(n[5:-5]),
        )
        for n in names[:-keep]:
            os.unlink(os.path.join(d, n))

    def _read_checkpoint(
        self, replica: int
    ) -> Dict[str, Dict[str, Any]]:
        """Load the replica's newest checkpoint back into
        ``rid → {"pos", "generated", "pages": {index: payload}}`` (empty
        when checkpointing is off, no file exists, or the file is
        unreadable — crash recovery then falls back to cold resets)."""
        cc = self.ccfg
        if cc.checkpoint_every_ticks <= 0 or not cc.checkpoint_dir:
            return {}
        path = latest_step_path(self._ckpt_dir(replica))
        if path is None:
            return {}
        try:
            leaves, _step = restore_leaves(path)
            manifest = msgpack.unpackb(leaves[0].tobytes(), raw=False)
        except Exception:
            return {}  # a torn/alien file must not turn crash into loss
        out: Dict[str, Dict[str, Any]] = {}
        cursor = 1
        for entry in manifest["reqs"]:
            pages: Dict[int, np.ndarray] = {}
            for idx in entry["pages"]:
                pages[int(idx)] = leaves[cursor]
                cursor += 1
            out[entry["rid"]] = {
                "pos": int(entry["pos"]),
                "generated": list(entry["generated"]),
                "pages": pages,
            }
        return out

    def _restore_victim(
        self,
        fresh: ServingEngine,
        req: Request,
        entry: Dict[str, Any],
        replica: int,
    ) -> None:
        """Land one crash victim from checkpointed state onto the
        replacement engine: position and tokens roll back to the
        checkpoint's values (everything after it died with the HBM),
        then :meth:`ServingEngine.restore_request` replays only what the
        checkpointed pages do not cover.  The from-zero counterfactual
        (what the cold-reset path would recompute) is recorded so the
        bench can gate restored replay strictly below it."""
        pos_at_crash = req.pos
        req.slot = -1
        req.finish_tick = -1
        req.cached_tokens = 0
        req.snap_key = None
        req.pos = int(entry["pos"])
        req.generated = list(entry["generated"])
        outcome = fresh.restore_request(req, entry["pages"])
        self._home[req.request_id] = replica
        self.ckpt_restored_requests += 1
        self.ckpt_restored_tokens += req.pos
        self.ckpt_replayed_tokens += max(pos_at_crash - req.pos, 0)
        self.ckpt_from_zero_tokens += pos_at_crash
        self.ckpt_outcomes[outcome] = (
            self.ckpt_outcomes.get(outcome, 0) + 1
        )

    # -------------------------------------------------------------- routing
    def _host(self, replica: int) -> str:
        return f"r{replica}"

    def _fail_unroutable(self, req: Request, why: str) -> None:
        """Typed router rejection: the request ends FAILED with an
        ``unroutable:`` reason on its outcome row — never a division
        error on an empty fleet, never a silent drop."""
        rid = req.request_id
        req.state = "failed"
        req.fail_reason = f"unroutable: {why}"
        req.finish_tick = self.tick
        self._submit_tick.setdefault(rid, self.tick)
        self._finish_tick[rid] = self.tick
        self.unroutable.append(rid)
        self.failed.append(rid)
        self._unrouted[rid] = req
        self._home.pop(rid, None)
        self._retry.pop(rid, None)

    def _unpark_capable(self, req: Request) -> Optional[int]:
        """Revive a parked replica that hosts ``req.model`` (autoscale
        fleets only — a hand-parked fleet stays parked and the request
        fails typed instead).  Returns the revived index or None."""
        if not self.ccfg.autoscale:
            return None
        for i in sorted(self._parked):
            if self._capable(i, req):
                self._parked.discard(i)
                self.scale_ups += 1
                self._last_scale_tick = self.tick
                return i
        return None

    def _route(self) -> None:
        """Place every queued request: score each (request, replica) pair
        via the router policy's ``placement_score``, place best-first,
        and fold each placement's estimated bytes/slot back into the
        stats so one routing pass cannot stack a burst onto the replica
        that merely LOOKED emptiest when the pass began.

        Capability comes first: a request tagged with a model only ever
        scores replicas HOSTING that model.  A request no scored replica
        can host falls back layer by layer — flagged stragglers, then a
        parked capable slot (autoscale revives it) — and only then fails
        with a typed ``unroutable`` outcome."""
        if not self.queue:
            return
        # parked replicas are off; draining replicas take no NEW work
        # (the whole point of a drain) — but if everything is draining,
        # serve anyway rather than starve the queue
        candidates = [
            i for i in self._active_indices() if i not in self._draining
        ]
        if not candidates:
            candidates = self._active_indices()
        if not candidates:
            # all-parked fleet: revive a slot (autoscale) or fail typed —
            # the scoring loop below must never see an empty stats map
            pending, self.queue = self.queue, []
            still: List[Request] = []
            for req in pending:
                revived = self._unpark_capable(req)
                if revived is not None:
                    candidates.append(revived)
                    still.append(req)
                elif candidates and any(
                    self._capable(i, req) for i in candidates
                ):
                    still.append(req)
                else:
                    self._fail_unroutable(req, "all replicas parked")
            if not candidates:
                return
            self.queue = still
            if not self.queue:
                return
        stats = {
            i: dict(self.replicas[i].replica_stats()) for i in candidates
        }
        caps = {
            i: max(self.replicas[i].pool.capacity, 1.0) for i in candidates
        }
        flagged = self._flagged_indices()
        if flagged and any(i not in flagged for i in stats):
            # never route NEW work onto a detected straggler while a
            # healthy replica exists — placement_score has no straggler
            # axis, so the router enforces this exclusion itself
            stats = {i: s for i, s in stats.items() if i not in flagged}

        def admit_stats(i: int) -> None:
            stats[i] = dict(self.replicas[i].replica_stats())
            caps[i] = max(self.replicas[i].pool.capacity, 1.0)

        pending, self.queue = self.queue, []
        routable: List[Request] = []
        for req in pending:
            if any(self._capable(i, req) for i in stats):
                routable.append(req)
                continue
            # sole capable replica was excluded as a straggler: routing
            # to a slow host beats failing the request
            fallback = next(
                (i for i in candidates if self._capable(i, req)), None
            )
            if fallback is not None:
                admit_stats(fallback)
                routable.append(req)
                continue
            revived = self._unpark_capable(req)
            if revived is not None:
                admit_stats(revived)
                routable.append(req)
                continue
            self._fail_unroutable(
                req, f"no active replica hosts model {req.model!r}"
            )
        pending = routable
        while pending:
            best: Optional[Tuple[float, int, int, int]] = None
            for qpos, req in enumerate(pending):
                for i in stats:
                    if not self._capable(i, req):
                        continue
                    s = self.router.placement_score(req.tenant, stats[i])
                    # ties (score AND queue order) break round-robin via
                    # the cursor distance, so the base policy's all-zero
                    # scores reproduce classic round-robin spraying
                    rr = (i - self._rr_cursor) % len(self.replicas)
                    cand = (s, -qpos, -rr, i)
                    if best is None or cand > best:
                        best = cand
            if best is None:  # defensive: partition above guarantees not
                for req in pending:
                    self._fail_unroutable(
                        req, f"no scored replica hosts model {req.model!r}"
                    )
                return
            _, nqpos, _, target = best
            req = pending.pop(-nqpos)
            eng = self.replicas[target]
            inbound = eng.estimate_request_bytes(req)
            stats[target]["demand_fraction"] += inbound / caps[target]
            stats[target]["projected_fraction"] = (
                stats[target].get("projected_fraction", 0.0)
                + inbound / caps[target]
            )
            stats[target]["slot_load"] += 1.0 / max(eng.ecfg.n_slots, 1)
            stats[target]["queued"] += 1.0
            eng.submit(req)
            self._home[req.request_id] = target
            self._rr_cursor = (target + 1) % len(self.replicas)

    def _flagged_indices(self) -> Set[int]:
        return {int(h[1:]) for h in self.detector.stragglers()}

    def _pick_target(
        self, group: str, exclude: Set[int], model: str = ""
    ) -> Optional[int]:
        """Best replica for a migrating request, at DELIVERY time — so a
        target that crashed, started straggling, parked, or began its
        own drain while the bytes were in flight is simply never chosen
        (falling back layer by layer when exclusions cover everyone).

        ``model`` is a HARD filter at every layer: migration refuses
        cross-arch targets outright — a transformer's KV pages mean
        nothing to a mamba replica.  Returns None when no capable
        replica exists at all."""

        def hosts(i: int) -> bool:
            return self._capable_for_model(i, model)

        avoid = set(exclude) | self._parked | set(self._draining)
        cands = [
            i
            for i in range(len(self.replicas))
            if i not in avoid and hosts(i)
        ]
        if not cands:  # only excluded replicas left: drop the soft axes
            cands = [
                i
                for i in self._active_indices()
                if i not in self._draining and hosts(i)
            ]
        if not cands:
            cands = [i for i in self._active_indices() if hosts(i)]
        if not cands:
            cands = [
                i for i in range(len(self.replicas)) if hosts(i)
            ]
        if not cands:
            return None  # no capable replica anywhere: caller decides
        best: Optional[Tuple[float, int, int]] = None
        for i in cands:
            s = self.router.placement_score(
                group, self.replicas[i].replica_stats()
            )
            rr = (i - self._rr_cursor) % len(self.replicas)
            cand = (s, -rr, i)
            if best is None or cand > best:
                best = cand
        return best[2]

    def _has_capable_target(self, model: str, exclude: Set[int]) -> bool:
        """Any non-parked replica outside ``exclude`` hosting ``model``?
        Consulted BEFORE exporting a request off its source — an export
        with nowhere to land would strand the only copy of its state."""
        return any(
            i not in exclude
            and i not in self._parked
            and self._capable_for_model(i, model)
            for i in range(len(self.replicas))
        )

    # ------------------------------------------------------------ migration
    def migrate(self, request_id: str, source: int) -> bool:
        """Begin live migration of one request off ``source``: extract its
        state, put the compressed bytes on the inter-replica link, and
        deliver to the best target when the transfer completes.  Returns
        False when the request is not there / not migratable — or when
        NO other replica hosts its model (migration refuses cross-arch
        targets, so exporting would strand the state)."""
        req = self.replicas[source].requests.get(request_id)
        if req is not None and not self._has_capable_target(
            req.model, exclude={source}
        ):
            return False
        ticket = self.replicas[source].export_request(request_id)
        if ticket is None:
            return False
        self._inflight[request_id] = (ticket, source)
        self._home[request_id] = -1
        self.migrations_started += 1
        self.migration_raw_bytes += ticket.raw_bytes
        self.migration_wire_bytes += ticket.wire_bytes
        self.link.send(
            request_id, ticket.wire_bytes, self.ccfg.net_bytes_per_tick
        )
        return True

    def _cutover(self, rid: str, snap: PrecopySnapshot, source: int) -> None:
        """Phase two of an incremental drain: the pre-copy bytes have
        landed, so export the request NOW with the snapshot as the
        baseline — the ticket ships only the dirty delta; the pre-copy
        plus delta replace what one monolithic copy would have moved."""
        req = self.replicas[source].requests.get(rid)
        if req is not None and not self._has_capable_target(
            req.model, exclude={source}
        ):
            return  # nowhere capable to land: the request stays put
        ticket = self.replicas[source].export_request(rid, baseline=snap)
        if ticket is None:
            return  # finished (or moved) while the pre-copy was in flight
        self._inflight[rid] = (ticket, source)
        self._home[rid] = -1
        self.migrations_started += 1
        self.migration_raw_bytes += ticket.raw_bytes
        self.migration_wire_bytes += ticket.wire_bytes
        if ticket.full_wire_bytes > 0:
            # the delta path ran: record cutover vs counterfactual
            self.delta_cutovers += 1
            self.migration_delta_wire_bytes += ticket.wire_bytes
            self.migration_full_wire_bytes += ticket.full_wire_bytes
            self.migration_precopy_wire_bytes += ticket.precopy_wire_bytes
        self.link.send(
            rid, ticket.wire_bytes, self.ccfg.net_bytes_per_tick
        )

    def _deliver_migrations(self) -> None:
        for tr in self.link.tick():
            key = str(tr.key)
            if key.startswith("pre:"):
                pre = self._precopy.pop(key, None)
                if pre is not None:
                    self._cutover(key[4:], pre[0], pre[1])
                continue
            entry = self._inflight.pop(tr.key, None)
            if entry is None:
                continue
            ticket, source = entry
            # exclude the source AND every currently-flagged straggler:
            # with 3+ replicas a victim must land on a healthy one, not
            # hop between two slow machines paying wire bytes each time
            target = self._pick_target(
                ticket.request.tenant,
                exclude={source} | self._flagged_indices(),
                model=ticket.request.model,
            )
            if target is None:
                # every capable replica vanished while the bytes were on
                # the wire (crash + repark): fail typed, never import
                # cross-arch and never drop silently
                self._fail_unroutable(
                    ticket.request,
                    f"no capable migration target for model "
                    f"{ticket.request.model!r}",
                )
                continue
            self.replicas[target].import_request(ticket)
            self._home[tr.key] = target
            self.migrations_completed += 1

    def _straggler_pass(self) -> None:
        flagged = self.detector.stragglers()
        if not flagged:
            return
        healthy = {
            i
            for i in range(len(self.replicas))
            if self._host(i) not in flagged
        }
        if not healthy:
            return  # everyone is slow: migration would just churn
        for host in flagged:
            i = int(host[1:])
            if i in self._draining or i in self._parked:
                continue  # the drain is already emptying it
            if (
                self.tick - self._last_migration[i]
                < self.ccfg.migration_cooldown_ticks
            ):
                continue
            victims = self.replicas[i].migratable_requests()
            moved = 0
            for rid, _state in victims:
                if moved >= self.ccfg.migrate_batch:
                    break
                if self.migrate(rid, i):
                    moved += 1
            if moved:
                self.straggler_flags += 1
                self._last_migration[i] = self.tick

    # ----------------------------------------------------- elastic scaling
    def _scale_pass(self) -> None:
        """Threshold the routing policy's ``scale_pressure`` with
        hysteresis: the signal must hold past the up/down line for
        ``scale_sustain_ticks`` consecutive ticks, and actions are
        ``scale_cooldown_ticks`` apart — a diurnal swell scales the
        fleet, a single bursty tick does not."""
        cc = self.ccfg
        serving = [
            i for i in self._active_indices() if i not in self._draining
        ]
        self.peak_replicas = max(self.peak_replicas, len(serving))
        if not cc.autoscale:
            return
        if not serving:
            # an all-parked fleet with autoscale on must be able to
            # revive itself: pending work IS maximal pressure (the mean
            # over zero replicas would divide by nothing / read as calm)
            if self.queue or self._requeue or self._inflight:
                self._scale_up()
            return
        stats = [self.replicas[i].replica_stats() for i in serving]
        pressure = self.router.scale_pressure(stats)
        self.last_scale_pressure = pressure
        if pressure >= cc.scale_up_pressure:
            self._pressure_high += 1
            self._pressure_low = 0
        elif pressure <= cc.scale_down_pressure:
            self._pressure_low += 1
            self._pressure_high = 0
        else:  # the hysteresis band: both streaks break
            self._pressure_high = 0
            self._pressure_low = 0
        if self.tick - self._last_scale_tick < cc.scale_cooldown_ticks:
            return
        if (
            self._pressure_high >= cc.scale_sustain_ticks
            and len(serving) < cc.max_replicas
        ):
            self._scale_up()
            self._pressure_high = 0
        elif (
            self._pressure_low >= cc.scale_sustain_ticks
            and len(serving) > cc.min_replicas
            and not self._draining  # one drain at a time
        ):
            # drain the emptiest replica: fewest live requests to move
            victim = min(
                serving,
                key=lambda i: (
                    self.replicas[i].replica_stats()["live"],
                    -i,  # ties drain the highest index
                ),
            )
            self._begin_drain(victim)
            self._pressure_low = 0

    def _scale_up(self) -> None:
        """Add one serving replica: unpark the lowest drained slot if
        one exists (its engine is already fresh), else grow the fleet —
        every per-replica parallel list grows with it."""
        if self._parked:
            self._parked.discard(min(self._parked))
        else:
            # a grown slot hosts the cluster's default model
            self._models.append((self.cfg, self.params))
            self.replicas.append(
                ServingEngine(self.cfg, self.params, self.ccfg.engine())
            )
            self._slowdown.append(1.0)
            self._last_migration.append(-(10**9))
            self._done_seen.append(0)
            self._failed_seen.append(0)
        self.scale_ups += 1
        self._last_scale_tick = self.tick

    def drain_replica(self, replica: int) -> int:
        """Operator-initiated drain (planned maintenance, a deploy, or
        manual scale-in): ``replica`` stops receiving new work and its
        live requests leave via the same incremental pre-copy + delta
        cutover an autoscaler drain uses; the slot parks once empty and
        a later scale-up can unpark it.  Returns how many live requests
        began a background pre-copy (zero-KV and un-snapshottable work
        moves monolithically on the next tick instead)."""
        if replica in self._parked or replica in self._draining:
            return 0
        before = self.precopies_started
        self._begin_drain(replica)
        return self.precopies_started - before

    def _begin_drain(self, replica: int) -> None:
        """Start emptying one replica for scale-down.  Routing stops
        sending it new work immediately; each live request's resident
        pages pre-copy onto the link WHILE the replica keeps serving it
        (the request keeps decoding — and dirtying pages — until its
        pre-copy lands and :meth:`_cutover` ships just the delta)."""
        self._draining[replica] = self.tick
        self._last_scale_tick = self.tick
        if not self.ccfg.precopy_drain:
            return  # _drain_pass will export monolithically instead
        eng = self.replicas[replica]
        for rid, _state in eng.migratable_requests():
            snap = eng.precopy_request(rid)
            if snap is None:
                continue  # queued / constant-state: monolithic later
            key = "pre:" + rid
            self._precopy[key] = (snap, replica)
            self.precopies_started += 1
            self.migration_raw_bytes += snap.raw_bytes
            self.migration_wire_bytes += snap.wire_bytes
            self.link.send(
                key, snap.wire_bytes, self.ccfg.net_bytes_per_tick
            )

    def _drain_pass(self) -> None:
        """Advance every in-progress drain: export whatever is not
        already pre-copying (queued work ships zero bytes; anything the
        pre-copy pass could not snapshot goes monolithically), then park
        the replica once it is empty and its pre-copies have cut over."""
        for i in list(self._draining):
            eng = self.replicas[i]
            pending = {
                k[4:] for k, (_s, src) in self._precopy.items() if src == i
            }
            for rid, _state in eng.migratable_requests():
                if rid in pending or rid in self._inflight:
                    continue
                self.migrate(rid, i)
            if not eng.has_pending and not pending:
                self._park(i)

    def _park(self, replica: int) -> None:
        """Finish a drain: harvest the last completions, switch the slot
        off, and leave a fresh engine in it so a later unpark starts
        cold (the drained process's policy state dies with it)."""
        self._harvest_replica(replica)
        self._draining.pop(replica, None)
        self._parked.add(replica)
        mcfg, mparams = self._models[replica]
        self.replicas[replica] = ServingEngine(
            mcfg, mparams, self.ccfg.engine()
        )
        self.detector.forget(self._host(replica))
        self._slowdown[replica] = 1.0
        self._done_seen[replica] = 0
        self._failed_seen[replica] = 0
        self.scale_downs += 1
        self._last_scale_tick = self.tick

    # ------------------------------------------------------------- harvest
    def _harvest_replica(self, i: int) -> None:
        eng = self.replicas[i]
        for rid in eng.completed[self._done_seen[i]:]:
            self.completed.append(rid)
            self._finish_tick[rid] = self.tick
            self._retry.pop(rid, None)
        self._done_seen[i] = len(eng.completed)
        for rid in eng.failed[self._failed_seen[i]:]:
            self.failed.append(rid)
            self._finish_tick[rid] = self.tick
            self._retry.pop(rid, None)
        self._failed_seen[i] = len(eng.failed)

    # ----------------------------------------------------------------- tick
    def step(self) -> None:
        """Advance one cluster tick: requeue due retries, route, deliver
        migrations, step active replicas (throttled ones skip ticks),
        then the straggler / drain / autoscale / checkpoint passes."""
        # crash-requeued work whose backoff expired rejoins the queue
        due = [r for t, r in self._requeue if t <= self.tick]
        self._requeue = [(t, r) for t, r in self._requeue if t > self.tick]
        self.queue.extend(due)
        self._route()
        self._deliver_migrations()
        for i in self._active_indices():  # parked replicas are off
            eng = self.replicas[i]
            # a throttled replica loses real ticks, not just face: at
            # slowdown f it advances once every ~f cluster ticks
            period = max(int(round(self._slowdown[i])), 1)
            if self.tick % period == 0:
                eng.step()
            self.detector.observe(
                self._host(i), eng.last_tick_cost * self._slowdown[i]
            )
            self._harvest_replica(i)
            # forward each replica policy's usage-rate EMAs into the
            # router: placement_score sees the SAME §III signal the
            # replica-local schedulers measured (a router never runs
            # propose, so this is its only rate feed)
            for g, r in eng.policy.group_rates().items():
                self.router.note_group_rate(g, r, float(self.tick))
            # forward declared architecture classes the same way: the
            # router's shed/placement hooks clamp structurally-flat
            # (constant-state) tenants even before any EMA warms up
            for g, c in eng.policy.group_classes().items():
                self.router.note_group_class(g, c)
        self._straggler_pass()
        self._drain_pass()
        self._scale_pass()
        self._checkpoint_pass()
        self.tick += 1

    @property
    def has_pending(self) -> bool:
        return bool(
            self.queue
            or self._inflight
            or self._precopy
            or self._requeue
            or any(eng.has_pending for eng in self.replicas)
        )

    def run(self, max_ticks: int = 2000) -> ServeReport:
        """Tick until drained or out of budget; returns the typed
        :class:`~repro.serve.report.ServeReport` (the legacy dict payload
        rides in ``report.extras``).
        Cluster outcome rows carry cluster-tick latency only — TTFT/TPOT
        are engine-tick quantities and stay unset (-1/0), which the SLO
        scorer treats as unmeasured, not failed."""
        while self.tick < max_ticks and self.has_pending:
            self.step()
        lat = sorted(
            self._finish_tick[rid] - self._submit_tick[rid]
            for rid in self.completed
            if rid in self._submit_tick
        )
        tokens = self._tokens_from_dead + sum(
            len(r.generated)
            for eng in self.replicas
            for r in eng.requests.values()
        )
        legacy = {
            "policy": self.router.name,
            "n_replicas": len(self.replicas),
            "hosted_models": self.hosted_models(),
            "submitted": len(self._submit_tick),
            "completed": len(self.completed),
            "failed": len(self.failed),
            "lost": len(self.lost),
            "unroutable": len(self.unroutable),
            "misroutes": sum(eng.misroutes for eng in self.replicas),
            "in_flight_unfinished": len(self._inflight),
            "crashes": self.crashes,
            "requeued": self.requeued,
            "straggler_flags": self.straggler_flags,
            "migrations": {
                "started": self.migrations_started,
                "completed": self.migrations_completed,
                "raw_bytes": self.migration_raw_bytes,
                "wire_bytes": self.migration_wire_bytes,
            },
            "autoscale": {
                "enabled": self.ccfg.autoscale,
                "scale_ups": self.scale_ups,
                "scale_downs": self.scale_downs,
                "peak_replicas": self.peak_replicas,
                "active_replicas": len(self._active_indices()),
                "parked": sorted(self._parked),
                "last_pressure": self.last_scale_pressure,
            },
            "delta_migration": {
                "precopies": self.precopies_started,
                "delta_cutovers": self.delta_cutovers,
                "precopy_wire_bytes": self.migration_precopy_wire_bytes,
                "delta_wire_bytes": self.migration_delta_wire_bytes,
                # what the same cutovers would have shipped monolithically
                "full_wire_bytes": self.migration_full_wire_bytes,
            },
            "checkpoint": {
                "saved": self.ckpt_saved,
                "restored_requests": self.ckpt_restored_requests,
                "restored_tokens": self.ckpt_restored_tokens,
                "replayed_tokens": self.ckpt_replayed_tokens,
                # what cold resets of the same victims would recompute
                "from_zero_tokens": self.ckpt_from_zero_tokens,
                "outcomes": dict(self.ckpt_outcomes),
            },
            "latency_ticks": lat,
            "ticks": self.tick,
            "tokens_generated": tokens,
            "tick_cost": _merge_tick_costs(
                [eng.tick_cost_stats() for eng in self.replicas]
            ),
            "memory": self.memory_stats(),
            "replicas": [
                {
                    "completed": len(eng.completed),
                    "failed": len(eng.failed),
                    "suspensions": eng.suspensions,
                    "offload_events": eng.reactive_offloads,
                    "migrations_in": eng.migrations_in,
                    "migrations_out": eng.migrations_out,
                    "peak_used_fraction": eng.peak_used_fraction,
                }
                for eng in self.replicas
            ],
        }
        # tokens each still-known request generated (crashed replicas'
        # histories are gone; their rows keep tokens=0)
        tok_by_rid: Dict[str, int] = {}
        for eng in self.replicas:
            for rid, r in eng.requests.items():
                tok_by_rid[rid] = len(r.generated)
        tenant_of: Dict[str, str] = {}
        model_of: Dict[str, str] = {}
        reason_of: Dict[str, str] = {}
        for eng in self.replicas:
            for rid, r in eng.requests.items():
                tenant_of[rid] = r.tenant
                model_of[rid] = r.model
                if r.fail_reason:
                    reason_of[rid] = r.fail_reason
        for source in (
            self.queue,
            [r for _, r in self._requeue],
            self._unrouted.values(),
        ):
            for req in source:
                tenant_of[req.request_id] = req.tenant
                model_of[req.request_id] = req.model
                if req.fail_reason:
                    reason_of[req.request_id] = req.fail_reason
        for ticket, _ in self._inflight.values():
            tenant_of[ticket.request.request_id] = ticket.request.tenant
            model_of[ticket.request.request_id] = ticket.request.model
        lost_set = set(self.lost)
        terminal: Dict[str, str] = {}
        for rid in self.completed:
            terminal[rid] = COMPLETED
        for rid in self.failed:
            # lost rids are recorded in both lists; LOST wins
            terminal[rid] = LOST if rid in lost_set else FAILED
        outcomes = []
        for rid, t0 in self._submit_tick.items():
            kind = terminal.get(rid, UNFINISHED)
            outcomes.append(
                RequestOutcome(
                    request_id=rid,
                    tenant=tenant_of.get(rid, ""),
                    outcome=kind,
                    submit_tick=t0,
                    finish_tick=self._finish_tick.get(rid, -1),
                    tokens=tok_by_rid.get(rid, 0),
                    reason=(
                        "crash retries exhausted"
                        if kind == LOST
                        else reason_of.get(rid, "")
                    ),
                    model=model_of.get(rid, ""),
                )
            )
        rep = ServeReport(
            policy=self.router.name,
            submitted=len(self._submit_tick),
            ticks=self.tick,
            tokens_generated=int(tokens),
            throughput_tokens_per_tick=tokens / max(1, self.tick),
            outcomes=outcomes,
            cluster={
                k: legacy[k]
                for k in (
                    "n_replicas",
                    "crashes",
                    "requeued",
                    "straggler_flags",
                    "migrations",
                    "autoscale",
                    "delta_migration",
                    "checkpoint",
                    "hosted_models",
                    "unroutable",
                    "replicas",
                )
            },
            memory=legacy["memory"],
            extras=legacy,
        )
        rep.refresh_summaries()
        # LOST rows count as failed in the headline (they ARE failures —
        # refresh_summaries only tallies FAILED, so fold them back in)
        rep.failed = len(self.failed)
        rep.apply_slo()
        return rep
