"""Tiered KV hierarchy below HBM: compressed host tier + disk-spill tier.

The serving engine's memory below the page pool used to be free and
boolean — "offload" released a request's whole page table and replayed
the prompt, with no host capacity, no transfer cost, and no disk.  This
module is the missing hierarchy, modeled the way the MURS paper treats
the space below the heap (its "data spilling" is our disk-tier traffic):

    HBM (page pool)  ──demote──▶  host DRAM  ──LRU evict──▶  disk
         ▲                           │
         └────────promote────────────┘ (disk reads pay the slow link)

Three pieces:

* **int8 page compression** — demoted pages are stored quantized through
  :func:`repro.dist.compression.quantize` / ``dequantize`` (symmetric
  per-tensor int8, error ≤ scale/2).  Byte accounting follows the same
  model everywhere: a page of ``raw_bytes`` (2-byte elements) stores and
  *moves* as ``raw_bytes/2 + 4`` bytes — compression directly halves the
  PCIe ticks a transfer occupies.  When the caller hands a real payload
  (the engine extracts the page's token span from its slot cache), the
  actual int8 codes are kept and the dequantized array is returned on
  promotion, so the lossy round-trip is real, not notional.

* **a PCIe bandwidth model** — one FIFO link; each transfer drains at its
  tier's rate (``pcie_bytes_per_tick`` for host, the slower
  ``disk_bytes_per_tick`` for disk reads).  Demotion frees the HBM page
  immediately (the bytes are in flight); promotion lands only when the
  transfer completes — the tick gap is the engine's transfer stall.

* **a disk third tier** — host DRAM has *capacity*; when a completing
  demotion would overflow it, cold host entries spill to disk (LRU).
  ``disk_spill_bytes`` is the paper's spill metric: traffic that fell out
  of both fast tiers.  Disk writes are buffered (cost bytes, not link
  time); disk reads pay the slow link on promotion.

Invariants (pinned by the hypothesis property test in
``tests/test_tiers.py``):

* a page is in exactly ONE place: HBM (untracked), in flight, host, or
  disk — never two tiers at once;
* raw bytes are conserved across demotion, host→disk eviction, and
  promotion (a block's ``raw_bytes`` never changes while tracked);
* a demoted page is never readable (``touch`` False) until a
  ``("resident", key, payload)`` promotion event has been emitted.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.dist.compression import dequantize, quantize
from repro.serve.ledger import MemoryLedger

__all__ = [
    "TierConfig",
    "CompressedBlock",
    "PcieLink",
    "TieredKVStore",
    "wire_bytes_for",
]

#: location states of a tracked block (untracked ⇒ resident in HBM)
TO_HOST = "to_host"
HOST = "host"
DISK = "disk"
TO_HBM = "to_hbm"

#: f32 scale riding along with each quantized block (wire + at-rest)
_SCALE_BYTES = 4.0
#: int8 codes are half the bytes of the 2-byte-element page model
_INT8_RATIO = 0.5


def wire_bytes_for(raw_bytes: float, n_pages: int, compress: bool) -> float:
    """Wire/at-rest size of ``n_pages`` pages totalling ``raw_bytes``
    under the tier compression model — the byte arithmetic every link in
    the system (PCIe demotion, inter-replica migration) shares, so
    "compression halves the transfer" means the same thing everywhere."""
    if not compress or raw_bytes <= 0.0:
        return max(raw_bytes, 0.0)
    return raw_bytes * _INT8_RATIO + _SCALE_BYTES * max(n_pages, 1)


@dataclass(frozen=True)
class TierConfig:
    """Capacities and link rates of the hierarchy below HBM."""

    #: host-DRAM budget for demoted pages (bytes AT REST, i.e. compressed)
    host_capacity_bytes: float
    #: HBM↔host link rate; a transfer of n bytes occupies n/rate ticks
    pcie_bytes_per_tick: float = float("inf")
    #: disk→host read rate (slower; disk writes are buffered and free)
    disk_bytes_per_tick: float = float("inf")
    #: int8-compress demoted pages (off ⇒ raw bytes move and rest)
    compress: bool = True

    def __post_init__(self) -> None:
        if self.host_capacity_bytes < 0:
            raise ValueError("host_capacity_bytes must be >= 0")
        if self.pcie_bytes_per_tick <= 0 or self.disk_bytes_per_tick <= 0:
            raise ValueError("link rates must be > 0 bytes/tick")


@dataclass
class CompressedBlock:
    """One demoted page at rest: int8 codes + scale, or raw when
    compression is off.  ``raw_bytes`` is the page's HBM (byte-model)
    size and never changes while the block lives — the conservation
    invariant of the tier hierarchy."""

    raw_bytes: float
    stored_bytes: float
    codes: Optional[np.ndarray] = None  # int8 payload (when one was given)
    scale: float = 0.0
    quant_error: float = 0.0  # max |payload − dequantized| of this block
    last_use: float = 0.0

    @classmethod
    def compress(
        cls, raw_bytes: float, payload: Optional[np.ndarray], compress: bool
    ) -> "CompressedBlock":
        """Build a block: int8-quantize the payload when ``compress``
        (recording the measured round-trip error), else store raw."""
        if not compress:
            return cls(raw_bytes=raw_bytes, stored_bytes=raw_bytes)
        stored = raw_bytes * _INT8_RATIO + _SCALE_BYTES
        if payload is None:
            return cls(raw_bytes=raw_bytes, stored_bytes=stored)
        q, scale = quantize(payload)
        deq = np.asarray(dequantize(q, scale))
        err = float(np.max(np.abs(payload - deq))) if payload.size else 0.0
        return cls(
            raw_bytes=raw_bytes,
            stored_bytes=stored,
            codes=np.asarray(q),
            scale=float(scale),
            quant_error=err,
        )

    def decompress(self) -> Optional[np.ndarray]:
        """Dequantized payload, or None for a byte-count-only block."""
        if self.codes is None:
            return None
        return np.asarray(dequantize(self.codes, self.scale))


@dataclass
class _Transfer:
    key: Hashable
    kind: str  # "demote" | "promote"
    nbytes: float
    rate: float
    remaining: float


class PcieLink:
    """One FIFO channel: transfers queue and drain in order, each at its
    own rate (host transfers at PCIe speed, disk reads slower).  A tick
    is one unit of time; the front transfer drains first and any leftover
    time flows to the next — so a half-page transfer does not round up to
    a whole tick."""

    def __init__(self) -> None:
        self._queue: List[_Transfer] = []
        self.completed_transfers = 0
        self.moved_bytes = 0.0

    @property
    def queued_bytes(self) -> float:
        return sum(t.remaining for t in self._queue)

    @property
    def in_flight(self) -> int:
        return len(self._queue)

    def submit(self, tr: _Transfer) -> None:
        self._queue.append(tr)

    def send(
        self, key: Hashable, nbytes: float, rate: float,
        kind: str = "migrate",
    ) -> None:
        """Queue a transfer of ``nbytes`` at ``rate`` — the convenience
        entry for callers outside the tier store (e.g. a serving
        cluster's inter-replica network reusing this link model)."""
        self.submit(
            _Transfer(
                key=key, kind=kind, nbytes=nbytes, rate=rate,
                remaining=nbytes,
            )
        )

    def cancel(self, key: Hashable) -> Optional[_Transfer]:
        """Pull a queued transfer off the link (e.g. its owner died);
        returns it, or None if not queued."""
        for i, tr in enumerate(self._queue):
            if tr.key == key:
                return self._queue.pop(i)
        return None

    def tick(self) -> List[_Transfer]:
        """Advance one tick of link time; returns completed transfers."""
        done: List[_Transfer] = []
        t = 1.0
        while self._queue and t > 1e-12:
            tr = self._queue[0]
            if math.isinf(tr.rate):
                # infinite link rate = instantaneous transfer; the naive
                # arithmetic below would produce dt·rate = 0·inf = NaN
                # and wedge the transfer in flight forever
                tr.remaining = 0.0
            else:
                need = tr.remaining / tr.rate
                dt = min(t, need)
                tr.remaining -= dt * tr.rate
                t -= dt
            if tr.remaining <= 1e-9:
                self._queue.pop(0)
                self.completed_transfers += 1
                self.moved_bytes += tr.nbytes
                done.append(tr)
        return done


class TieredKVStore:
    """Demotion/promotion orchestrator over host + disk with one link.

    Keys are opaque hashables (the KV manager uses ``("req", rid, idx)``
    for request pages and ``("cache", token_key)`` for cold trie pages).
    A key the store does not track is, by definition, HBM-resident.
    """

    def __init__(
        self, config: TierConfig, ledger: Optional[MemoryLedger] = None
    ) -> None:
        self.config = config
        self.link = PcieLink()
        #: the class-stamped byte ledger — the single writer of resident
        #: byte tallies (``host_used_bytes`` and ``disk_spill_bytes`` are
        #: ledger queries below); a standalone store owns a private one
        self.ledger = ledger if ledger is not None else MemoryLedger()
        self.ledger.attach_tiers(self)
        self._blocks: Dict[Hashable, CompressedBlock] = {}
        self._state: Dict[Hashable, str] = {}
        # ---- cumulative traffic counters (the spill metrics)
        self.spilled_bytes = 0.0  # raw bytes demoted out of HBM
        self.wire_bytes = 0.0  # compressed bytes submitted to the link
        self.disk_read_bytes = 0.0  # disk→HBM promotions (stored bytes)
        self.demotions = 0
        self.promotions = 0
        self.discards = 0
        self.extractions = 0  # blocks handed to a migration (not garbage)
        self.max_quant_error = 0.0
        self.host_peak_bytes = 0.0  # high-water mark of host occupancy
        # ---- checkpoint traffic (DESIGN.md §11: a third byte stream,
        # distinct from spill and migration — durable snapshot writes
        # through the disk tier's buffered write path)
        self.checkpoint_bytes = 0.0  # compressed snapshot bytes written
        self.checkpoint_raw_bytes = 0.0  # pre-compression page bytes
        self.checkpoints = 0

    # ------------------------------------------------------------- queries
    def location(self, key: Hashable) -> str:
        """One of "hbm" / "to_host" / "host" / "disk" / "to_hbm"."""
        return self._state.get(key, "hbm")

    def tracked(self, key: Hashable) -> bool:
        return key in self._state

    def touch(self, key: Hashable) -> bool:
        """Read attempt: True iff the page is HBM-resident.  A tracked
        (demoted) page is unreadable until its promotion event fires."""
        return key not in self._state

    @property
    def host_used_bytes(self) -> float:
        """Stored bytes at rest in the host tier — a ledger query."""
        return self.ledger.tier_bytes(HOST)

    @property
    def disk_spill_bytes(self) -> float:
        """Host→disk eviction traffic (stored bytes) — the paper's spill
        metric, DERIVED from the ledger's host→disk flow rather than
        counted separately."""
        return self.ledger.flow(HOST, DISK)

    @property
    def tracked_raw_bytes(self) -> float:
        return sum(b.raw_bytes for b in self._blocks.values())

    @property
    def inflight_promotions(self) -> int:
        return sum(1 for s in self._state.values() if s == TO_HBM)

    @property
    def compression_ratio(self) -> float:
        """Raw bytes per stored/wire byte (≈2 for int8 over 2-byte KV)."""
        return self.spilled_bytes / self.wire_bytes if self.wire_bytes else 1.0

    def keys_in(self, *states: str) -> List[Hashable]:
        return [k for k, s in self._state.items() if s in states]

    # ----------------------------------------------------------- transitions
    def demote(
        self,
        key: Hashable,
        raw_bytes: float,
        payload: Optional[np.ndarray] = None,
        now: float = 0.0,
        repark: bool = False,
    ) -> None:
        """Begin moving an HBM page to the host tier.  The HBM copy is
        gone the moment this is called (the caller frees the physical
        page); the bytes are in flight until the link delivers them.

        ``repark=True`` marks a BOUNCE-BACK: a promotion that landed but
        could not be re-attached (no free page) returning to the host
        tier.  The page never became HBM-resident, so it is link traffic
        (``wire_bytes``) but NOT new spill — counting it as
        ``spilled_bytes`` would inflate a gated metric by a page per
        round trip under sustained free-page scarcity."""
        if key in self._state:
            raise ValueError(f"page {key!r} is already demoted ({self._state[key]})")
        block = CompressedBlock.compress(raw_bytes, payload, self.config.compress)
        block.last_use = now
        self.max_quant_error = max(self.max_quant_error, block.quant_error)
        self._blocks[key] = block
        self._state[key] = TO_HOST
        self.ledger.tier_demote(key, raw_bytes, block.stored_bytes)
        self.wire_bytes += block.stored_bytes
        if not repark:
            self.spilled_bytes += raw_bytes
            self.demotions += 1
        self.link.submit(
            _Transfer(
                key=key,
                kind="demote",
                nbytes=block.stored_bytes,
                rate=self.config.pcie_bytes_per_tick,
                remaining=block.stored_bytes,
            )
        )

    def promote(self, key: Hashable, now: float = 0.0) -> bool:
        """Begin moving a host/disk page back to HBM; returns False when
        the page is not promotable yet (still in flight, or unknown)."""
        state = self._state.get(key)
        if state not in (HOST, DISK):
            return False
        block = self._blocks[key]
        block.last_use = now
        rate = self.config.pcie_bytes_per_tick
        if state == DISK:
            self.disk_read_bytes += block.stored_bytes
            rate = min(rate, self.config.disk_bytes_per_tick)
        self._state[key] = TO_HBM
        self.ledger.tier_move(key, TO_HBM)
        self.promotions += 1
        self.link.submit(
            _Transfer(
                key=key,
                kind="promote",
                nbytes=block.stored_bytes,
                rate=rate,
                remaining=block.stored_bytes,
            )
        )
        return True

    def discard(self, key: Hashable) -> None:
        """Forget a tracked page (its owner finished): cancels any
        in-flight transfer and drops the host/disk copy."""
        if key not in self._state:
            return
        self.link.cancel(key)
        del self._state[key]
        del self._blocks[key]
        self.ledger.tier_drop(key)
        self.discards += 1

    def extract(self, key: Hashable) -> Optional[CompressedBlock]:
        """Remove a tracked block and hand its compressed payload to the
        caller — the live-migration extraction.  Unlike :meth:`discard`
        the bytes are NOT garbage: the caller ships them to another
        replica, so they leave this hierarchy intact (any in-flight
        transfer is cancelled; the block's codes ride along)."""
        if key not in self._state:
            return None
        self.link.cancel(key)
        del self._state[key]
        block = self._blocks.pop(key)
        self.ledger.tier_drop(key)
        self.extractions += 1
        return block

    # ---------------------------------------------------------------- clock
    def tick(self, now: float = 0.0) -> List[Tuple[str, Hashable, Any]]:
        """Advance one tick of link time.  Returns events:

        ``("resident", key, payload)`` — a promotion completed; the page
        is HBM-resident again and ``payload`` is the dequantized array
        (None when the demotion carried no payload).  Host arrivals that
        overflow host capacity cascade to disk here (LRU), which is where
        ``disk_spill_bytes`` accrues.
        """
        events: List[Tuple[str, Hashable, Any]] = []
        for tr in self.link.tick():
            if tr.key not in self._state:
                continue  # discarded while in flight (defensive)
            if tr.kind == "demote":
                self._state[tr.key] = HOST
                self._blocks[tr.key].last_use = now
                # ledger first: the overflow cascade below reads the
                # host tier's occupancy through it
                self.ledger.tier_move(tr.key, HOST)
                self._spill_host_overflow(tr.key)
                # sampled AFTER the overflow cascade: the high-water mark
                # must never claim the host tier held more than it can
                self.host_peak_bytes = max(
                    self.host_peak_bytes, self.host_used_bytes
                )
            else:
                block = self._blocks.pop(tr.key)
                del self._state[tr.key]
                self.ledger.tier_drop(tr.key)
                events.append(("resident", tr.key, block.decompress()))
        return events

    def _spill_host_overflow(self, arriving: Hashable) -> None:
        """Evict LRU host entries to disk until the host tier fits its
        capacity again.  The arriving block is the last resort victim
        (a host tier smaller than one block sends it straight to disk)."""
        while self.host_used_bytes > self.config.host_capacity_bytes:
            victims = [
                k
                for k, s in self._state.items()
                if s == HOST and k != arriving
            ]
            if not victims:
                victims = [arriving] if self._state.get(arriving) == HOST else []
            if not victims:
                break
            victim = min(victims, key=lambda k: self._blocks[k].last_use)
            self._state[victim] = DISK
            # the ledger's host→disk flow IS the spill metric
            self.ledger.tier_move(victim, DISK)
            if victim == arriving:
                break

    # ----------------------------------------------------------- checkpoints
    def note_checkpoint(self, raw_bytes: float, stored_bytes: float) -> None:
        """Account one KV snapshot written through the disk tier.

        Checkpoint writes ride the buffered disk-write path (cost bytes,
        not link time — same model as host→disk eviction), but they are a
        SEPARATE byte stream from spill: spill is pages falling out of
        the fast tiers under pressure, a checkpoint is a durable copy of
        pages that stay resident (DESIGN.md §11 keeps the two metrics
        from being conflated)."""
        self.checkpoint_raw_bytes += max(raw_bytes, 0.0)
        self.checkpoint_bytes += max(stored_bytes, 0.0)
        self.checkpoints += 1

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        """Machine-readable tier trajectory for ``BENCH_serve.json``."""
        return {
            "spilled_bytes": self.spilled_bytes,
            "wire_bytes": self.wire_bytes,
            "disk_spill_bytes": self.disk_spill_bytes,
            "disk_read_bytes": self.disk_read_bytes,
            "compression_ratio": self.compression_ratio,
            "host_used_bytes": self.host_used_bytes,
            "host_peak_bytes": self.host_peak_bytes,
            "host_capacity_bytes": self.config.host_capacity_bytes,
            "demotions": self.demotions,
            "promotions": self.promotions,
            "extractions": self.extractions,
            "transfers_completed": self.link.completed_transfers,
            "transfers_in_flight": self.link.in_flight,
            "max_quant_error": self.max_quant_error,
            "checkpoint_bytes": self.checkpoint_bytes,
            "checkpoint_raw_bytes": self.checkpoint_raw_bytes,
            "checkpoints": self.checkpoints,
        }
