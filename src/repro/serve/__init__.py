from .engine import EngineConfig, Request, ServingEngine
from .kv_cache import (
    CACHE_OWNER,
    PageBlockAllocator,
    PagedKVManager,
    PrefixCache,
    constant_state_bytes,
    kv_bytes_per_token,
)

__all__ = [
    "CACHE_OWNER",
    "EngineConfig",
    "Request",
    "ServingEngine",
    "PageBlockAllocator",
    "PagedKVManager",
    "PrefixCache",
    "constant_state_bytes",
    "kv_bytes_per_token",
]
