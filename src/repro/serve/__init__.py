from .cluster import ClusterConfig, ServingCluster
from .engine import EngineConfig, MigrationTicket, Request, ServingEngine
from .kv_cache import (
    CACHE_OWNER,
    DEMOTED,
    PageBlockAllocator,
    PagedKVManager,
    PrefixCache,
    constant_state_bytes,
    kv_bytes_per_token,
)
from .tiers import TierConfig, TieredKVStore

__all__ = [
    "CACHE_OWNER",
    "ClusterConfig",
    "DEMOTED",
    "EngineConfig",
    "MigrationTicket",
    "Request",
    "ServingCluster",
    "ServingEngine",
    "PageBlockAllocator",
    "PagedKVManager",
    "PrefixCache",
    "TierConfig",
    "TieredKVStore",
    "constant_state_bytes",
    "kv_bytes_per_token",
]
