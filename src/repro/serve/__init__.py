"""Public surface of the serving stack: engine, cluster, front door,
traffic generators, and the typed :class:`ServeReport` (DESIGN.md
§§2 and 8–9, 11; operator guide in docs/OPERATIONS.md)."""

from .cluster import ClusterConfig, ServingCluster
from .engine import (
    EngineConfig,
    MigrationTicket,
    PrecopySnapshot,
    Request,
    ServingEngine,
)
from .frontdoor import FrontDoor, FrontDoorConfig, TokenBucket
from .ledger import LedgerView, MemoryLedger, PageClass, PressurePlan
from .kv_cache import (
    CACHE_OWNER,
    DEMOTED,
    PageBlockAllocator,
    PagedKVManager,
    PrefixCache,
    constant_state_bytes,
    kv_bytes_per_token,
)
from .report import (
    COMPLETED,
    FAILED,
    LOST,
    RATE_LIMITED,
    SHED,
    UNFINISHED,
    LatencySummary,
    RequestOutcome,
    ServeReport,
    SloSpec,
)
from .server import Server
from .tiers import TierConfig, TieredKVStore
from .traffic import (
    Arrival,
    TenantProfile,
    bursty_trace,
    diurnal_trace,
    drive,
    poisson_trace,
)

__all__ = [
    "CACHE_OWNER",
    "COMPLETED",
    "Arrival",
    "ClusterConfig",
    "DEMOTED",
    "EngineConfig",
    "FAILED",
    "FrontDoor",
    "FrontDoorConfig",
    "LOST",
    "LatencySummary",
    "LedgerView",
    "MemoryLedger",
    "MigrationTicket",
    "PageClass",
    "PrecopySnapshot",
    "PageBlockAllocator",
    "PagedKVManager",
    "PrefixCache",
    "PressurePlan",
    "RATE_LIMITED",
    "Request",
    "RequestOutcome",
    "SHED",
    "Server",
    "ServeReport",
    "ServingCluster",
    "ServingEngine",
    "SloSpec",
    "TenantProfile",
    "TierConfig",
    "TieredKVStore",
    "TokenBucket",
    "UNFINISHED",
    "bursty_trace",
    "constant_state_bytes",
    "diurnal_trace",
    "drive",
    "kv_bytes_per_token",
    "poisson_trace",
]
