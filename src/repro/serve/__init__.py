from .engine import EngineConfig, Request, ServingEngine
from .kv_cache import (
    PageBlockAllocator,
    PagedKVManager,
    constant_state_bytes,
    kv_bytes_per_token,
)

__all__ = [
    "EngineConfig",
    "Request",
    "ServingEngine",
    "PageBlockAllocator",
    "PagedKVManager",
    "constant_state_bytes",
    "kv_bytes_per_token",
]
