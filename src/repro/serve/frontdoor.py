"""Admission control at the serving front door: token buckets + shedding.

The paper's spill-avoidance, moved to the door.  Every MURS mechanism
inside the engine (suspend, demote, tier) mitigates pressure from work
*already admitted*; under sustained overload the cheapest byte to manage
is the one never allocated.  :class:`FrontDoor` wraps anything
satisfying :class:`repro.serve.server.Server` and applies two gates to
each arrival, in order:

1. **per-tenant token bucket** — classic rate limiting (lazy refill:
   ``tokens = min(burst, tokens + elapsed * rate)``); a dry bucket
   rejects with :data:`~repro.serve.report.RATE_LIMITED`;
2. **projected-demand shedding** — the §III-B admission idea at cluster
   scope: each request's page-rounded *peak* bytes (prompt + declared
   max_new_tokens) are known at admission.  When total projected bytes
   (in-flight + inbound) cross ``pressure_threshold × capacity``, the
   scheduling policy's ``shed_order`` hook ranks tenant groups and the
   leading groups' arrivals are rejected (503,
   :data:`~repro.serve.report.SHED`) until enough of the projected
   demand belongs to shed groups to cover the overshoot.  MURS sheds the
   highest-usage-rate group first; priority sheds by 1/weight; fair
   sheds FIFO.

Every submission ends in exactly one outcome row — admitted requests
resolve through the wrapped server's report, rejected ones are recorded
here — which is the conservation property the tests check: nothing is
ever silently dropped.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.sched import BasePolicy, SchedulingPolicy
from repro.serve.engine import Request
from repro.serve.report import (
    RATE_LIMITED,
    SHED,
    RequestOutcome,
    ServeReport,
    SloSpec,
)

__all__ = ["FrontDoor", "FrontDoorConfig", "TokenBucket"]


@dataclass
class TokenBucket:
    """Lazily refilled token bucket: ``rate`` tokens per tick, capped at
    ``burst``.  Starts full."""

    rate: float
    burst: float
    tokens: Optional[float] = None
    last_tick: float = 0.0

    def __post_init__(self) -> None:
        if self.rate < 0 or self.burst <= 0:
            raise ValueError(
                f"need rate >= 0 and burst > 0, got {self.rate}/{self.burst}"
            )
        if self.tokens is None:
            self.tokens = self.burst

    def try_take(self, now: float, cost: float = 1.0) -> bool:
        """Lazy-refill then take ``cost`` tokens; False = rate-limited."""
        if now > self.last_tick:
            self.tokens = min(
                self.burst, self.tokens + (now - self.last_tick) * self.rate
            )
            self.last_tick = now
        if self.tokens + 1e-9 >= cost:
            self.tokens -= cost
            return True
        return False


@dataclass
class FrontDoorConfig:
    """Admission knobs: shed threshold, token buckets, SLOs (DESIGN.md
    §9; tuning table in docs/OPERATIONS.md)."""

    #: projected-demand fraction of capacity above which shedding starts;
    #: >= 1.0 still sheds (overcommit by declared peak), inf disables
    pressure_threshold: float = 0.95
    #: per-tenant token-bucket parameters as (rate_per_tick, burst)
    buckets: Dict[str, Tuple[float, float]] = field(default_factory=dict)
    #: bucket for tenants not listed in ``buckets``; None = unlimited
    default_bucket: Optional[Tuple[float, float]] = None
    #: per-tenant SLOs scored into the report's goodput
    slos: Dict[str, SloSpec] = field(default_factory=dict)
    default_slo: Optional[SloSpec] = None
    #: shed-order provider; None → the wrapped server's policy (falls
    #: back to BasePolicy FIFO when the server exposes none)
    policy: Optional[SchedulingPolicy] = None


class FrontDoor:
    """Admission layer in front of a :class:`~repro.serve.server.Server`.

    Satisfies the ``Server`` protocol itself, so traffic drivers and
    benchmarks are indifferent to whether a front door is present.
    """

    def __init__(
        self, server: Any, cfg: Optional[FrontDoorConfig] = None
    ) -> None:
        self.server = server
        self.cfg = cfg or FrontDoorConfig()
        self.policy: SchedulingPolicy = (
            self.cfg.policy
            if self.cfg.policy is not None
            else getattr(server, "policy", None) or BasePolicy()
        )
        self._buckets: Dict[str, TokenBucket] = {}
        self._group_seq: Dict[str, int] = {}  # tenant → first-seen order
        self._rejected: List[RequestOutcome] = []
        self.submitted = 0
        self.admitted = 0
        self.shed_count = 0
        self.rate_limited_count = 0
        self.shed_by_tenant: Dict[str, int] = {}

    # ----------------------------------------------------- Server protocol
    @property
    def tick(self) -> int:
        return self.server.tick

    @property
    def has_pending(self) -> bool:
        return self.server.has_pending

    def replica_stats(self) -> Dict[str, float]:
        return self.server.replica_stats()

    def step(self) -> None:
        self.server.step()

    # ------------------------------------------------------------ admission
    def _bucket_for(self, tenant: str) -> Optional[TokenBucket]:
        bucket = self._buckets.get(tenant)
        if bucket is None:
            params = self.cfg.buckets.get(tenant, self.cfg.default_bucket)
            if params is None:
                return None
            bucket = TokenBucket(rate=params[0], burst=params[1])
            self._buckets[tenant] = bucket
        return bucket

    def _reject(self, req: Request, outcome: str, reason: str) -> bool:
        now = self.server.tick
        self._rejected.append(
            RequestOutcome(
                request_id=req.request_id,
                tenant=req.tenant,
                outcome=outcome,
                submit_tick=now,
                finish_tick=now,
                reason=reason,
                model=req.model,
            )
        )
        if outcome == SHED:
            self.shed_count += 1
            self.shed_by_tenant[req.tenant] = (
                self.shed_by_tenant.get(req.tenant, 0) + 1
            )
        else:
            self.rate_limited_count += 1
        return False

    def _shed_groups(self, overshoot: float, tenant: str) -> Optional[set]:
        """The set of tenant groups whose NEW arrivals are rejected right
        now: a prefix of the policy's ``shed_order`` whose in-flight
        projected demand covers the overshoot.  Returns None when even
        shedding every known group cannot cover it (reject everyone)."""
        demand: Dict[str, float] = dict(
            getattr(self.server, "group_demand", dict)() or {}
        )
        demand.setdefault(tenant, 0.0)
        rates: Mapping[str, float] = self.policy.group_rates() or {}
        groups = sorted(demand, key=lambda g: self._group_seq.get(g, 1 << 30))
        stats = {
            g: {
                "rate": float(rates.get(g, 0.0)),
                "demand_bytes": demand[g],
                "arrival_seq": float(self._group_seq.get(g, 1 << 30)),
            }
            for g in groups
        }
        order = self.policy.shed_order(groups, stats)
        shed: set = set()
        freed = 0.0
        for g in order:
            if freed >= overshoot:
                break
            shed.add(g)
            freed += demand.get(g, 0.0)
        if freed < overshoot:
            return None
        return shed

    def submit(self, req: Request) -> bool:
        """Admit or reject one arrival; True = handed to the server."""
        self.submitted += 1
        self._group_seq.setdefault(req.tenant, len(self._group_seq))
        bucket = self._bucket_for(req.tenant)
        if bucket is not None and not bucket.try_take(float(self.server.tick)):
            return self._reject(req, RATE_LIMITED, "token bucket dry")
        stats = self.server.replica_stats()
        cap = float(stats.get("capacity_bytes", 0.0))
        if cap > 0.0:
            estimate = getattr(self.server, "estimate_request_bytes", None)
            inbound = estimate(req) if estimate is not None else 0.0
            projected = float(stats.get("projected_bytes", 0.0)) + inbound
            overshoot = projected - self.cfg.pressure_threshold * cap
            if overshoot > 0.0:
                shed = self._shed_groups(overshoot, req.tenant)
                if shed is None or req.tenant in shed:
                    return self._reject(
                        req, SHED, "projected demand over threshold"
                    )
        self.server.submit(req)
        self.admitted += 1
        return True

    # ------------------------------------------------------------------ run
    def run(self, max_ticks: int = 1000) -> ServeReport:
        """Drain the wrapped server, merge in the door's rejection rows,
        and score goodput against the configured SLOs."""
        report = self.server.run(max_ticks=max_ticks)
        report.outcomes = list(report.outcomes) + list(self._rejected)
        report.submitted = self.submitted
        report.refresh_summaries()
        report.apply_slo(self.cfg.slos, self.cfg.default_slo)
        report.extras["admitted"] = self.admitted
        report.extras["shed"] = self.shed_count
        report.extras["rate_limited"] = self.rate_limited_count
        report.extras["shed_by_tenant"] = dict(self.shed_by_tenant)
        report.extras["per_model"] = report.model_summary()
        return report
