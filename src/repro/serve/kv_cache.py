"""Paged KV-cache manager: page-granular HBM accounting per request.

The serving engine's memory substrate.  Pages are fixed-size token spans
(``page_tokens``); a request holds ⌈len/page_tokens⌉ pages per layer-group.
The manager tracks the byte-exact HBM footprint of every request — this is
what the MURS sampler reads as the request's *live* bytes, and what decides
spill-to-host (offload) and OOM.

Byte model per architecture (the MURS memory-usage classification of
DESIGN.md §4 falls out of these):
    full attention  : 2 · n_kv · hd · bytes  per token per attn layer  (linear)
    MLA             : (kv_lora + rope)·bytes per token per layer       (linear,
                      ~57× shallower slope than per-head KV at dsv2 dims)
    sliding window  : bounded by window  (constant once past the window)
    mamba           : fixed state bytes  (constant)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.configs.base import ArchConfig


def _block_counts(cfg: ArchConfig) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for b in (
        list(cfg.block_pattern) * cfg.resolved_pattern_repeats
        + list(cfg.suffix_blocks)
    ):
        counts[b] = counts.get(b, 0) + 1
    return counts


def kv_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    """Marginal HBM bytes per generated token (the memory-usage *rate*)."""
    counts = _block_counts(cfg)
    per_tok = 0.0
    if cfg.mla is not None:
        lat = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        per_tok += (counts.get("attn", 0) + counts.get("local_attn", 0)) * lat * dtype_bytes
    else:
        kv = 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
        per_tok += counts.get("attn", 0) * kv
        per_tok += counts.get("shared_attn", 0) * kv
        # local layers stop growing once past the window → marginal 0 there
    return per_tok


def constant_state_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    """Sequence-length-independent state (mamba states, local windows)."""
    counts = _block_counts(cfg)
    total = 0.0
    if cfg.ssm is not None and counts.get("mamba"):
        ssm = cfg.ssm
        di = ssm.d_inner(cfg.d_model)
        conv = (ssm.d_conv - 1) * (di + 2 * ssm.d_state) * dtype_bytes
        state = ssm.n_heads(cfg.d_model) * ssm.head_dim * ssm.d_state * 4
        total += counts["mamba"] * (conv + state)
    if cfg.mla is None and counts.get("local_attn"):
        kv = 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
        total += counts["local_attn"] * kv * cfg.sliding_window
    return total


@dataclass
class PagedKVManager:
    """Page-pool accounting for a shared HBM region."""

    capacity_bytes: float
    page_tokens: int = 16
    _pages: Dict[str, int] = field(default_factory=dict)  # request → pages
    _page_bytes: Dict[str, float] = field(default_factory=dict)
    _state_bytes: Dict[str, float] = field(default_factory=dict)
    offloaded_bytes: float = 0.0
    offload_events: int = 0

    # ------------------------------------------------------------ requests
    def register(self, request_id: str, cfg: ArchConfig) -> None:
        self._pages[request_id] = 0
        self._page_bytes[request_id] = (
            kv_bytes_per_token(cfg) * self.page_tokens
        )
        self._state_bytes[request_id] = constant_state_bytes(cfg)

    def grow_to(self, request_id: str, n_tokens: int) -> float:
        """Ensure pages cover ``n_tokens``; returns newly allocated bytes."""
        need = (n_tokens + self.page_tokens - 1) // self.page_tokens
        have = self._pages.get(request_id, 0)
        if need <= have:
            return 0.0
        self._pages[request_id] = need
        return (need - have) * self._page_bytes[request_id]

    def release(self, request_id: str) -> float:
        pages = self._pages.pop(request_id, 0)
        pb = self._page_bytes.pop(request_id, 0.0)
        sb = self._state_bytes.pop(request_id, 0.0)
        return pages * pb + sb

    def request_bytes(self, request_id: str) -> float:
        return (
            self._pages.get(request_id, 0)
            * self._page_bytes.get(request_id, 0.0)
            + self._state_bytes.get(request_id, 0.0)
        )

    @property
    def used_bytes(self) -> float:
        return sum(
            self._pages[r] * self._page_bytes[r] + self._state_bytes[r]
            for r in self._pages
        )

    @property
    def used_fraction(self) -> float:
        return self.used_bytes / self.capacity_bytes if self.capacity_bytes else 1.0

    def offload(self, request_id: str) -> float:
        """Spill a request's pages to host DRAM (the TPU 'spill')."""
        freed = self.release(request_id)
        self.offloaded_bytes += freed
        self.offload_events += 1
        return freed
