"""Paged KV-cache manager: page-granular HBM block allocation per request.

The serving engine's memory substrate.  Pages are fixed-size token spans
(``page_tokens``); a request holds ⌈len/page_tokens⌉ pages per layer-group,
drawn from a shared fixed-size HBM pool by :class:`PageBlockAllocator` —
a free list plus a per-request PAGE TABLE.  The same tables feed the Pallas
``paged_decode`` kernel (:mod:`repro.kernels.paged_decode`): the scheduler's
byte accounting and the attention kernel's indirection consume one memory
model, instead of bytes-only bookkeeping on one side and dense caches on
the other.

The manager tracks the byte-exact HBM footprint of every request — this is
what the MURS sampler reads as the request's *live* bytes, and what decides
spill-to-host (offload) and OOM.  Pages past pool capacity are OVERFLOW
pages (ids ≥ ``n_pages``): the pool is overcommitted, ``used_fraction``
exceeds 1.0, and the runtime's reactive path (offload / fail) fires.

Byte model per architecture (the MURS memory-usage classification of
DESIGN.md §4 falls out of these):
    full attention  : 2 · n_kv · hd · bytes  per token per attn layer  (linear)
    MLA             : (kv_lora + rope)·bytes per token per layer       (linear,
                      ~57× shallower slope than per-head KV at dsv2 dims)
    sliding window  : bounded by window  (constant once past the window)
    mamba           : fixed state bytes  (constant)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig

__all__ = [
    "PageBlockAllocator",
    "PagedKVManager",
    "constant_state_bytes",
    "kv_bytes_per_token",
]


def _block_counts(cfg: ArchConfig) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for b in (
        list(cfg.block_pattern) * cfg.resolved_pattern_repeats
        + list(cfg.suffix_blocks)
    ):
        counts[b] = counts.get(b, 0) + 1
    return counts


def kv_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    """Marginal HBM bytes per generated token (the memory-usage *rate*)."""
    counts = _block_counts(cfg)
    per_tok = 0.0
    if cfg.mla is not None:
        lat = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        per_tok += (counts.get("attn", 0) + counts.get("local_attn", 0)) * lat * dtype_bytes
    else:
        kv = 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
        per_tok += counts.get("attn", 0) * kv
        per_tok += counts.get("shared_attn", 0) * kv
        # local layers stop growing once past the window → marginal 0 there
    return per_tok


def constant_state_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    """Sequence-length-independent state (mamba states, local windows)."""
    counts = _block_counts(cfg)
    total = 0.0
    if cfg.ssm is not None and counts.get("mamba"):
        ssm = cfg.ssm
        di = ssm.d_inner(cfg.d_model)
        conv = (ssm.d_conv - 1) * (di + 2 * ssm.d_state) * dtype_bytes
        state = ssm.n_heads(cfg.d_model) * ssm.head_dim * ssm.d_state * 4
        total += counts["mamba"] * (conv + state)
    if cfg.mla is None and counts.get("local_attn"):
        kv = 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
        total += counts["local_attn"] * kv * cfg.sliding_window
    return total


class PageBlockAllocator:
    """Fixed-size HBM page pool: free list + per-owner page tables.

    ``n_pages`` physical pages exist; allocation pops the free list (lowest
    id first on a fresh pool, then LIFO reuse for locality).  When the free
    list is empty, allocation hands out OVERFLOW page ids (≥ ``n_pages``) —
    the pool is overcommitted; callers detect this via
    :attr:`overflow_pages` / byte accounting and react (offload, fail,
    or — under a proactive policy — never get here).
    """

    def __init__(self, n_pages: int) -> None:
        if n_pages < 0:
            raise ValueError(f"n_pages must be >= 0, got {n_pages}")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._free_overflow: List[int] = []  # recycled overflow ids
        self._tables: Dict[str, List[int]] = {}
        self._next_overflow = n_pages
        self.overflow_pages = 0  # overflow pages currently held

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return sum(len(t) for t in self._tables.values())

    @property
    def page_id_bound(self) -> int:
        """Exclusive upper bound on every page id ever handed out — size
        pool-indexed arrays (k/v pools) with this, not
        ``n_pages + overflow_pages`` (overflow ids are recycled, but the
        high-water mark can exceed the current count)."""
        return self._next_overflow

    def table(self, owner: str) -> Tuple[int, ...]:
        return tuple(self._tables.get(owner, ()))

    def pages_held(self, owner: str) -> int:
        return len(self._tables.get(owner, ()))

    def table_array(
        self, owners: Sequence[str], max_pages: Optional[int] = None
    ) -> np.ndarray:
        """Kernel-ready page tables: int32 ``[len(owners), max_pages]``.

        Rows are padded with page 0 — the paged_decode kernel masks tokens
        past ``seq_lens``, so padding entries cost a wasted DMA, never a
        wrong value.
        """
        tables = [self._tables.get(o, []) for o in owners]
        width = max_pages or max((len(t) for t in tables), default=1) or 1
        out = np.zeros((len(owners), width), np.int32)
        for i, t in enumerate(tables):
            if len(t) > width:
                raise ValueError(
                    f"owner {owners[i]!r} holds {len(t)} pages > max_pages={width}"
                )
            out[i, : len(t)] = t
        return out

    # ---------------------------------------------------------- allocation
    def grow_to(self, owner: str, n_pages_needed: int) -> int:
        """Extend ``owner``'s table to ``n_pages_needed``; returns #new pages."""
        table = self._tables.setdefault(owner, [])
        new = n_pages_needed - len(table)
        if new <= 0:
            return 0
        for _ in range(new):
            if self._free:
                table.append(self._free.pop())
            elif self._free_overflow:
                table.append(self._free_overflow.pop())
                self.overflow_pages += 1
            else:
                table.append(self._next_overflow)
                self._next_overflow += 1
                self.overflow_pages += 1
        return new

    def free(self, owner: str) -> int:
        """Release every page ``owner`` holds; returns the page count."""
        table = self._tables.pop(owner, [])
        for pid in table:
            if pid < self.n_pages:
                self._free.append(pid)
            else:
                self._free_overflow.append(pid)
                self.overflow_pages -= 1
        return len(table)

    # ------------------------------------------------------------ residency
    def resident(self, owner: str) -> bool:
        """True iff every page of ``owner`` is a physical HBM page.

        A request holding overflow pages cannot be decoded — those tokens
        live in host memory, not HBM — until :meth:`reclaim` pages them
        back in after something else frees physical pages.
        """
        return all(pid < self.n_pages for pid in self._tables.get(owner, ()))

    def reclaim(self) -> int:
        """Page overflow entries back into freed physical pages (the DMA
        that resolves overcommit); returns the number of pages moved."""
        moved = 0
        for table in self._tables.values():
            for i, pid in enumerate(table):
                if pid >= self.n_pages and self._free:
                    self._free_overflow.append(pid)
                    table[i] = self._free.pop()
                    self.overflow_pages -= 1
                    moved += 1
        return moved


@dataclass
class PagedKVManager:
    """Byte accounting + page-table allocation for a shared HBM region.

    The page pool is sized lazily on the first :meth:`register` (the page
    byte size depends on the architecture): ``n_pages = ⌊capacity /
    page_bytes⌋``.  Architectures with zero marginal KV bytes (mamba:
    constant state) hold no pages at all.
    """

    capacity_bytes: float
    page_tokens: int = 16
    _page_bytes: Dict[str, float] = field(default_factory=dict)
    _state_bytes: Dict[str, float] = field(default_factory=dict)
    _alloc: Optional[PageBlockAllocator] = None
    offloaded_bytes: float = 0.0
    offload_events: int = 0

    # ------------------------------------------------------------ requests
    def register(self, request_id: str, cfg: ArchConfig) -> None:
        page_bytes = kv_bytes_per_token(cfg) * self.page_tokens
        self._page_bytes[request_id] = page_bytes
        self._state_bytes[request_id] = constant_state_bytes(cfg)
        if self._alloc is None and page_bytes > 0:
            self._alloc = PageBlockAllocator(
                int(self.capacity_bytes // page_bytes)
            )
        if self._alloc is not None and page_bytes > 0:
            self._alloc.grow_to(request_id, 0)  # materialize an empty table

    def grow_to(self, request_id: str, n_tokens: int) -> float:
        """Ensure pages cover ``n_tokens``; returns newly allocated bytes."""
        page_bytes = self._page_bytes.get(request_id, 0.0)
        if page_bytes <= 0.0 or self._alloc is None:
            return 0.0
        need = (n_tokens + self.page_tokens - 1) // self.page_tokens
        return self._alloc.grow_to(request_id, need) * page_bytes

    def bytes_for(self, cfg: ArchConfig, n_tokens: int) -> float:
        """Page-rounded HBM bytes ``n_tokens`` would occupy — an
        arithmetic admission probe that allocates nothing."""
        pages = (n_tokens + self.page_tokens - 1) // self.page_tokens
        return pages * kv_bytes_per_token(cfg) * self.page_tokens

    def release(self, request_id: str) -> float:
        pages = self._alloc.free(request_id) if self._alloc is not None else 0
        pb = self._page_bytes.pop(request_id, 0.0)
        sb = self._state_bytes.pop(request_id, 0.0)
        return pages * pb + sb

    # ------------------------------------------------------------- queries
    def page_table(self, request_id: str) -> Tuple[int, ...]:
        """The request's page table — the paged_decode kernel's indirection."""
        if self._alloc is None:
            return ()
        return self._alloc.table(request_id)

    def table_array(
        self, request_ids: Sequence[str], max_pages: Optional[int] = None
    ) -> np.ndarray:
        """Kernel-ready ``[B, max_pages]`` int32 page tables (padded)."""
        if self._alloc is None:
            return np.zeros((len(request_ids), max_pages or 1), np.int32)
        return self._alloc.table_array(request_ids, max_pages)

    def request_pages(self, request_id: str) -> int:
        return self._alloc.pages_held(request_id) if self._alloc else 0

    def resident(self, request_id: str) -> bool:
        """True iff the request's KV is fully HBM-resident (decodable)."""
        return self._alloc.resident(request_id) if self._alloc else True

    def reclaim(self) -> int:
        """Page overflow entries back in; returns pages moved."""
        return self._alloc.reclaim() if self._alloc is not None else 0

    def request_bytes(self, request_id: str) -> float:
        return (
            self.request_pages(request_id)
            * self._page_bytes.get(request_id, 0.0)
            + self._state_bytes.get(request_id, 0.0)
        )

    @property
    def n_pages(self) -> int:
        """Physical pages in the pool (0 until the first register sizes it)."""
        return self._alloc.n_pages if self._alloc is not None else 0

    @property
    def free_pages(self) -> int:
        return self._alloc.free_pages if self._alloc is not None else 0

    @property
    def overflow_pages(self) -> int:
        return self._alloc.overflow_pages if self._alloc is not None else 0

    @property
    def page_id_bound(self) -> int:
        """Exclusive upper bound on every page id ever handed out."""
        return self._alloc.page_id_bound if self._alloc is not None else 0

    @property
    def used_bytes(self) -> float:
        return sum(
            self.request_pages(r) * self._page_bytes[r] + self._state_bytes[r]
            for r in self._page_bytes
        )

    @property
    def used_fraction(self) -> float:
        return self.used_bytes / self.capacity_bytes if self.capacity_bytes else 1.0

    def offload(self, request_id: str) -> float:
        """Spill a request's pages to host DRAM (the TPU 'spill')."""
        freed = self.release(request_id)
        self.offloaded_bytes += freed
        self.offload_events += 1
        return freed
