"""Paged KV-cache manager: page-granular HBM block allocation per request.

The serving engine's memory substrate.  Pages are fixed-size token spans
(``page_tokens``); a request holds ⌈len/page_tokens⌉ pages per layer-group,
drawn from a shared fixed-size HBM pool by :class:`PageBlockAllocator` —
a free list plus a per-request PAGE TABLE.  The same tables feed the Pallas
``paged_decode`` kernel (:mod:`repro.kernels.paged_decode`): the scheduler's
byte accounting and the attention kernel's indirection consume one memory
model, instead of bytes-only bookkeeping on one side and dense caches on
the other.

Pages are REFCOUNTED: more than one page table may point at the same
physical page.  :class:`PrefixCache` exploits this — a token trie at page
granularity maps prompt prefixes onto already-materialized pages, so a
hundred tenants sharing one system prompt pin ONE copy of its KV, not a
hundred.  Writes into a shared page go through copy-on-write
(:meth:`PageBlockAllocator.ensure_private`); cold cached prefixes are
evicted under pressure by LRU crossed with the scheduling policy's
``cache_pressure`` hint (MURS: low-usage-rate tenants' cold prefixes go
first).  Fewer live bytes is the same lever the MURS scheduler pulls —
dedup attacks the pressure at its source (DESIGN.md §6).

The manager tracks the byte-exact HBM footprint of every request — this is
what the MURS sampler reads as the request's *live* bytes, and what decides
spill-to-host and OOM.  A shared page is charged fractionally (1/refcount)
to each holder so the per-owner shares sum to the physical total.  Pages
past pool capacity are OVERFLOW pages (ids ≥ ``n_pages``): the pool is
overcommitted, ``used_fraction`` exceeds 1.0, and the runtime's reactive
path fires.

Below HBM sits the TIER HIERARCHY (:mod:`repro.serve.tiers`): pages demote
INDIVIDUALLY — a private page's entry becomes the :data:`DEMOTED` sentinel
(position preserved) while its bytes move, int8-compressed, over a modeled
PCIe link into a host tier with real capacity, overflowing to a disk tier
whose traffic is the paper's "data spilling" metric.  A request with
demoted pages is simply non-resident (it stalls only if actually
scheduled); promotion is likewise asynchronous and page-granular.  Cold
cached trie pages demote too: the node survives as a HOST node — the
prefix stays known, a later match promotes it back instead of recomputing
the prefill.

Byte model per architecture (the MURS memory-usage classification of
DESIGN.md §4 falls out of these):
    full attention  : 2 · n_kv · hd · bytes  per token per attn layer  (linear)
    MLA             : (kv_lora + rope)·bytes per token per layer       (linear,
                      ~57× shallower slope than per-head KV at dsv2 dims)
    sliding window  : bounded by window  (constant once past the window)
    mamba           : fixed state bytes  (constant)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
)

import numpy as np

from repro.configs.base import ArchConfig
from repro.serve.ledger import CACHE_OWNER, MemoryLedger, PageClass

if TYPE_CHECKING:  # deferred: keeps this module import-light (numpy only)
    from repro.serve.tiers import TierConfig, TieredKVStore

__all__ = [
    "CACHE_OWNER",  # re-exported from repro.serve.ledger (defined there)
    "DEMOTED",
    "PageBlockAllocator",
    "PagedKVManager",
    "PrefixCache",
    "constant_state_bytes",
    "kv_bytes_per_token",
]

#: page-table sentinel for a page demoted to the tier hierarchy (host or
#: disk): the entry keeps its position — the tokens still exist, just not
#: in HBM — and :meth:`PageBlockAllocator.swap_in` re-materializes it
DEMOTED = -1


def kv_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    """Marginal HBM bytes per generated token (the memory-usage *rate*).

    Thin wrapper over :meth:`ArchConfig.kv_bytes_per_token` — the byte
    model lives on the config so layers that never import serve (cluster
    routing, policy scoring, benchmarks) read the same numbers."""
    return cfg.kv_bytes_per_token(dtype_bytes)


def constant_state_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    """Sequence-length-independent state (mamba states, local windows).

    Thin wrapper over :meth:`ArchConfig.constant_state_bytes`."""
    return cfg.constant_state_bytes(dtype_bytes)


class PageBlockAllocator:
    """Fixed-size HBM page pool: free list + refcounted per-owner tables.

    ``n_pages`` physical pages exist; allocation pops the free list (lowest
    id first on a fresh pool, then LIFO reuse for locality).  A page may be
    held by MULTIPLE owners (prefix sharing): :meth:`share` bumps its
    refcount, and the page returns to the free list only when the last
    holder releases it.  :meth:`ensure_private` is the copy-on-write
    primitive — an owner about to append into a shared page gets a private
    replacement; the shared page is never mutated.

    When the free list is empty, allocation hands out OVERFLOW page ids
    (≥ ``n_pages``) — the pool is overcommitted; callers detect this via
    :attr:`overflow_pages` / byte accounting and react (offload, fail,
    evict cached prefixes, or — under a proactive policy — never get here).
    Overflow pages are never shared: only HBM-resident pages are cacheable.
    """

    def __init__(
        self, n_pages: int, ledger: Optional[MemoryLedger] = None
    ) -> None:
        if n_pages < 0:
            raise ValueError(f"n_pages must be >= 0, got {n_pages}")
        self.n_pages = n_pages
        #: class-stamped byte ledger (single writer of byte tallies);
        #: every holder-set mutation below fans out through :meth:`_note`
        self.ledger = ledger
        if ledger is not None:
            ledger.attach_allocator(self)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._free_overflow: List[int] = []  # recycled overflow ids
        self._tables: Dict[str, List[int]] = {}
        self._ref: Dict[int, int] = {}  # page id → number of holders
        #: page id → owners holding it (reverse of the tables, DEMOTED
        #: entries excluded) — a refcount change on a SHARED page changes
        #: every co-holder's fractional share, so attribution updates
        #: must fan out to all of them
        self._holders: Dict[int, List[str]] = {}
        #: owners whose attributed share (:meth:`owner_share`) changed
        #: since the last :meth:`drain_dirty` — the engine's incremental
        #: pool-accounting sync reads and clears this instead of
        #: recomputing every live owner per tick
        self.dirty: set = set()
        self._next_overflow = n_pages
        self.overflow_pages = 0  # overflow pages currently held
        self.cow_events = 0  # copy-on-write page splits

    def drain_dirty(self) -> set:
        """Return-and-clear the owners whose page set changed since the
        last drain (the pool-sync dirty set)."""
        out = self.dirty
        self.dirty = set()
        return out

    def _note(self, pid: int) -> None:
        """Propagate a holder-set change on ``pid`` into the ledger (the
        ledger re-derives the page's class and fractional attribution)."""
        if self.ledger is not None:
            self.ledger.page_update(pid, self._holders.get(pid, ()))

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Distinct pages currently held (a shared page counts once)."""
        return len(self._ref)

    @property
    def page_id_bound(self) -> int:
        """Exclusive upper bound on every page id ever handed out — size
        pool-indexed arrays (k/v pools) with this, not
        ``n_pages + overflow_pages`` (overflow ids are recycled, but the
        high-water mark can exceed the current count)."""
        return self._next_overflow

    def table(self, owner: str) -> Tuple[int, ...]:
        return tuple(self._tables.get(owner, ()))

    def pages_held(self, owner: str) -> int:
        return len(self._tables.get(owner, ()))

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    def owner_share(self, owner: str) -> float:
        """Fractionally attributed page count: a page shared by k holders
        charges 1/k to each, so shares sum to the physical page count.
        Demoted entries charge nothing — those bytes live in a lower
        tier, not in this pool."""
        return sum(
            1.0 / self._ref[pid]
            for pid in self._tables.get(owner, ())
            if pid != DEMOTED
        )

    def table_array(
        self, owners: Sequence[str], max_pages: Optional[int] = None
    ) -> np.ndarray:
        """Kernel-ready page tables: int32 ``[len(owners), max_pages]``.

        Rows are padded with page 0 — the paged_decode kernel masks tokens
        past ``seq_lens``, so padding entries cost a wasted DMA, never a
        wrong value.
        """
        tables = [self._tables.get(o, []) for o in owners]
        width = max_pages or max((len(t) for t in tables), default=1) or 1
        out = np.zeros((len(owners), width), np.int32)
        for i, t in enumerate(tables):
            if len(t) > width:
                raise ValueError(
                    f"owner {owners[i]!r} holds {len(t)} pages > max_pages={width}"
                )
            # demoted entries render as page 0 like padding: the kernel
            # must never be launched over a non-resident table (the engine
            # stalls such requests), so the value is a mask-safe filler
            out[i, : len(t)] = [max(pid, 0) for pid in t]
        return out

    # ---------------------------------------------------------- allocation
    def _alloc_page(self, owner: str) -> int:
        if self._free:
            pid = self._free.pop()
        elif self._free_overflow:
            pid = self._free_overflow.pop()
            self.overflow_pages += 1
        else:
            pid = self._next_overflow
            self._next_overflow += 1
            self.overflow_pages += 1
        self._ref[pid] = 1
        self._holders[pid] = [owner]
        self.dirty.add(owner)
        self._note(pid)
        return pid

    def _decref(self, pid: int, owner: str) -> bool:
        """Drop ``owner``'s reference; returns True iff the page became
        free.  Remaining co-holders' fractional shares grow, so they are
        marked dirty too."""
        holders = self._holders.get(pid)
        if holders is not None:
            try:
                holders.remove(owner)
            except ValueError:
                pass
        self.dirty.add(owner)
        n = self._ref[pid] - 1
        if n > 0:
            self._ref[pid] = n
            if holders:
                self.dirty.update(holders)
            self._note(pid)
            return False
        del self._ref[pid]
        self._holders.pop(pid, None)
        if pid < self.n_pages:
            self._free.append(pid)
        else:
            self._free_overflow.append(pid)
            self.overflow_pages -= 1
        self._note(pid)
        return True

    def grow_to(self, owner: str, n_pages_needed: int) -> int:
        """Extend ``owner``'s table to ``n_pages_needed``; returns #new pages."""
        table = self._tables.setdefault(owner, [])
        new = n_pages_needed - len(table)
        if new <= 0:
            return 0
        for _ in range(new):
            table.append(self._alloc_page(owner))
        return new

    def share(self, owner: str, pages: Sequence[int]) -> None:
        """Append existing live pages to ``owner``'s table (refcount +1 each).

        This is the prefix-sharing primitive: the pages stay owned by every
        current holder; ``owner`` must treat them as read-only until
        :meth:`ensure_private` splits them.
        """
        table = self._tables.setdefault(owner, [])
        for pid in pages:
            if pid not in self._ref:
                raise ValueError(f"page {pid} is not live; cannot share")
            if pid >= self.n_pages:
                raise ValueError(f"overflow page {pid} cannot be shared")
            self._ref[pid] += 1
            holders = self._holders.setdefault(pid, [])
            self.dirty.update(holders)  # their 1/k share just shrank
            holders.append(owner)
            self.dirty.add(owner)
            table.append(pid)
            self._note(pid)

    def ensure_private(self, owner: str, index: int) -> int:
        """Copy-on-write: make ``owner``'s page at table ``index`` private.

        If the page is shared (refcount > 1) the owner receives a freshly
        allocated replacement (the copy) and drops its reference to the
        shared original — which is NEVER mutated.  Returns the (possibly
        new) page id.
        """
        table = self._tables[owner]
        pid = table[index]
        if self._ref.get(pid, 0) <= 1:
            return pid
        new = self._alloc_page(owner)
        table[index] = new
        self._ref[pid] -= 1
        holders = self._holders.get(pid)
        if holders is not None:
            try:
                holders.remove(owner)
            except ValueError:
                pass
            self.dirty.update(holders)  # co-holders' shares grew
        self._note(pid)
        self.cow_events += 1
        return new

    def free(self, owner: str) -> int:
        """Release every page reference ``owner`` holds; returns the count
        of HBM table entries released (shared pages stay live for others;
        demoted entries hold no HBM page — the caller must discard their
        tier copies)."""
        table = self._tables.pop(owner, [])
        released = 0
        for pid in table:
            if pid == DEMOTED:
                continue
            self._decref(pid, owner)
            released += 1
        return released

    # ------------------------------------------------------------- demotion
    def swap_out(self, owner: str, index: int) -> int:
        """Demote ``owner``'s page at table ``index`` out of HBM: the
        physical page returns to the free list and the entry becomes the
        :data:`DEMOTED` sentinel (position preserved).  Only PRIVATE
        (refcount 1) physical pages are demotable — a shared page is
        pinned by its other holders, and an overflow id is the legacy
        overcommit representation, not a resident page.  Returns the
        freed page id."""
        table = self._tables[owner]
        pid = table[index]
        if pid == DEMOTED:
            raise ValueError(f"page {owner!r}[{index}] is already demoted")
        if pid >= self.n_pages:
            raise ValueError(f"overflow page {pid} cannot be demoted")
        if self._ref.get(pid, 0) != 1:
            raise ValueError(f"shared page {pid} cannot be demoted")
        self._decref(pid, owner)
        table[index] = DEMOTED
        return pid

    def swap_in(self, owner: str, index: int) -> int:
        """Re-materialize a demoted entry: allocates a page (overflow id
        under a drained pool — the normal overcommit machinery then
        applies) and writes it back into the table slot."""
        table = self._tables[owner]
        if table[index] != DEMOTED:
            raise ValueError(f"page {owner!r}[{index}] is not demoted")
        pid = self._alloc_page(owner)
        table[index] = pid
        return pid

    def demoted_indices(self, owner: str) -> Tuple[int, ...]:
        return tuple(
            i
            for i, pid in enumerate(self._tables.get(owner, ()))
            if pid == DEMOTED
        )

    def take_free(self, owner: str) -> Optional[int]:
        """Append one FREE-LIST page to ``owner``'s table, or None when
        the free list is empty (never hands out overflow ids) — the
        promotion path for cache-held pages, which must be physical."""
        if not self._free:
            return None
        pid = self._free.pop()
        self._ref[pid] = 1
        self._holders[pid] = [owner]
        self.dirty.add(owner)
        self._tables.setdefault(owner, []).append(pid)
        self._note(pid)
        return pid

    def release_pages(self, owner: str, pages: Sequence[int]) -> None:
        """Release specific page references from ``owner``'s table (one
        table entry per listed id) — the prefix cache's eviction path."""
        table = self._tables.get(owner, [])
        for pid in pages:
            table.remove(pid)
            self._decref(pid, owner)

    # ------------------------------------------------------------ residency
    def resident(self, owner: str) -> bool:
        """True iff every page of ``owner`` is a physical HBM page.

        A request holding overflow pages (legacy overcommit) or DEMOTED
        entries (tiered out to host/disk) cannot be decoded — those
        tokens are not in HBM — until :meth:`reclaim` / :meth:`swap_in`
        bring them back.
        """
        return all(
            0 <= pid < self.n_pages for pid in self._tables.get(owner, ())
        )

    def reclaim(self) -> int:
        """Page overflow entries back into freed physical pages (the DMA
        that resolves overcommit); returns the number of pages moved."""
        moved = 0
        for owner, table in self._tables.items():
            for i, pid in enumerate(table):
                if pid >= self.n_pages and self._free:
                    # overflow pages are never shared → refcount is 1
                    self._free_overflow.append(pid)
                    del self._ref[pid]
                    self._holders.pop(pid, None)
                    self._note(pid)
                    new = self._free.pop()
                    self._ref[new] = 1
                    self._holders[new] = [owner]
                    self.dirty.add(owner)
                    table[i] = new
                    self._note(new)
                    self.overflow_pages -= 1
                    moved += 1
        return moved


@dataclass
class _PrefixNode:
    """One cached page: the trie node for a (page-aligned) token prefix."""

    page_id: int
    n_tokens: int  # valid tokens in this page (< page_tokens ⇒ terminal)
    group: str  # tenant that materialized it (cache_pressure key)
    snap_key: Tuple[int, ...]  # engine-side KV snapshot this page came from
    last_use: float
    #: True when the page was demoted to the tier hierarchy: the node
    #: survives (the prefix is still KNOWN) but holds no HBM page
    #: (``page_id`` is :data:`DEMOTED`); a match stops at it and triggers
    #: promotion instead of sharing
    host: bool = False


class PrefixCache:
    """Token trie over the page pool: prompt prefix → shared pages.

    Nodes live at page-granular depths — the node for ``tokens[:d·P]``
    records the physical page holding tokens ``[(d−1)·P, d·P)`` of that
    prefix.  A cached prompt's final PARTIAL page is stored as a terminal
    node keyed by the full prompt, so an exact-prompt repeat shares every
    page (its first append then triggers copy-on-write).  The cache holds
    one allocator reference per node (owner :data:`CACHE_OWNER`); a node
    whose page refcount is 1 is COLD — no live request uses it — and is
    the only kind eviction may touch.

    Eviction order is LRU crossed with the scheduling policy's
    ``cache_pressure(group)`` hint: highest pressure first, then least
    recently used, deepest leaf first; inner nodes are never evicted
    before their descendants (the trie stays connected).
    """

    def __init__(self, alloc: PageBlockAllocator, page_tokens: int) -> None:
        self.alloc = alloc
        self.page_tokens = page_tokens
        self._nodes: Dict[Tuple[int, ...], _PrefixNode] = {}
        self._children: Dict[Tuple[int, ...], int] = {}  # key → child nodes
        # parent full-page key → terminal (partial-page) keys beneath it
        self._terminals: Dict[Tuple[int, ...], List[Tuple[int, ...]]] = {}
        #: called with a node key when a match walks into a HOST node —
        #: the manager promotes the page so the NEXT match can share it
        self.promote_cb: Optional[Callable[[Tuple[int, ...]], None]] = None
        #: called with a node key when a host node's tier copy becomes
        #: garbage (re-adopted by a fresh prefill, or dropped)
        self.on_host_drop: Optional[Callable[[Tuple[int, ...]], None]] = None
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.shared_pages_acquired = 0
        self.insertions = 0
        self.evictions = 0

    # ------------------------------------------------------------- queries
    @property
    def cached_pages(self) -> int:
        return len(self._nodes)

    @property
    def evictable_pages(self) -> int:
        """Pages eviction could free by cascading leaf-first: COLD nodes
        (refcount 1) with no warm descendant.  A single ``evict`` step
        only takes leaves, but evicting a leaf exposes its parent — this
        counts the whole reclaimable chain, which is what "reclaimable
        bytes" means for the demand metric."""
        blocked = set()
        for key, node in self._nodes.items():
            # warm (request-shared) nodes pin their chain; HOST nodes do
            # not — eviction may drop them to reach the ancestors
            if not node.host and self.alloc.refcount(node.page_id) != 1:
                k = key
                while k:
                    blocked.add(k)
                    k = self._parent(k)
        return sum(
            1
            for key, node in self._nodes.items()
            if key not in blocked and not node.host
        )

    def live_snap_keys(self) -> set:
        return {node.snap_key for node in self._nodes.values()}

    def _parent(self, key: Tuple[int, ...]) -> Tuple[int, ...]:
        return key[: ((len(key) - 1) // self.page_tokens) * self.page_tokens]

    def _evictable(self, key: Tuple[int, ...]) -> bool:
        if self._children.get(key, 0) > 0:
            return False  # inner node: descendants would be orphaned
        if self._nodes[key].host:
            return False  # no HBM page to free; lives in the tier store
        return self.alloc.refcount(self._nodes[key].page_id) == 1

    # --------------------------------------------------------------- match
    def _walk(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        """Longest chain of cached nodes covering a prefix of ``tokens``."""
        toks = tuple(tokens)
        keys: List[Tuple[int, ...]] = []
        d = self.page_tokens
        while d <= len(toks):
            key = toks[:d]
            if key not in self._nodes:
                break
            keys.append(key)
            d += self.page_tokens
        base = keys[-1] if keys else ()
        best: Optional[Tuple[int, ...]] = None
        for term in self._terminals.get(base, ()):
            if len(term) <= len(toks) and toks[: len(term)] == term:
                if best is None or len(term) > len(best):
                    best = term
        if best is not None:
            keys.append(best)
        return keys

    def probe(
        self, tokens: Sequence[int]
    ) -> Tuple[int, Optional[Tuple[int, ...]], Tuple[int, ...]]:
        """(matched token count, snapshot key, matched page ids) without
        acquiring pages — the admission arithmetic, plus the page set an
        admission-time eviction must not victimize (the pages it is about
        to count as free-to-share).  The walk stops at the first HOST
        node: a demoted page cannot be shared until it is promoted."""
        keys = self._hbm_chain(self._walk(tokens))
        if not keys:
            return 0, None, ()
        pages = tuple(self._nodes[k].page_id for k in keys)
        return len(keys[-1]), self._nodes[keys[-1]].snap_key, pages

    def _hbm_chain(
        self, keys: List[Tuple[int, ...]]
    ) -> List[Tuple[int, ...]]:
        """Truncate a walk chain at the first non-HBM (host) node."""
        out: List[Tuple[int, ...]] = []
        for k in keys:
            if self._nodes[k].host:
                break
            out.append(k)
        return out

    def peek(self, tokens: Sequence[int]) -> Tuple[int, Optional[Tuple[int, ...]]]:
        """(matched token count, snapshot key) without acquiring pages."""
        matched, snap_key, _ = self.probe(tokens)
        return matched, snap_key

    def match(
        self,
        owner: str,
        tokens: Sequence[int],
        now: float = 0.0,
        count_stats: bool = True,
    ) -> Tuple[int, Optional[Tuple[int, ...]]]:
        """Longest-prefix match that ACQUIRES the cached pages for ``owner``
        (refcount +1 each, appended to its page table, LRU refreshed).
        Returns (matched token count, snapshot key).

        ``count_stats=False`` keeps the hit/dedup counters untouched — an
        offload-reload re-matching the request's OWN published prefix is
        a real page re-share but not evidence of cross-request sharing,
        and must not satisfy the benchmark's hit-rate acceptance bit."""
        if count_stats:
            self.lookups += 1
            self.lookup_tokens += len(tokens)
        walked = self._walk(tokens)
        keys = self._hbm_chain(walked)
        if len(keys) < len(walked) and self.promote_cb is not None:
            # the match ran into a demoted page: promote it so the next
            # identical prompt (a few ticks from now) shares the full
            # chain — page-granular, asynchronous re-warming
            self.promote_cb(walked[len(keys)])
        if not keys:
            return 0, None
        pages = [self._nodes[k].page_id for k in keys]
        self.alloc.share(owner, pages)
        for k in keys:
            self._nodes[k].last_use = now
        matched = len(keys[-1])
        if count_stats:
            self.hits += 1
            self.hit_tokens += matched
            self.shared_pages_acquired += len(pages)
        return matched, self._nodes[keys[-1]].snap_key

    # -------------------------------------------------------------- insert
    def insert(
        self,
        owner_table: Sequence[int],
        tokens: Sequence[int],
        group: str,
        snap_key: Tuple[int, ...],
        now: float = 0.0,
    ) -> int:
        """Record ``tokens``'s pages (from a finished prefill) in the trie.

        Full pages first, then the trailing partial page as a terminal
        node.  Pages already cached (by an identical earlier prompt) are
        skipped; overflow (host-resident) pages are never cached.  Returns
        the number of nodes inserted.
        """
        toks = tuple(tokens)
        P = self.page_tokens
        inserted = 0
        full = len(toks) // P
        for d in range(1, full + 1):
            key = toks[: d * P]
            if key in self._nodes:
                self._nodes[key].last_use = now
                self._readopt(key, owner_table, d - 1)
                continue
            parent = toks[: (d - 1) * P]
            if parent and parent not in self._nodes:
                break  # keep the trie connected
            if d - 1 >= len(owner_table):
                break
            pid = owner_table[d - 1]
            if pid >= self.alloc.n_pages or pid < 0:
                break  # never cache overflow or demoted entries
            self.alloc.share(CACHE_OWNER, [pid])
            self._nodes[key] = _PrefixNode(pid, P, group, snap_key, now)
            self._children[parent] = self._children.get(parent, 0) + 1
            inserted += 1
        rem = len(toks) % P
        if rem:
            key = toks
            parent = toks[: full * P]
            if key in self._nodes:
                self._nodes[key].last_use = now
                self._readopt(key, owner_table, full)
            elif (
                (full == 0 or parent in self._nodes)
                and full < len(owner_table)
                and 0 <= owner_table[full] < self.alloc.n_pages
            ):
                self.alloc.share(CACHE_OWNER, [owner_table[full]])
                self._nodes[key] = _PrefixNode(
                    owner_table[full], rem, group, snap_key, now
                )
                self._children[parent] = self._children.get(parent, 0) + 1
                self._terminals.setdefault(parent, []).append(key)
                inserted += 1
        if inserted:
            self.insertions += 1
        return inserted

    def _readopt(
        self, key: Tuple[int, ...], owner_table: Sequence[int], index: int
    ) -> None:
        """A fresh prefill re-materialized a prefix whose node had been
        demoted: the node adopts the new HBM page and the tier copy is
        dropped (it would otherwise be a second resident copy)."""
        node = self._nodes[key]
        if not node.host or index >= len(owner_table):
            return
        pid = owner_table[index]
        if not (0 <= pid < self.alloc.n_pages):
            return
        self.alloc.share(CACHE_OWNER, [pid])
        node.page_id = pid
        node.host = False
        if self.on_host_drop is not None:
            self.on_host_drop(key)

    # ------------------------------------------------------------- demotion
    def demote_node(self, key: Tuple[int, ...]) -> int:
        """Mark a COLD node as tier-resident: releases the cache's HBM
        page (the node's position in the trie survives, so the prefix is
        still matchable-after-promotion) and returns the freed page id.
        The caller moves the bytes into the tier store."""
        node = self._nodes[key]
        if node.host:
            raise ValueError(f"node {key!r} is already demoted")
        pid = node.page_id
        if self.alloc.refcount(pid) != 1:
            raise ValueError(f"page {pid} is warm (shared); only cold pages demote")
        node.page_id = DEMOTED
        node.host = True
        self.alloc.release_pages(CACHE_OWNER, [pid])
        return pid

    def demotable_victim(
        self, pressure: Optional[Callable[[str], float]] = None
    ) -> Optional[Tuple[int, ...]]:
        """The node cold-page demotion should move next (policy pressure
        × LRU, deepest first).  Unlike eviction there is NO leaf-first
        constraint: demotion keeps the node, so the trie stays connected
        whatever order pages leave HBM — a chain of host nodes re-warms
        progressively as matches promote it front to back."""
        best_key, best_rank = None, None
        for key, node in self._nodes.items():
            if node.host or self.alloc.refcount(node.page_id) != 1:
                continue
            p = float(pressure(node.group)) if pressure is not None else 0.0
            rank = (-p, node.last_use, -len(key))
            if best_rank is None or rank < best_rank:
                best_key, best_rank = key, rank
        return best_key

    def promote_node(self, key: Tuple[int, ...]) -> bool:
        """A tier promotion completed: give the node a fresh physical
        page.  When the free list cannot supply one, a LEAF node is
        dropped (a later identical prompt just re-prefills) — an INNER
        host node must stay: removing it would orphan its still-cached
        descendants, so it simply remains host and the next match
        retries the promotion.  Returns True when the node is
        HBM-backed again."""
        node = self._nodes.get(key)
        if node is None or not node.host:
            return False
        pid = self.alloc.take_free(CACHE_OWNER)
        if pid is None:
            if self._children.get(key, 0) == 0:
                self._remove_node(key, release_page=False)
            return False
        node.page_id = pid
        node.host = False
        return True

    # ------------------------------------------------------------ eviction
    def evict(
        self,
        n_pages: int,
        pressure: Optional[Callable[[str], float]] = None,
        protect: Sequence[int] = (),
    ) -> int:
        """Evict up to ``n_pages`` COLD cached pages; returns #evicted.

        Victim order: highest ``pressure(group)`` first (the policy's
        hint — MURS returns high pressure for low-usage-rate tenants),
        then least-recently-used, then deepest leaf.  Pages referenced by
        any live request (refcount > 1), inner nodes, and ``protect``-ed
        page ids (pages an in-flight admission probe just counted as
        free-to-share) are untouchable.
        """
        freed = 0
        protected = frozenset(protect)
        while freed < n_pages:
            victim = self._pick_victim(pressure, protected)
            if victim is None:
                break
            if self._nodes[victim].host:
                # dropping a host leaf frees no HBM page, but it unblocks
                # the HBM ancestors above it — without this, a demoted
                # terminal pins its whole chain against eviction forever
                self._remove_node(victim, release_page=False)
            else:
                self._evict_node(victim)
                freed += 1
        return freed

    def _pick_victim(
        self,
        pressure: Optional[Callable[[str], float]],
        protected: frozenset = frozenset(),
    ) -> Optional[Tuple[int, ...]]:
        """Best eviction victim: HBM cold leaves first (they actually free
        pages); host leaves only as a last resort (they merely unblock
        their ancestors)."""
        best_key, best_rank = None, None
        for key, node in self._nodes.items():
            if node.host:
                if self._children.get(key, 0) > 0:
                    continue  # host inner node: still anchors descendants
            elif node.page_id in protected or not self._evictable(key):
                continue
            p = float(pressure(node.group)) if pressure is not None else 0.0
            rank = (node.host, -p, node.last_use, -len(key))
            if best_rank is None or rank < best_rank:
                best_key, best_rank = key, rank
        return best_key

    def evict_node_for_page(self, pid: int) -> bool:
        """Drop the (leaf) node holding ``pid`` regardless of its warmth —
        the copy-on-write ownership transfer: when a writer needs the page
        private and the cache is the only other holder, releasing the
        cache's reference makes the page private IN PLACE, with no
        allocation at all.  Returns True if a node was dropped."""
        for key, node in self._nodes.items():
            if node.page_id == pid and self._children.get(key, 0) == 0:
                self._evict_node(key)
                return True
        return False

    def _evict_node(self, key: Tuple[int, ...]) -> None:
        self._remove_node(key, release_page=True)

    def _remove_node(self, key: Tuple[int, ...], release_page: bool) -> None:
        """Unlink a (leaf) node from the trie.  ``release_page=False`` is
        the host-node variant: there is no HBM page to release, but any
        tier copy must be dropped via ``on_host_drop``."""
        node = self._nodes.pop(key)
        parent = self._parent(key)
        remaining = self._children.get(parent, 1) - 1
        if remaining > 0:
            self._children[parent] = remaining
        else:
            # zero-count entries must go: their keys are full token tuples
            # and a long-lived engine churns through unboundedly many
            self._children.pop(parent, None)
        if node.n_tokens < self.page_tokens:
            terms = self._terminals.get(parent)
            if terms and key in terms:
                terms.remove(key)
                if not terms:
                    del self._terminals[parent]
        if release_page:
            self.alloc.release_pages(CACHE_OWNER, [node.page_id])
        elif self.on_host_drop is not None:
            self.on_host_drop(key)
        self.evictions += 1


@dataclass
class PagedKVManager:
    """Byte accounting + page-table allocation for a shared HBM region.

    The page pool is sized lazily on the first :meth:`register` (the page
    byte size depends on the architecture): ``n_pages = ⌊capacity /
    page_bytes⌋``.  Architectures with zero marginal KV bytes (mamba:
    constant state) hold no pages at all.  One pool can host MIXED page
    owners — requests registered under different :class:`ArchConfig`\\ s
    keep their own per-page byte geometry for attribution
    (:meth:`request_bytes`, :meth:`page_bytes_of`), while prefix-trie
    sharing stays restricted to the arch that sized the pool
    (:attr:`pool_arch`): token ids alone do not identify KV values
    across architectures.

    With ``enable_prefix_cache`` a :class:`PrefixCache` trie is attached:
    :meth:`match_prefix` / :meth:`insert_prefix` are the serving engine's
    admission hooks, and page shortage triggers cold-prefix eviction
    ordered by ``cache_pressure_fn`` (the active scheduling policy's
    hint) before the allocator falls back to overflow ids.
    """

    capacity_bytes: float
    page_tokens: int = 16
    enable_prefix_cache: bool = False
    cache_pressure_fn: Optional[Callable[[str], float]] = None
    #: tier hierarchy below HBM (host + disk); None → demotion disabled
    tier_config: Optional["TierConfig"] = None
    #: the single writer of byte tallies (DESIGN.md §13) — created here
    #: when not injected, and shared with the allocator and tier store so
    #: every byte the pool tracks carries a ``(tenant, class, tier)`` stamp
    ledger: Optional[MemoryLedger] = None
    #: owners registered as SCRATCH (speculative-decoding draft pages):
    #: eviction prefers their pages over every other class
    _scratch: set = field(default_factory=set)
    _page_bytes: Dict[str, float] = field(default_factory=dict)
    _state_bytes: Dict[str, float] = field(default_factory=dict)
    #: request id → arch name it registered under — one pool can host
    #: MIXED page owners (a model-zoo engine), each with its own
    #: per-page byte geometry; the prefix trie stays single-arch (token
    #: ids alone do not identify KV values across architectures)
    _arch: Dict[str, str] = field(default_factory=dict)
    _alloc: Optional[PageBlockAllocator] = None
    _prefix: Optional[PrefixCache] = None
    _pool_page_bytes: float = 0.0
    #: arch whose geometry sized the physical pool (first nonzero
    #: registrant); only its requests may share trie pages
    _pool_arch: Optional[str] = None
    tiers: Optional["TieredKVStore"] = None
    #: request ids whose attributed bytes changed outside the allocator
    #: (constant-state registration); merged into :meth:`drain_dirty`
    _dirty: set = field(default_factory=set)
    #: per-request, per-table-index WRITE EPOCHS — the delta-migration
    #: ledger (DESIGN.md §11).  The engine stamps every cache-write site
    #: (prefill install, chunked scan, decode append, payload install)
    #: with its tick; a drain pre-copy records the epoch it snapshotted
    #: at, and :meth:`pages_written_since` answers which pages the
    #: cutover must re-ship.  Distinct from the owner-level ``_dirty``
    #: set above, which tracks BYTE-ATTRIBUTION changes for the pool
    #: accounting, not page content.
    _write_epoch: Dict[str, Dict[int, int]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.ledger is None:
            self.ledger = MemoryLedger()
        if self.tier_config is not None:
            from repro.serve.tiers import TieredKVStore

            self.tiers = TieredKVStore(self.tier_config, ledger=self.ledger)

    # ------------------------------------------------------------ requests
    def page_bytes_for(self, cfg: ArchConfig) -> float:
        """One page's HBM bytes under ``cfg``'s geometry — THE page-size
        arithmetic; :meth:`register` and :meth:`admission_probe` both use
        it, so the pool and per-request paths cannot diverge."""
        return kv_bytes_per_token(cfg) * self.page_tokens

    def register(
        self,
        request_id: str,
        cfg: ArchConfig,
        prompt_tokens: int = 0,
        tenant: str = "",
    ) -> None:
        """Start tracking a request: derive its per-page bytes from the
        arch config and create the allocator on first use.

        Requests of DIFFERENT architectures may register into one pool
        (mixed page owners): each keeps its own per-page byte geometry
        for attribution (:meth:`request_bytes`), while physical page
        count is sized once, by the first nonzero-KV registrant.

        ``prompt_tokens`` adds the encoder-side cross-attention KV an
        encoder-decoder model pins for this prompt (zero elsewhere) into
        the request's fixed state bytes — it is written once at prefill
        and never grows with decode, so it rides with the constant-state
        term rather than the paged per-token term."""
        page_bytes = self.page_bytes_for(cfg)
        self._page_bytes[request_id] = page_bytes
        self._state_bytes[request_id] = constant_state_bytes(
            cfg
        ) + cfg.encoder_bytes(prompt_tokens)
        self._arch[request_id] = cfg.name
        self._dirty.add(request_id)
        self.ledger.register_owner(
            request_id,
            tenant=tenant,
            kind="request",
            page_bytes=page_bytes,
            state_bytes=self._state_bytes[request_id],
        )
        if self._alloc is None and page_bytes > 0:
            self._alloc = PageBlockAllocator(
                int(self.capacity_bytes // page_bytes), ledger=self.ledger
            )
            self._pool_page_bytes = page_bytes
            self._pool_arch = cfg.name
            self.ledger.register_owner(
                CACHE_OWNER, kind="cache", page_bytes=page_bytes
            )
            if self.enable_prefix_cache:
                self._prefix = PrefixCache(self._alloc, self.page_tokens)
                self._prefix.promote_cb = self._promote_cache_node
                self._prefix.on_host_drop = self._drop_cache_tier_copy
        if self._alloc is not None and page_bytes > 0:
            self._alloc.grow_to(request_id, 0)  # materialize an empty table

    @property
    def pool_arch(self) -> Optional[str]:
        """Arch name whose page geometry sized the pool (None before the
        first nonzero-KV registration)."""
        return self._pool_arch

    def page_bytes_of(self, request_id: str) -> float:
        """The request's own per-page byte size (its model's geometry —
        NOT necessarily the pool's)."""
        return self._page_bytes.get(request_id, 0.0)

    def _prefix_eligible(self, request_id: str) -> bool:
        """Prefix pages are only shareable within the pool's arch: the
        trie is keyed by token ids alone, and identical tokens under
        different architectures hold different KV values."""
        return (
            self._pool_arch is None
            or self._arch.get(request_id, self._pool_arch) == self._pool_arch
        )

    def grow_to(self, request_id: str, n_tokens: int) -> float:
        """Ensure pages cover ``n_tokens``; returns newly allocated bytes.

        When the free list cannot cover the growth, cold cached prefixes
        are evicted first (policy-ordered) — overflow ids are the last
        resort, not the first response to a warm cache.
        """
        page_bytes = self._page_bytes.get(request_id, 0.0)
        if page_bytes <= 0.0 or self._alloc is None:
            return 0.0
        need = (n_tokens + self.page_tokens - 1) // self.page_tokens
        new = need - self._alloc.pages_held(request_id)
        if new > 0 and self._prefix is not None:
            short = new - self._alloc.free_pages
            if short > 0:
                self._prefix.evict(short, self.cache_pressure_fn)
        return self._alloc.grow_to(request_id, need) * page_bytes

    def bytes_for(self, cfg: ArchConfig, n_tokens: int) -> float:
        """Page-rounded HBM bytes ``n_tokens`` would occupy — an
        arithmetic admission probe that allocates nothing."""
        pages = (n_tokens + self.page_tokens - 1) // self.page_tokens
        return pages * self.page_bytes_for(cfg)

    def admission_probe(
        self, cfg: ArchConfig, tokens: Sequence[int]
    ) -> Tuple[float, Tuple[int, ...]]:
        """Admission arithmetic net of prefix-cache hits: (bytes the NEW
        pages for ``tokens`` would occupy, the matched page ids).  The
        caller must pass the page ids as ``protect`` to any eviction it
        runs before acquiring the match — otherwise the eviction can
        victimize exactly the cold pages this probe just counted as
        free-to-share, and the later allocation overshoots the line that
        was checked."""
        total = (len(tokens) + self.page_tokens - 1) // self.page_tokens
        page_bytes = self.page_bytes_for(cfg)
        if self._prefix is None or (
            self._pool_arch is not None and cfg.name != self._pool_arch
        ):
            return total * page_bytes, ()
        matched, _, pages = self._prefix.probe(tokens)
        new = max(total - len(pages), 0)
        if pages and matched % self.page_tokens:
            # the match ends in a shared PARTIAL page: the request's first
            # append into it copy-on-writes onto a fresh page — count that
            # page now or admission admits one page more than it checked
            new += 1
        return new * page_bytes, pages

    def release(self, request_id: str) -> float:
        """Free every page the request owns (tier copies included);
        returns the bytes returned to the pool."""
        pages = 0
        if self._alloc is not None:
            if self.tiers is not None:
                # drop tier copies of demoted pages — their owner is gone
                for idx in self._alloc.demoted_indices(request_id):
                    self.tiers.discard(("req", request_id, idx))
            pages = self._alloc.free(request_id)
        pb = self._page_bytes.pop(request_id, 0.0)
        sb = self._state_bytes.pop(request_id, 0.0)
        self._dirty.add(request_id)
        self._write_epoch.pop(request_id, None)
        self.ledger.release_owner(request_id)
        return pages * pb + sb

    def set_frozen(self, request_id: str, frozen: bool) -> None:
        """Stamp a request suspended (or resumed): its sole-held pages
        restamp ``PRIVATE_SUFFIX`` ⇄ ``FROZEN`` in the ledger — frozen
        bytes are the proactive-demotion pass's primary target."""
        self.ledger.set_frozen(request_id, frozen)

    # ------------------------------------------------------------- scratch
    def register_scratch(
        self, owner: str, n_pages: int, tenant: str = ""
    ) -> int:
        """Allocate ``n_pages`` SCRATCH-class pages under ``owner`` (the
        speculative-decoding draft-page hook): eviction prefers scratch
        over every other class.  Returns the number of pages allocated
        (0 when the pool has not been sized yet)."""
        if self._alloc is None or self._pool_page_bytes <= 0:
            return 0
        if owner not in self._scratch:
            self.ledger.register_owner(
                owner,
                tenant=tenant,
                kind="scratch",
                page_bytes=self._pool_page_bytes,
            )
            self._scratch.add(owner)
        self._dirty.add(owner)
        return self._alloc.grow_to(
            owner, self._alloc.pages_held(owner) + n_pages
        )

    def evict_scratch(self, n_pages: int) -> int:
        """Drop up to ``n_pages`` scratch pages (newest first per owner)
        — the cheapest reclaim there is: scratch holds draft state that
        is free to regenerate.  Returns the number of pages freed."""
        if self._alloc is None:
            return 0
        freed = 0
        for owner in list(self._scratch):
            while freed < n_pages:
                table = self._alloc.table(owner)
                live = [pid for pid in table if pid >= 0]
                if not live:
                    break
                self._alloc.release_pages(owner, [live[-1]])
                freed += 1
            if freed >= n_pages:
                break
        return freed

    def release_scratch(self, owner: str) -> int:
        """Free every page of a scratch owner and retire it from the
        ledger; returns the number of pages released."""
        self._scratch.discard(owner)
        pages = self._alloc.free(owner) if self._alloc is not None else 0
        self.ledger.release_owner(owner)
        self._dirty.add(owner)
        return pages

    @property
    def scratch_bytes(self) -> float:
        """HBM bytes currently held by the SCRATCH class."""
        return self.ledger.class_bytes(PageClass.SCRATCH)

    def drain_dirty(self) -> set:
        """Owners whose attributed bytes may have changed since the last
        drain (registration, release, and every allocator refcount event
        — including co-holders of shared pages)."""
        out = self._dirty
        self._dirty = set()
        if self._alloc is not None:
            out |= self._alloc.drain_dirty()
        return out

    # ------------------------------------------------------ write epochs
    def note_write(
        self, request_id: str, start_tok: int, end_tok: int, epoch: int
    ) -> None:
        """Stamp the pages covering tokens ``[start_tok, end_tok)`` as
        written at ``epoch`` (the engine tick).  Every engine cache-write
        site calls this; the delta-migration cutover ships only pages
        whose stamp is newer than the pre-copy's epoch."""
        if end_tok <= start_tok:
            return
        ledger = self._write_epoch.setdefault(request_id, {})
        first = start_tok // self.page_tokens
        last = (end_tok - 1) // self.page_tokens
        for idx in range(first, last + 1):
            ledger[idx] = epoch

    def note_page_write(
        self, request_id: str, page_index: int, epoch: int
    ) -> None:
        """Stamp one table index as written at ``epoch`` (payload
        installs land whole pages, not token spans)."""
        self._write_epoch.setdefault(request_id, {})[page_index] = epoch

    def pages_written_since(self, request_id: str, epoch: int) -> set:
        """Table indices written STRICTLY AFTER ``epoch`` — the dirty
        delta between a pre-copy snapshot taken at ``epoch`` and now.
        Pages never stamped (e.g. installed before the ledger existed)
        are conservatively treated as dirty by the caller, not here."""
        ledger = self._write_epoch.get(request_id, {})
        return {idx for idx, e in ledger.items() if e > epoch}

    def write_epochs(self, request_id: str) -> Dict[int, int]:
        """The request's full write-epoch ledger (copy)."""
        return dict(self._write_epoch.get(request_id, {}))

    # ----------------------------------------------------- tier transitions
    def demote_page(
        self,
        request_id: str,
        index: int,
        payload: Optional[np.ndarray] = None,
        now: float = 0.0,
    ) -> bool:
        """Demote ONE private HBM page of ``request_id`` into the tier
        hierarchy (async: the page leaves HBM now, lands in host DRAM
        when the PCIe transfer completes).  Returns False when the page
        is not demotable (shared, overflow, already demoted, no tiers)."""
        if self.tiers is None or self._alloc is None:
            return False
        table = self._alloc.table(request_id)
        if index >= len(table):
            return False
        pid = table[index]
        if pid == DEMOTED or pid >= self._alloc.n_pages:
            return False
        if self._alloc.refcount(pid) != 1:
            return False  # shared with the trie/another request: pinned
        self._alloc.swap_out(request_id, index)
        self.tiers.demote(
            ("req", request_id, index),
            self._page_bytes.get(request_id, self._pool_page_bytes),
            payload,
            now,
        )
        return True

    def demotable_indices(self, request_id: str) -> Tuple[int, ...]:
        """Table indices demote_page would accept (private HBM pages)."""
        if self._alloc is None:
            return ()
        return tuple(
            i
            for i, pid in enumerate(self._alloc.table(request_id))
            if 0 <= pid < self._alloc.n_pages
            and self._alloc.refcount(pid) == 1
        )

    def shared_page_indices(self, request_id: str) -> set:
        """Table indices backed by SHARED physical pages (refcount > 1:
        cached prefixes and co-held prompt pages) — the long-lived
        lifetime class of DESIGN.md §6, and therefore the pages a KV
        checkpoint persists first (§11): they outlive any one request
        and shield the most replay work per byte."""
        if self._alloc is None:
            return set()
        return {
            i
            for i, pid in enumerate(self._alloc.table(request_id))
            if pid >= 0
            and self.ledger.page_class(pid) is PageClass.SHARED_PREFIX
        }

    def has_demoted(self, request_id: str) -> bool:
        """True if any of the request's pages live below HBM."""
        if self._alloc is None:
            return False
        return bool(self._alloc.demoted_indices(request_id))

    def demoted_page_count(self, request_id: str) -> int:
        """Number of the request's pages currently demoted to a tier."""
        if self._alloc is None:
            return 0
        return len(self._alloc.demoted_indices(request_id))

    def pending_transfers(self, request_id: str) -> bool:
        """True while any of the request's pages are ON THE LINK (demotion
        not yet landed in host, or promotion not yet landed in HBM)."""
        if self.tiers is None or self._alloc is None:
            return False
        return any(
            self.tiers.location(("req", request_id, idx))
            in ("to_host", "to_hbm")
            for idx in self._alloc.demoted_indices(request_id)
        )

    def promote_request(self, request_id: str, max_pages: int, now: float = 0.0) -> int:
        """Begin promoting up to ``max_pages`` of the request's demoted
        pages (those already landed in host/disk; in-flight demotions
        must finish first).  Returns the number of promotions started."""
        if self.tiers is None or self._alloc is None or max_pages <= 0:
            return 0
        started = 0
        for idx in self._alloc.demoted_indices(request_id):
            key = ("req", request_id, idx)
            if self.tiers.location(key) in ("host", "disk"):
                if self.tiers.promote(key, now):
                    started += 1
                    if started >= max_pages:
                        break
        return started

    def extract_demoted(self, request_id: str) -> Dict[int, object]:
        """Pull the compressed tier blocks of the request's DEMOTED pages
        out of the hierarchy (live-migration extraction): ``{table index →
        CompressedBlock}``.  Each block leaves host/disk for good — any
        in-flight transfer cancels — and the caller owns the bytes; the
        table entries stay :data:`DEMOTED`, so the caller must
        :meth:`release` the request afterwards (the migration source) or
        re-materialize the pages itself (there is no third option: an
        extracted page has no copy left on this replica)."""
        out: Dict[int, object] = {}
        if self.tiers is None or self._alloc is None:
            return out
        for idx in self._alloc.demoted_indices(request_id):
            block = self.tiers.extract(("req", request_id, idx))
            if block is not None:
                out[idx] = block
        return out

    def demote_cold_page(self, now: float = 0.0) -> bool:
        """Demote one COLD cached trie page (policy-ordered victim) into
        the tier hierarchy.  Unlike eviction the prefix stays KNOWN: the
        node survives as a host node, a later match promotes it back."""
        if self.tiers is None or self._prefix is None:
            return False
        victim = self._prefix.demotable_victim(self.cache_pressure_fn)
        if victim is None:
            return False
        self._prefix.demote_node(victim)
        self.tiers.demote(("cache", victim), self._pool_page_bytes, None, now)
        return True

    def _promote_cache_node(self, key: Tuple[int, ...]) -> None:
        if self.tiers is not None:
            self.tiers.promote(("cache", key))

    def _drop_cache_tier_copy(self, key: Tuple[int, ...]) -> None:
        if self.tiers is not None:
            self.tiers.discard(("cache", key))

    def tick_tiers(
        self, now: float = 0.0
    ) -> List[Tuple[str, int, Optional[np.ndarray]]]:
        """Advance the tier hierarchy one tick.  Completed request-page
        promotions are swapped back into their tables (overflow ids under
        a drained pool — the normal overcommit machinery applies) and
        returned as ``(request_id, page_index, dequantized_payload)`` so
        the engine can restore the page's KV values; completed cache-node
        promotions re-attach their trie nodes internally."""
        if self.tiers is None:
            return []
        restored: List[Tuple[str, int, Optional[np.ndarray]]] = []
        for kind, key, payload in self.tiers.tick(now):
            if kind != "resident":
                continue
            if key[0] == "req":
                _, rid, idx = key
                if (
                    rid in self._page_bytes
                    and self._alloc is not None
                    and idx < len(self._alloc.table(rid))
                    and self._alloc.table(rid)[idx] == DEMOTED
                ):
                    self._alloc.swap_in(rid, idx)
                    restored.append((rid, idx, payload))
            elif key[0] == "cache" and self._prefix is not None:
                if not self._prefix.promote_node(key[1]):
                    # the DMA landed but no free page could back it; if
                    # the node survived (an inner host node — dropping
                    # it would orphan descendants), park the bytes back
                    # in the hierarchy so a later match can retry —
                    # otherwise the node would be host with NO tier copy
                    node = self._prefix._nodes.get(key[1])
                    if node is not None and node.host:
                        self.tiers.demote(
                            key, self._pool_page_bytes, None, now,
                            repark=True,
                        )
        return restored

    @property
    def inflight_promotions(self) -> int:
        return self.tiers.inflight_promotions if self.tiers is not None else 0

    def tier_stats(self) -> Dict[str, float]:
        """Tier-hierarchy counters for the report (empty-shape when
        tiering is disabled)."""
        if self.tiers is None:
            return {"enabled": False}
        stats: Dict[str, float] = {"enabled": True}
        stats.update(self.tiers.stats())
        return stats

    # -------------------------------------------------------- prefix cache
    def peek_prefix(
        self, tokens: Sequence[int]
    ) -> Tuple[int, Optional[Tuple[int, ...]]]:
        """(matched token count, snapshot key) — no pages acquired."""
        if self._prefix is None:
            return 0, None
        return self._prefix.peek(tokens)

    def match_prefix(
        self,
        request_id: str,
        tokens: Sequence[int],
        now: float = 0.0,
        count_stats: bool = True,
    ) -> Tuple[int, Optional[Tuple[int, ...]]]:
        """Acquire the longest cached prefix of ``tokens`` for
        ``request_id`` (its page table must be empty).  Returns (matched
        token count, snapshot key).  ``count_stats=False`` for replays —
        re-sharing your own published prefix is not a cache hit."""
        if self._prefix is None or self._alloc is None:
            return 0, None
        if not self._prefix_eligible(request_id):
            return 0, None
        if self._alloc.pages_held(request_id) > 0:
            raise ValueError(
                f"match_prefix needs an empty table for {request_id!r}"
            )
        return self._prefix.match(request_id, tokens, now, count_stats)

    def insert_prefix(
        self,
        request_id: str,
        tokens: Sequence[int],
        group: str,
        snap_key: Tuple[int, ...],
        now: float = 0.0,
    ) -> int:
        """Publish a finished prefill's pages into the trie; returns the
        number of newly cached pages.  Off-pool-arch requests publish
        nothing (their KV is not shareable under the pool's trie)."""
        if self._prefix is None or self._alloc is None:
            return 0
        if not self._prefix_eligible(request_id):
            return 0
        return self._prefix.insert(
            self._alloc.table(request_id), tokens, group, snap_key, now
        )

    def make_private(self, request_id: str, page_index: int) -> None:
        """Copy-on-write guard: call before writing tokens into the page at
        ``page_index`` of the request's table.  No-op for private pages.

        Like :meth:`grow_to`, a COW under a drained free list sheds cache
        before handing out overflow ids: first by OWNERSHIP TRANSFER —
        if the cache is the only other holder of the page, dropping its
        node makes the page private in place with no allocation — then by
        evicting some other cold page to back the copy."""
        if self._alloc is None:
            return
        if page_index >= self._alloc.pages_held(request_id):
            return
        pid = self._alloc.table(request_id)[page_index]
        if (
            self._alloc.refcount(pid) > 1
            and self._alloc.free_pages == 0
            and self._prefix is not None
        ):
            if (
                self._alloc.refcount(pid) == 2
                and self._prefix.evict_node_for_page(pid)
                and self._alloc.refcount(pid) <= 1
            ):
                return  # transferred: already private, nothing to copy
            self._prefix.evict(1, self.cache_pressure_fn, protect=(pid,))
        self._alloc.ensure_private(request_id, page_index)

    def evict_cache(self, n_pages: int, protect: Sequence[int] = ()) -> int:
        """Evict up to ``n_pages`` cold cached pages (policy-ordered);
        ``protect`` shields pages an admission probe just counted."""
        if self._prefix is None:
            return 0
        return self._prefix.evict(n_pages, self.cache_pressure_fn, protect)

    def live_snap_keys(self) -> set:
        return self._prefix.live_snap_keys() if self._prefix else set()

    @property
    def evictable_cache_pages(self) -> int:
        return self._prefix.evictable_pages if self._prefix else 0

    @property
    def reclaimable_bytes(self) -> float:
        """Bytes one eviction call away from being free — the ledger's
        ``COLD_CACHED`` + ``SCRATCH`` HBM totals (cold cached pages are
        held by the cache alone; scratch is droppable by definition) —
        the OS page-cache notion of "available".  Pool demand = used −
        reclaimable."""
        return self.ledger.class_bytes(
            PageClass.COLD_CACHED
        ) + self.ledger.class_bytes(PageClass.SCRATCH)

    @property
    def cache_bytes(self) -> float:
        """Pool bytes attributed to the prefix cache (its fractional share
        of the pages it holds — a page also held by a request is mostly
        charged to the request).  A ledger owner query."""
        return self.ledger.owner_bytes(CACHE_OWNER)

    @property
    def cow_events(self) -> int:
        return self._alloc.cow_events if self._alloc is not None else 0

    @property
    def cache_evictions(self) -> int:
        return self._prefix.evictions if self._prefix is not None else 0

    def prefix_stats(self) -> Dict[str, float]:
        """Machine-readable prefix-cache trajectory for BENCH_serve.json."""
        p = self._prefix
        if p is None:
            return {"enabled": False}
        return {
            "enabled": True,
            "lookups": p.lookups,
            "hits": p.hits,
            "hit_rate": p.hits / p.lookups if p.lookups else 0.0,
            "hit_tokens": p.hit_tokens,
            "lookup_tokens": p.lookup_tokens,
            "token_hit_rate": (
                p.hit_tokens / p.lookup_tokens if p.lookup_tokens else 0.0
            ),
            "shared_pages_acquired": p.shared_pages_acquired,
            "dedup_bytes": p.shared_pages_acquired * self._pool_page_bytes,
            "cached_pages": p.cached_pages,
            "insertions": p.insertions,
            "evictions": p.evictions,
            "cow_events": self.cow_events,
        }

    # ------------------------------------------------------------- queries
    def page_table(self, request_id: str) -> Tuple[int, ...]:
        """The request's page table — the paged_decode kernel's indirection."""
        if self._alloc is None:
            return ()
        return self._alloc.table(request_id)

    def table_array(
        self, request_ids: Sequence[str], max_pages: Optional[int] = None
    ) -> np.ndarray:
        """Kernel-ready ``[B, max_pages]`` int32 page tables (padded)."""
        if self._alloc is None:
            return np.zeros((len(request_ids), max_pages or 1), np.int32)
        return self._alloc.table_array(request_ids, max_pages)

    def gather_plan(
        self, request_ids: Sequence[str], slots: Sequence[int]
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """Export one decode batch for the paged kernel: width-trimmed
        page tables plus pool-page PROVENANCE — which batch slot's dense
        cache holds each referenced pool page, and at which logical page
        index inside that slot.

        ``request_ids`` and ``slots`` are parallel; callers sort rows by
        sequence length (longest first) so the returned width trims the
        kernel's page grid to the longest resident request.  Returns
        ``(tables, src_slot, src_idx, n_pool)``:

        * ``tables``  int32 ``[B, W]`` — W is the smallest power of two
          covering the longest request's table (bounded compile cache);
        * ``src_slot``/``src_idx`` int32 ``[n_pool]`` — provenance of
          every referenced page id (unreferenced ids stay 0: the kernel
          masks them via ``seq_lens``, so they are never read);
        * ``n_pool`` — power-of-two exclusive bound on referenced ids.

        A shared (prefix) page may be owned by several rows; any owner's
        slot cache holds identical values for it, so last-writer-wins
        provenance is safe.  Raises ``ValueError`` when a request holds
        demoted pages — those tokens are not in HBM and the caller must
        keep the request off the kernel path.
        """
        rows = [self.page_table(rid) for rid in request_ids]
        if any(pid < 0 for row in rows for pid in row):
            raise ValueError(
                "gather_plan: request holds demoted (non-HBM) pages"
            )
        max_pages = max((len(row) for row in rows), default=0)
        width = 1 << max(max_pages - 1, 0).bit_length()
        tables = self.table_array(request_ids, max(width, 1))
        bound = max((pid for row in rows for pid in row), default=0) + 1
        n_pool = 1 << max(bound - 1, 0).bit_length()
        src_slot = np.zeros(max(n_pool, 1), np.int32)
        src_idx = np.zeros(max(n_pool, 1), np.int32)
        for row, slot in zip(rows, slots):
            for j, pid in enumerate(row):
                src_slot[pid] = slot
                src_idx[pid] = j
        return tables, src_slot, src_idx, max(n_pool, 1)

    def request_pages(self, request_id: str) -> int:
        return self._alloc.pages_held(request_id) if self._alloc else 0

    def resident(self, request_id: str) -> bool:
        """True iff the request's KV is fully HBM-resident (decodable)."""
        return self._alloc.resident(request_id) if self._alloc else True

    def reclaim(self) -> int:
        """Page overflow entries back in; returns pages moved.  Cold cached
        prefixes are evicted first when they are what stands between an
        overflow page and residency."""
        if self._alloc is None:
            return 0
        if self._prefix is not None:
            short = self._alloc.overflow_pages - self._alloc.free_pages
            if short > 0:
                self._prefix.evict(short, self.cache_pressure_fn)
        return self._alloc.reclaim()

    def request_bytes(self, request_id: str) -> float:
        """The request's attributed HBM bytes (shared pages fractionally,
        plus its fixed state) — a ledger owner query."""
        return self.ledger.owner_bytes(request_id)

    @property
    def n_pages(self) -> int:
        """Physical pages in the pool (0 until the first register sizes it)."""
        return self._alloc.n_pages if self._alloc is not None else 0

    @property
    def free_pages(self) -> int:
        return self._alloc.free_pages if self._alloc is not None else 0

    @property
    def overflow_pages(self) -> int:
        return self._alloc.overflow_pages if self._alloc is not None else 0

    @property
    def page_id_bound(self) -> int:
        """Exclusive upper bound on every page id ever handed out."""
        return self._alloc.page_id_bound if self._alloc is not None else 0

    @property
    def used_bytes(self) -> float:
        """Physical bytes held: the ledger's total HBM-resident bytes —
        per-owner fractional shares sum to the physical total, so a page
        shared k ways is counted exactly once."""
        return self.ledger.hbm_bytes()

    @property
    def used_fraction(self) -> float:
        """Pool occupancy.  A zero-capacity pool (constant-state / mamba
        deployments hold no KV pages at all) with nothing in it is EMPTY
        (0.0), not full — reporting 1.0 made every ``> threshold`` check
        fire permanently for a pool that cannot hold anything; a
        zero-capacity pool that somehow holds bytes is saturated (1.0)."""
        if self.capacity_bytes:
            return self.used_bytes / self.capacity_bytes
        return 0.0 if not self.used_bytes else 1.0
