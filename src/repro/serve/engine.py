"""Multi-tenant continuous-batching serving engine on the policy layer.

The paper's scheduler compiled into a JAX serving runtime: multiple tenants
submit requests into one engine (one model, one HBM pool — the "service
mode" of MURS §II).  Each request is a task of the pluggable
:class:`repro.sched.SchedulingPolicy`:

    processed  = tokens consumed so far (prompt + generated)
    live bytes = its KV/state footprint from the PagedKVManager
    rate       = Δlive/Δtokens — measured online by the MURS Sampler, which
                 classifies full-attention decodes as linear, MLA as shallow-
                 linear, sliding-window/mamba as constant (paper §III models)

Every ``period`` ticks the policy runs against the pool: requests proposed
for suspension stop being scheduled (their KV pages stay resident — exactly
Spark's suspended tasks); one suspended request resumes per completion
(FIFO, starvation-free under MURS) and all resume when pressure drops below
yellow.  :class:`FairPolicy` is the stock baseline: no pressure response,
so the engine's reactive path (offload-to-host, or hard failure when
offload is disabled) fires when the pool overcommits.  Admission is
uniform — every policy queues at the door; what differs is the admission
line (``admission_headroom``) and how fast headroom appears (a suspending
policy swaps frozen KV to host, a pressure-oblivious one waits for
completions or pays the reactive path).

The hot loop is CONTINUOUS BATCHING with CHUNKED PREFILL: prompts are
consumed in token-budgeted chunks (``prefill_chunk_tokens`` per tick)
interleaved with decode ticks, so one long prompt never stalls every
in-flight decode the way a monolithic prefill call does.  Decode runs
slot-batched: one jitted vmapped decode step advances every active slot per
tick with per-slot positions; prefill continuation shares the same cache
layout through a single-slot jitted step.  KV lives in the paged pool of
:class:`PagedKVManager` — free-list block allocator, per-request page
tables, the same tables the Pallas ``paged_decode`` kernel consumes.

PREFIX SHARING: admission matches each prompt against the pool's token
trie (:class:`repro.serve.kv_cache.PrefixCache`).  Matched pages are
acquired by reference (refcount + 1, zero new bytes) and their KV is
installed from a snapshot taken when the prefix was first prefetched —
prefill compute is SKIPPED for cached tokens; chunked prefill starts at
the first uncached token.  Any later append into a shared page goes
through copy-on-write, so a shared page is never mutated.  Cold cached
prefixes evict under pressure in LRU order crossed with the policy's
``cache_pressure`` hint (MURS: low-usage-rate tenants first).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.memory_manager import MemoryPool
from repro.core.sampler import Sampler
from repro.sched import FairPolicy, MursConfig, MursPolicy, SchedulingPolicy
from repro.models import decode_step, init_cache, prefill
from repro.serve.kv_cache import CACHE_OWNER, PagedKVManager

#: Request.reload_at sentinel — offloaded while suspended; reload is gated
#: on the policy resuming the request, not on a timer.
WAIT_FOR_RESUME = -2


@dataclass
class Request:
    request_id: str
    tenant: str
    prompt: List[int]
    max_new_tokens: int
    submit_tick: int = 0
    slot: int = -1
    pos: int = 0  # tokens materialized in the cache so far
    generated: List[int] = field(default_factory=list)
    state: str = "queued"  # queued|prefill|decoding|suspended|offloaded|done|failed
    finish_tick: int = -1
    #: MURS §III classification of this request's memory behaviour, as
    #: measured online by the sampler (constant/sub_linear/linear/super_linear)
    memory_model: str = "constant"
    reload_at: int = -1  # tick when an offloaded request finishes reloading
    offloads: int = 0
    #: prompt tokens covered by a prefix-cache match (0 = cold)
    cached_tokens: int = 0
    #: KV-snapshot key of the matched prefix (the caching prompt's tokens)
    snap_key: Optional[Tuple[int, ...]] = None
    first_token_tick: int = -1  # tick the first generated token appeared
    #: engine hit counters already incremented for this request — a
    #: suspend/resume replay re-installs the snapshot but must not
    #: re-count the dedup'd prefill work
    hit_counted: bool = False

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new_tokens

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def feed_tokens(self) -> List[int]:
        """Every token whose KV must be materialized before the next decode
        step: the prompt plus all generated tokens but the last (which is
        fed BY the next decode step).  This is also the replay sequence
        that rebuilds a slot cache after suspension moved the request out
        of its batch row."""
        if self.generated:
            return self.prompt + self.generated[:-1]
        return self.prompt

    @property
    def prefilling(self) -> bool:
        return self.pos < len(self.feed_tokens)


@dataclass
class EngineConfig:
    n_slots: int = 4
    max_seq: int = 128
    hbm_capacity_bytes: float = 1e6  # KV pool budget (simulated pressure)
    #: scheduling policy instance; None → resolved from ``scheduler``
    policy: Optional[SchedulingPolicy] = None
    #: legacy spelling: a MursConfig → MursPolicy, None → FairPolicy
    scheduler: Optional[MursConfig] = None
    #: engine ticks per unit of the policy's ``period`` — the seasonal
    #: pass runs every ``round(policy.period * murs_period_ticks)`` ticks
    murs_period_ticks: int = 1
    greedy: bool = True
    #: prefill token budget per engine tick — prompts longer than this are
    #: split into chunks interleaved with decode ticks (continuous batching)
    prefill_chunk_tokens: int = 64
    #: host-DRAM offload ("spill") instead of hard failure when the pool
    #: overcommits; reloading costs this many ticks per offloaded request
    offload_enabled: bool = True
    offload_reload_ticks: int = 8
    #: prefix-sharing paged KV cache: admission matches prompts against the
    #: token trie, cached pages are shared by refcount (COW on append) and
    #: prefill is skipped up to the first uncached token
    prefix_cache: bool = True
    #: host-side KV snapshots backing prefill-skip, LRU-bounded so a
    #: long-lived engine serving many distinct prompts cannot grow host
    #: memory without bound (each snapshot is one slot's full cache
    #: subtree).  Beyond the bound, matches on snapshot-less trie nodes
    #: still dedup pages — they just recompute the prefill (COW-guarded).
    max_prefix_snapshots: int = 64

    def resolve_policy(self) -> SchedulingPolicy:
        if self.policy is not None and self.scheduler is not None:
            raise ValueError("pass either policy= or scheduler=, not both")
        if self.policy is not None:
            return self.policy
        if self.scheduler is not None:
            return MursPolicy(self.scheduler)
        return FairPolicy()


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig) -> None:
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.pool = MemoryPool(capacity=ecfg.hbm_capacity_bytes)
        self.kv = PagedKVManager(
            capacity_bytes=ecfg.hbm_capacity_bytes,
            enable_prefix_cache=ecfg.prefix_cache,
        )
        self.policy: SchedulingPolicy = ecfg.resolve_policy()
        # eviction order consults the active policy: LRU × cache_pressure
        self.kv.cache_pressure_fn = self.policy.cache_pressure
        self.sampler = Sampler()
        self.tick = 0
        self.queue: List[Request] = []
        self._restore: List[str] = []  # resumed/reloaded, waiting for a slot
        self.requests: Dict[str, Request] = {}  # full history (lookup/report)
        #: not-yet-terminal requests — every per-tick scan walks this, so
        #: tick cost is bounded by the in-flight set, not request history
        self._live: Dict[str, Request] = {}
        self.failed: List[str] = []
        self.completed: List[str] = []
        self.suspensions = 0
        self.peak_used_fraction = 0.0
        #: like peak_used_fraction but net of RECLAIMABLE bytes (cold
        #: cached prefixes are one evict_cache() from free — the page-cache
        #: notion of available memory); this is the dedup'd live demand
        self.peak_demand_fraction = 0.0
        self.chunked_prefill_ticks = 0
        self.reactive_offloads = 0  # forced spill of RUNNING work (stock path)
        self.swap_outs = 0  # suspended-KV swapped to host to free pages
        self.stall_ticks = 0  # request-ticks lost to non-resident KV
        self.prefix_hits = 0  # requests that skipped prefill via the trie
        self.prefix_hit_tokens = 0  # prompt tokens whose prefill was skipped
        #: KV snapshots backing cached prefixes: snap_key (the caching
        #: prompt's token tuple) → (slot cache subtree, first greedy token,
        #: snapshot length).  Pruned when the trie evicts the last node
        #: referencing a snapshot.
        self._snaps: Dict[Tuple[int, ...], Tuple[Any, int, int]] = {}
        self._pruned_at_evictions = 0

        # slot-batched decode state.  Cache layout quirk: "unit" leaves are
        # scan-stacked [reps, batch, ...] (batch on axis 1) while "suffix"
        # (and cross_kv) leaves are [batch, ...] — vmap axes and the
        # batch-insert/strip helpers below account for that.
        self._caches = init_cache(cfg, ecfg.n_slots, ecfg.max_seq)
        self._slot_req: List[Optional[str]] = [None] * ecfg.n_slots

        def _cache_axes(caches):
            axes = {
                "unit": jax.tree_util.tree_map(lambda _: 1, caches["unit"]),
                "suffix": jax.tree_util.tree_map(
                    lambda _: 0, caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                axes["cross_kv"] = jax.tree_util.tree_map(
                    lambda _: 0, caches["cross_kv"]
                )
            return axes

        def _add_batch(caches):
            out = {
                "unit": jax.tree_util.tree_map(
                    lambda x: x[:, None], caches["unit"]
                ),
                "suffix": jax.tree_util.tree_map(
                    lambda x: x[None], caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                out["cross_kv"] = jax.tree_util.tree_map(
                    lambda x: x[None], caches["cross_kv"]
                )
            return out

        def _strip_batch(caches):
            out = {
                "unit": jax.tree_util.tree_map(
                    lambda x: x[:, 0], caches["unit"]
                ),
                "suffix": jax.tree_util.tree_map(
                    lambda x: x[0], caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                out["cross_kv"] = jax.tree_util.tree_map(
                    lambda x: x[0], caches["cross_kv"]
                )
            return out

        def _one_slot_decode(params, token, caches, pos, active):
            logits, new_caches = decode_step(
                cfg, params, token[None], _add_batch(caches), pos
            )
            # inactive slots (mid-chunked-prefill, stalled, suspended-but-
            # slotted) must not advance: keep their cache bit-for-bit —
            # an unmasked step would write token-0 KV at position 0 and
            # advance recurrent (mamba) state unconditionally
            new_caches = jax.tree_util.tree_map(
                lambda n, o: jnp.where(active, n, o),
                _strip_batch(new_caches),
                caches,
            )
            return logits[0], new_caches

        self._decode_all = jax.jit(
            jax.vmap(
                _one_slot_decode,
                in_axes=(None, 0, _cache_axes(self._caches), 0, 0),
                out_axes=(0, _cache_axes(self._caches)),
            ),
            donate_argnums=(2,),
        )
        self._prefill = jax.jit(
            lambda params, tokens: prefill(
                cfg, params, tokens, max_seq=ecfg.max_seq, remat=False
            )
        )

        def _chunk_scan(params, tokens, caches, slot, pos0):
            """Advance ONE slot by ``len(tokens)`` prompt tokens in a
            single device dispatch (scan over the shared decode_step) —
            the chunked-prefill continuation path of continuous batching.

            Extracts the slot's cache once (keepdims → batch of 1), scans
            the chunk through decode_step, writes the slot back, and
            returns the last token's logits.
            """
            take_u = lambda x: jax.lax.dynamic_index_in_dim(x, slot, 1)
            take_s = lambda x: jax.lax.dynamic_index_in_dim(x, slot, 0)
            sub = {
                "unit": jax.tree_util.tree_map(take_u, caches["unit"]),
                "suffix": jax.tree_util.tree_map(take_s, caches["suffix"]),
            }
            if "cross_kv" in caches:
                sub["cross_kv"] = jax.tree_util.tree_map(
                    take_s, caches["cross_kv"]
                )

            def body(carry, inp):
                tok, p = inp
                logits, carry = decode_step(
                    cfg, params, tok[None, None], carry, p
                )
                return carry, logits[0, 0]

            poss = pos0 + jnp.arange(tokens.shape[0], dtype=jnp.int32)
            new_sub, logits_seq = jax.lax.scan(body, sub, (tokens, poss))
            put_u = lambda s, o: jax.lax.dynamic_update_index_in_dim(s, o, slot, 1)
            put_s = lambda s, o: jax.lax.dynamic_update_index_in_dim(s, o, slot, 0)
            out = {
                "unit": jax.tree_util.tree_map(
                    put_u, caches["unit"], new_sub["unit"]
                ),
                "suffix": jax.tree_util.tree_map(
                    put_s, caches["suffix"], new_sub["suffix"]
                ),
            }
            if "cross_kv" in caches:
                out["cross_kv"] = caches["cross_kv"]  # static during decode
            return logits_seq[-1], out

        self._chunk_scan = jax.jit(_chunk_scan, donate_argnums=(2,))

    # ------------------------------------------------------------- tenants
    def submit(self, req: Request) -> None:
        req.submit_tick = self.tick
        self.queue.append(req)
        self.requests[req.request_id] = req
        self._live[req.request_id] = req

    # ------------------------------------------------------------ accounting
    def _update_pool(self) -> None:
        for rid, req in self._live.items():
            if req.state in ("prefill", "decoding", "suspended"):
                self.pool.set_live(rid, self.kv.request_bytes(rid))
        if self.ecfg.prefix_cache:
            # cold cached prefixes are live pool bytes too — the policy
            # must see them (and eviction must relieve them)
            self.pool.set_live(CACHE_OWNER, self.kv.cache_bytes)
        self.peak_used_fraction = max(
            self.peak_used_fraction, self.pool.used_fraction
        )
        if self.pool.capacity > 0:
            demand = (
                self.pool.used_bytes - self.kv.reclaimable_bytes
            ) / self.pool.capacity
            self.peak_demand_fraction = max(self.peak_demand_fraction, demand)

    def _active(self) -> List[Request]:
        return [
            r
            for r in self._live.values()
            if r.state in ("prefill", "decoding")
        ]

    # ------------------------------------------------------------ admission
    def _admit(self) -> None:
        """Admit queued requests while slots and prompt headroom allow.

        A request that does not fit WAITS at the door (stock continuous-
        batching semantics: block until KV pages free up) — for every
        policy, so admission order is never a policy branch.  What differs
        is how fast headroom appears: a suspending policy swaps frozen KV
        to host and frees pages; a pressure-oblivious one waits for
        completions or pays the reactive spill path.
        """
        free_slots = [i for i, r in enumerate(self._slot_req) if r is None]
        # resumed / reloaded requests re-acquire a batch row first — their
        # slot cache is rebuilt by replaying feed_tokens through the
        # chunked-prefill path (their page-pool accounting never moved)
        while self._restore and free_slots:
            req = self.requests[self._restore.pop(0)]
            if req.state == "offloaded":
                self.kv.register(req.request_id, self.cfg)
            if self.ecfg.prefix_cache:
                # replay can skip prefill too: a reloaded request re-shares
                # cached pages; a suspended one (pages retained) just reuses
                # the snapshot for the covered positions.  Neither counts
                # as a cache HIT — re-matching your own published prefix is
                # not cross-request sharing (count_stats/hit_counted)
                if self.kv.request_pages(req.request_id) == 0:
                    req.cached_tokens, req.snap_key = self.kv.match_prefix(
                        req.request_id,
                        req.feed_tokens,
                        float(self.tick),
                        count_stats=False,
                    )
                else:
                    req.cached_tokens, req.snap_key = self.kv.peek_prefix(
                        req.feed_tokens
                    )
                req.hit_counted = True
            slot = free_slots.pop(0)
            req.slot = slot
            self._slot_req[slot] = req.request_id
            req.state = "prefill"
            req.pos = 0
            # replay rewinds processed-token counts: restart the rate
            # estimator so the sampler never sees progress go backwards
            # (a stale window would report rate 0 and invert MURS's
            # keep-the-lightest victim ordering)
            self.sampler.forget(req.request_id)
        # a tenant with suspended requests is a known heavy-pressure source:
        # don't admit more of its traffic until its queue drains (the sim's
        # launch gating, §I: "the resources are released from running heavy
        # tasks" — and handed to the light tenants)
        gated = {
            self.requests[tid].tenant
            for tid in self.policy.suspended_queue
            if tid in self.requests
        }
        headroom = self.policy.admission_headroom * self.pool.capacity
        # the policy's placement hook decides which tenant's head-of-line
        # request each free slot goes to (FAIR/MURS: round-robin across
        # tenants, PriorityPolicy: weighted stride) — FIFO within a tenant
        by_tenant: Dict[str, List[Request]] = {}
        for r in self.queue:
            if r.tenant not in gated:
                by_tenant.setdefault(r.tenant, []).append(r)
        picks = self.policy.assign(
            len(free_slots), {t: len(v) for t, v in by_tenant.items()}
        )
        for tenant in picks:
            if not free_slots or not by_tenant.get(tenant):
                continue
            req = by_tenant[tenant][0]
            # capacity check: would this request's prompt fit below the
            # policy's admission line right now?  Pure arithmetic — no
            # allocator churn for a request that just waits at the door.
            # Pages a prefix-cache match would share cost nothing new;
            # ``protected`` shields them from this pass's own evictions.
            prompt_bytes, protected = self.kv.admission_probe(
                self.cfg, req.prompt
            )
            if prompt_bytes > headroom:
                # can never fit, even into an empty pool: fail fast
                # (OOM semantics) instead of blocking the queue forever
                self.queue.remove(req)
                by_tenant[tenant].pop(0)
                req.state = "failed"
                req.finish_tick = self.tick
                self.failed.append(req.request_id)
                self._live.pop(req.request_id, None)
                continue
            # cold cached prefixes are the cheapest bytes to shed — drop
            # them (policy-ordered) before touching anyone's frozen KV,
            # but never the pages the probe above counted as shareable
            while self.pool.used_bytes + prompt_bytes > headroom:
                if not self.kv.evict_cache(1, protect=protected):
                    break
                self._update_pool()
            # frozen suspended KV pins the pool while slots idle — swap
            # victims to host while that can actually open the door
            while (
                self.pool.used_bytes + prompt_bytes > headroom
                and self.pool.used_bytes - self._frozen_bytes() + prompt_bytes
                <= headroom
            ):
                if not self._swap_out_frozen():
                    break
            if self.pool.used_bytes + prompt_bytes > headroom:
                break  # pool-bound: nobody else fits this tick either
            self.queue.remove(req)
            by_tenant[tenant].pop(0)
            self.kv.register(req.request_id, self.cfg)
            if self.ecfg.prefix_cache:
                # the trie hands over every page of the longest cached
                # prefix by reference — prefill will start at the first
                # uncached token
                req.cached_tokens, req.snap_key = self.kv.match_prefix(
                    req.request_id, req.feed_tokens, float(self.tick)
                )
            self.kv.grow_to(req.request_id, len(req.prompt))
            slot = free_slots.pop(0)
            req.slot = slot
            self._slot_req[slot] = req.request_id
            req.state = "prefill"
            req.pos = 0
            self._update_pool()

    # --------------------------------------------------------- slot caches
    def _extract_slot(self, slot: int) -> Dict[str, Any]:
        """Copy one slot's cache subtree (the KV snapshot a cached prefix
        is installed from)."""
        sub = {
            "unit": jax.tree_util.tree_map(
                lambda x: x[:, slot], self._caches["unit"]
            ),
            "suffix": jax.tree_util.tree_map(
                lambda x: x[slot], self._caches["suffix"]
            ),
        }
        if "cross_kv" in self._caches:
            sub["cross_kv"] = jax.tree_util.tree_map(
                lambda x: x[slot], self._caches["cross_kv"]
            )
        return sub

    def _install_slot(self, slot: int, sub: Dict[str, Any]) -> None:
        """Write a snapshot subtree into ``slot`` of the batched caches."""
        new = dict(self._caches)
        new["unit"] = jax.tree_util.tree_map(
            lambda s, o: s.at[:, slot].set(o), self._caches["unit"], sub["unit"]
        )
        new["suffix"] = jax.tree_util.tree_map(
            lambda s, o: s.at[slot].set(o),
            self._caches["suffix"],
            sub["suffix"],
        )
        if "cross_kv" in self._caches:
            new["cross_kv"] = jax.tree_util.tree_map(
                lambda s, o: s.at[slot].set(o),
                self._caches["cross_kv"],
                sub["cross_kv"],
            )
        self._caches = new

    # ---------------------------------------------------------- prefix COW
    def _cow_range(self, req: Request, start_pos: int, end_pos: int) -> None:
        """Copy-on-write guard before writing tokens [start_pos, end_pos):
        any shared page in that span is split so the shared copy is never
        mutated.  No-op over private pages."""
        if end_pos <= start_pos:
            return
        page = self.kv.page_tokens
        for idx in range(start_pos // page, (end_pos - 1) // page + 1):
            self.kv.make_private(req.request_id, idx)

    # -------------------------------------------------------------- prefill
    def _install_prefill(self, req: Request, tokens: List[int]) -> Any:
        """Monolithic prefill of ``tokens`` into the request's slot; returns
        the last-position logits."""
        arr = jnp.asarray(tokens, jnp.int32)[None]
        logits, caches = self._prefill(self.params, arr)
        # install the request's cache into its slot (unit leaves carry the
        # scan dim first → slot axis is 1; suffix/cross leaves → axis 0)
        slot = req.slot
        new = dict(self._caches)
        new["unit"] = jax.tree_util.tree_map(
            lambda s, o: s.at[:, slot].set(o[:, 0]),
            self._caches["unit"],
            caches["unit"],
        )
        new["suffix"] = jax.tree_util.tree_map(
            lambda s, o: s.at[slot].set(o[0]),
            self._caches["suffix"],
            caches["suffix"],
        )
        if "cross_kv" in self._caches:
            new["cross_kv"] = jax.tree_util.tree_map(
                lambda s, o: s.at[slot].set(o[0]),
                self._caches["cross_kv"],
                caches["cross_kv"],
            )
        self._caches = new
        req.pos = len(tokens)
        return logits[0, -1]

    def _finish_prefill(self, req: Request, last_logits) -> None:
        if req.generated:
            # replay after suspension/offload: the cache is rebuilt; the
            # next decode step feeds generated[-1] — nothing new to sample
            req.state = "decoding"
            return
        next_tok = int(jnp.argmax(last_logits))
        self._publish_prefix(req, next_tok)
        req.generated.append(next_tok)
        req.first_token_tick = self.tick
        req.state = "decoding"

    def _publish_prefix(self, req: Request, first_tok: int) -> None:
        """Insert a freshly prefilled prompt's pages into the trie and
        snapshot its slot KV so later identical/overlapping prompts skip
        prefill.  The request keeps decoding into its own pages: its first
        append into the now-shared terminal page copy-on-writes."""
        if not self.ecfg.prefix_cache or req.slot < 0:
            return
        feed = tuple(req.feed_tokens)
        inserted = self.kv.insert_prefix(
            req.request_id, feed, req.tenant, feed, float(self.tick)
        )
        if inserted and feed not in self._snaps:
            while len(self._snaps) >= self.ecfg.max_prefix_snapshots:
                # LRU: dict order is maintained by the touch in
                # _install_cached_prefix, so the head is the coldest
                self._snaps.pop(next(iter(self._snaps)))
            self._snaps[feed] = (
                self._extract_slot(req.slot),
                first_tok,
                len(feed),
            )

    def _install_cached_prefix(self, req: Request) -> None:
        """Skip prefill for trie-matched tokens: install the prefix's KV
        snapshot into the request's slot and continue from the first
        uncached token.  An exact-prompt hit finishes prefill outright —
        zero prefill compute, first token this tick."""
        snap = self._snaps.get(req.snap_key) if req.snap_key else None
        feed = req.feed_tokens
        if snap is None:
            # snapshot pruned between match and slot assignment: recompute
            # from scratch — writes into the still-shared pages COW first
            req.cached_tokens = 0
            req.snap_key = None
            return
        self._snaps[req.snap_key] = self._snaps.pop(req.snap_key)  # LRU touch
        caches_sub, first_tok, snap_len = snap
        self._install_slot(req.slot, caches_sub)
        matched = min(req.cached_tokens, len(feed))
        count = not req.hit_counted  # replays must not re-count dedup work
        if count:
            self.prefix_hits += 1
            req.hit_counted = True
        if matched >= len(feed) and snap_len == len(feed):
            req.pos = len(feed)
            if count:
                self.prefix_hit_tokens += len(feed)
            if req.generated:
                req.state = "decoding"  # replay: next decode feeds last tok
            else:
                req.generated.append(first_tok)
                req.first_token_tick = self.tick
                req.state = "decoding"
        else:
            # partial hit (or full-page hit needing last-position logits):
            # chunked prefill resumes at the first position whose logits or
            # KV the snapshot cannot provide
            req.pos = min(matched, len(feed) - 1)
            if count:
                self.prefix_hit_tokens += req.pos

    def _prefill_tick(self) -> None:
        """Consume up to ``prefill_chunk_tokens`` prompt tokens this tick.

        Short prompts take the monolithic fast path (one fused prefill
        call, same numerics as before); longer prompts start with one
        budget-sized monolithic chunk and continue through the single-slot
        decode path a chunk per tick — decode slots keep ticking in
        between, which is the whole point of chunked prefill.
        """
        budget = self.ecfg.prefill_chunk_tokens
        chunked = False
        for rid in list(self._slot_req):
            if rid is None:
                continue
            req = self.requests[rid]
            if req.state != "prefill":
                continue
            if not self.kv.resident(rid):
                self.stall_ticks += 1  # KV partly in host memory: wait
                continue
            if req.pos == 0 and req.cached_tokens > 0:
                # prefix-cache hit: KV for the matched tokens installs
                # from the snapshot — no prefill compute, no budget, so
                # this runs even when a long cold prefill drained the
                # budget (an exact hit must never queue behind compute)
                self._install_cached_prefix(req)
                if req.state != "prefill":
                    continue  # exact hit: first token already sampled
            if budget <= 0:
                continue  # compute paths below need budget; hits don't
            feed = req.feed_tokens
            if req.pos == 0:
                if len(feed) <= budget:
                    self.kv.grow_to(rid, len(feed))
                    self._cow_range(req, 0, len(feed))
                    logits = self._install_prefill(req, feed)
                    budget -= len(feed)
                    self._finish_prefill(req, logits)
                else:
                    # power-of-two first chunk: a partial leftover budget
                    # still starts the prompt (no starvation behind short
                    # traffic) while keeping the compiled shapes bounded
                    w = 1 << (budget.bit_length() - 1)
                    self.kv.grow_to(rid, w)
                    self._cow_range(req, 0, w)
                    self._install_prefill(req, feed[:w])
                    budget -= w
                    chunked = True
            else:
                take = min(budget, len(feed) - req.pos)
                budget -= take
                last = None
                if take > 0:
                    self.kv.grow_to(rid, req.pos + take)
                    self._cow_range(req, req.pos, req.pos + take)
                # power-of-two buckets: O(log chunk) dispatches per tick
                # and a bounded set of compiled scan widths
                while take > 0:
                    w = 1 << (take.bit_length() - 1)
                    toks = jnp.asarray(feed[req.pos:req.pos + w], jnp.int32)
                    last, self._caches = self._chunk_scan(
                        self.params, toks, self._caches, req.slot,
                        jnp.int32(req.pos),
                    )
                    req.pos += w
                    take -= w
                chunked = True
                if not req.prefilling and last is not None:
                    self._finish_prefill(req, last)
            self.kv.grow_to(req.request_id, max(req.pos, 1))
        if chunked:
            self.chunked_prefill_ticks += 1
        self._update_pool()

    # --------------------------------------------------------------- decode
    def _decode_tick(self) -> None:
        active = []
        for i, rid in enumerate(self._slot_req):
            if rid is None or self.requests[rid].state != "decoding":
                continue
            if not self.kv.resident(rid):
                # tokens on overflow pages live in host DRAM — attention
                # cannot read them; the request stalls until reclaim()
                self.stall_ticks += 1
                continue
            active.append((i, self.requests[rid]))
        if not active:
            return
        tokens = jnp.zeros((self.ecfg.n_slots, 1), jnp.int32)
        poss = jnp.zeros((self.ecfg.n_slots,), jnp.int32)
        mask = jnp.zeros((self.ecfg.n_slots,), jnp.bool_)
        for i, req in active:
            tokens = tokens.at[i, 0].set(req.generated[-1])
            poss = poss.at[i].set(req.pos)
            mask = mask.at[i].set(True)
        logits, self._caches = self._decode_all(
            self.params, tokens, self._caches, poss, mask
        )
        for i, req in active:
            req.pos += 1
            self.kv.grow_to(req.request_id, req.pos)
            # the KV write landed at position pos-1: if that page is shared
            # (an exact-prompt hit decoding past its cached terminal page),
            # split it first — shared pages are never mutated
            self.kv.make_private(
                req.request_id, (req.pos - 1) // self.kv.page_tokens
            )
            nxt = int(jnp.argmax(logits[i, 0]))
            req.generated.append(nxt)
            if req.done:
                self._finish(req)
        self._update_pool()

    def _finish(self, req: Request) -> None:
        req.state = "done"
        req.finish_tick = self.tick
        self.completed.append(req.request_id)
        self._live.pop(req.request_id, None)
        self._release_slot(req)
        self.pool.release_owner(req.request_id)
        self.kv.release(req.request_id)
        self.sampler.forget(req.request_id)
        rid = self.policy.on_task_complete(req.request_id)
        if rid is not None:
            self._resume(rid)

    # ----------------------------------------------------------------- policy
    def _policy_pass(self) -> None:
        active = self._active()
        for r in active:
            self.sampler.observe(
                r.request_id,
                processed_bytes=float(r.pos),
                total_bytes=float(r.total_tokens),
                live_bytes=self.kv.request_bytes(r.request_id),
                group=r.tenant,
            )
        stats = self.sampler.stats([r.request_id for r in active])
        # expose the online §III classification on each request
        for st in stats:
            self.requests[st.task_id].memory_model = st.model.value
        frozen = self.sampler.stats(
            [
                r.request_id
                for r in self._live.values()
                if r.state == "suspended"
            ]
        )
        decision = self.policy.propose(
            self.pool, stats, now=float(self.tick), suspended=frozen
        )
        for rid in decision.suspend:
            req = self.requests[rid]
            if req.state in ("decoding", "prefill"):
                req.state = "suspended"
                self.suspensions += 1
                self._release_slot(req)
        for rid in decision.resume:
            self._resume(rid)

    def _release_slot(self, req: Request) -> None:
        """Free the request's batch row (its KV pages stay accounted) — in
        a paged runtime batch rows are virtual, so a suspended request must
        not block admission of new work."""
        if req.slot >= 0:
            self._slot_req[req.slot] = None
            req.slot = -1

    def _resume(self, rid: str) -> None:
        req = self.requests.get(rid)
        if req is None:
            return
        if req.state == "suspended":
            # re-acquire a batch row; the slot cache is rebuilt by replay
            if rid not in self._restore:
                self._restore.append(rid)
        elif req.state == "offloaded" and req.reload_at == WAIT_FOR_RESUME:
            # swapped out while suspended: start the PCIe reload now
            req.reload_at = self.tick + self.ecfg.offload_reload_ticks

    # ----------------------------------------------------------------- tick
    def step(self) -> None:
        self._admit()
        self._prefill_tick()
        self._decode_tick()
        period_ticks = max(
            round(self.policy.period * self.ecfg.murs_period_ticks), 1
        )
        if self.tick % period_ticks == 0:
            self._policy_pass()
        self._resolve_overcommit()
        # offloaded requests finish their PCIe reload and queue for a batch
        # row.  reload_at == WAIT_FOR_RESUME means the request was swapped
        # out while suspended: it reloads only once the policy resumes it.
        for r in self._live.values():
            if (
                r.state == "offloaded"
                and r.reload_at != WAIT_FOR_RESUME
                and self.tick >= r.reload_at
                and r.request_id not in self._restore
            ):
                self._restore.append(r.request_id)
        self.kv.reclaim()
        if (
            self.ecfg.prefix_cache
            and self.kv.cache_evictions != self._pruned_at_evictions
        ):
            # drop KV snapshots no trie node references anymore
            live = self.kv.live_snap_keys()
            self._snaps = {k: v for k, v in self._snaps.items() if k in live}
            self._pruned_at_evictions = self.kv.cache_evictions
        self.tick += 1

    def _frozen_bytes(self) -> float:
        """Pool bytes held by swappable (suspended, not restoring) KV."""
        return sum(
            self.kv.request_bytes(r.request_id)
            for r in self._live.values()
            if r.state == "suspended" and r.request_id not in self._restore
        )

    def _swap_out_frozen(self) -> bool:
        """Swap the fattest SUSPENDED request's frozen KV to host DRAM.

        It is not being decoded, so moving it stalls nobody; it reloads
        when the policy resumes it.  Returns False when nothing is
        swappable (no suspended request holding pages).
        """
        suspended = [
            r
            for r in self._live.values()
            if r.state == "suspended"
            and r.request_id not in self._restore
            and self.kv.request_bytes(r.request_id) > 0.0
        ]
        if not suspended:
            return False
        victim = max(
            suspended, key=lambda r: self.kv.request_bytes(r.request_id)
        )
        self.kv.offload(victim.request_id)
        self.pool.release_owner(victim.request_id)
        victim.state = "offloaded"
        victim.offloads += 1
        victim.reload_at = WAIT_FOR_RESUME
        self.swap_outs += 1
        self.kv.reclaim()
        return True

    def _resolve_overcommit(self) -> None:
        """Restore HBM residency when the page pool is overcommitted.

        One path for every policy (no scheduler branches):

          1. swap out a SUSPENDED request's frozen KV first — it is not
             being decoded, so moving it to host DRAM stalls nobody; it
             reloads when the policy resumes it.  A proactive policy that
             suspends under pressure therefore sheds overcommit without
             ever interrupting running work.
          2. otherwise the stock spill: offload (or, with offload disabled,
             fail) the fattest ACTIVE request — the paper's Table III
             reactive path, which is all a pressure-oblivious policy has.
        """
        while (
            self.kv.overflow_pages > 0 or self.pool.used_fraction > 1.0
        ) and self.kv.evict_cache(1):
            # cold cached prefixes go first: dropping them stalls nobody
            # and frees pages an overflow entry can reclaim into
            self.kv.reclaim()
            self._update_pool()
        if not (self.kv.overflow_pages > 0 or self.pool.used_fraction > 1.0):
            return
        if self._swap_out_frozen():
            return
        victim = max(
            self._active(), key=lambda r: self.kv.request_bytes(r.request_id),
            default=None,
        )
        if victim is None:
            return
        if self.ecfg.offload_enabled and victim.state in ("decoding", "prefill"):
            # mid-prefill victims are offloadable too (chunked prefill keeps
            # requests in "prefill" across ticks): reload replays the prompt
            self.kv.offload(victim.request_id)
            self.pool.release_owner(victim.request_id)
            victim.state = "offloaded"
            victim.offloads += 1
            victim.reload_at = self.tick + self.ecfg.offload_reload_ticks
            self.reactive_offloads += 1
            self._release_slot(victim)
        else:
            victim.state = "failed"
            victim.finish_tick = self.tick
            self.failed.append(victim.request_id)
            self._live.pop(victim.request_id, None)
            self.pool.release_owner(victim.request_id)
            self.kv.release(victim.request_id)
            self.sampler.forget(victim.request_id)
            self.policy.drop(victim.request_id)
            self._release_slot(victim)
        self.kv.reclaim()

    def run(self, max_ticks: int = 1000) -> Dict[str, Any]:
        while self.tick < max_ticks:
            pending = self.queue or any(
                r.state in ("prefill", "decoding", "suspended", "offloaded")
                for r in self._live.values()
            )
            if not pending:
                break
            self.step()
        lat = [
            r.finish_tick - r.submit_tick
            for r in self.requests.values()
            if r.state == "done"
        ]
        ttft = [
            r.first_token_tick - r.submit_tick
            for r in self.requests.values()
            if r.first_token_tick >= 0
        ]
        prefix = dict(self.kv.prefix_stats())
        prefix["requests_hit"] = self.prefix_hits
        prefix["prefill_tokens_skipped"] = self.prefix_hit_tokens
        return {
            "policy": self.policy.name,
            "completed": len(self.completed),
            "failed": len(self.failed),
            "suspensions": self.suspensions,
            "peak_used_fraction": self.peak_used_fraction,
            "peak_demand_fraction": self.peak_demand_fraction,
            "offload_events": self.reactive_offloads,
            "swap_events": self.swap_outs,
            "host_transfers": self.kv.offload_events,
            "stall_ticks": self.stall_ticks,
            "mean_latency_ticks": sum(lat) / len(lat) if lat else None,
            "latency_ticks": sorted(lat),
            "ttft_ticks": sorted(ttft),
            "prefix_cache": prefix,
            "ticks": self.tick,
            "chunked_prefill_ticks": self.chunked_prefill_ticks,
            "tokens_generated": sum(
                len(r.generated) for r in self.requests.values()
            ),
            "memory_models": {
                r.request_id: r.memory_model for r in self.requests.values()
            },
        }
