"""Multi-tenant serving engine with MURS HBM-admission control.

The paper's scheduler compiled into a JAX serving runtime: multiple tenants
submit requests into one engine (one model, one HBM pool — the "service
mode" of MURS §II).  Each request is a MURS task:

    processed  = tokens consumed so far (prompt + generated)
    live bytes = its KV/state footprint from the PagedKVManager
    rate       = Δlive/Δtokens — measured online by the MURS Sampler, which
                 classifies full-attention decodes as linear, MLA as shallow-
                 linear, sliding-window/mamba as constant (paper §III models)

Every ``period`` ticks the MursScheduler runs Algorithm 1 against the pool:
requests proposed for suspension stop being scheduled (their KV stays
resident — exactly Spark's suspended tasks); one suspended request resumes
per completion (FIFO, starvation-free) and all resume when pressure drops
below yellow.  The red band triggers ComputeSpill: offload-avoidance by
parallelism reduction.  The FAIR baseline schedules round-robin and, like
stock Spark, OOMs/offloads when the pool runs dry.

Decode runs slot-batched: one jitted vmapped decode step advances every
active slot per tick with per-slot positions.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.memory_manager import MemoryPool
from repro.core.sampler import Sampler
from repro.core.scheduler import MursConfig, MursScheduler
from repro.models import decode_step, init_cache, prefill
from repro.serve.kv_cache import PagedKVManager


@dataclass
class Request:
    request_id: str
    tenant: str
    prompt: List[int]
    max_new_tokens: int
    submit_tick: int = 0
    slot: int = -1
    pos: int = 0  # tokens materialized in the cache so far
    generated: List[int] = field(default_factory=list)
    state: str = "queued"  # queued|prefill|decoding|suspended|offloaded|done|failed
    finish_tick: int = -1
    #: MURS §III classification of this request's memory behaviour, as
    #: measured online by the sampler (constant/sub_linear/linear/super_linear)
    memory_model: str = "constant"
    reload_at: int = -1  # tick when an offloaded request finishes reloading
    offloads: int = 0

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new_tokens

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclass
class EngineConfig:
    n_slots: int = 4
    max_seq: int = 128
    hbm_capacity_bytes: float = 1e6  # KV pool budget (simulated pressure)
    scheduler: Optional[MursConfig] = None  # None → FAIR baseline
    murs_period_ticks: int = 1
    greedy: bool = True
    #: host-DRAM offload ("spill") instead of hard failure when the pool
    #: overcommits; reloading costs this many ticks per offloaded request
    offload_enabled: bool = True
    offload_reload_ticks: int = 8


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig) -> None:
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.pool = MemoryPool(capacity=ecfg.hbm_capacity_bytes)
        self.kv = PagedKVManager(capacity_bytes=ecfg.hbm_capacity_bytes)
        self.murs = (
            MursScheduler(ecfg.scheduler) if ecfg.scheduler is not None else None
        )
        self.sampler = Sampler()
        self.tick = 0
        self.queue: List[Request] = []
        self.requests: Dict[str, Request] = {}
        self.failed: List[str] = []
        self.completed: List[str] = []
        self.suspensions = 0
        self.peak_used_fraction = 0.0

        # slot-batched decode state.  Cache layout quirk: "unit" leaves are
        # scan-stacked [reps, batch, ...] (batch on axis 1) while "suffix"
        # (and cross_kv) leaves are [batch, ...] — vmap axes and the
        # batch-insert/strip helpers below account for that.
        self._caches = init_cache(cfg, ecfg.n_slots, ecfg.max_seq)
        self._slot_req: List[Optional[str]] = [None] * ecfg.n_slots

        def _cache_axes(caches):
            axes = {
                "unit": jax.tree_util.tree_map(lambda _: 1, caches["unit"]),
                "suffix": jax.tree_util.tree_map(
                    lambda _: 0, caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                axes["cross_kv"] = jax.tree_util.tree_map(
                    lambda _: 0, caches["cross_kv"]
                )
            return axes

        def _add_batch(caches):
            out = {
                "unit": jax.tree_util.tree_map(
                    lambda x: x[:, None], caches["unit"]
                ),
                "suffix": jax.tree_util.tree_map(
                    lambda x: x[None], caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                out["cross_kv"] = jax.tree_util.tree_map(
                    lambda x: x[None], caches["cross_kv"]
                )
            return out

        def _strip_batch(caches):
            out = {
                "unit": jax.tree_util.tree_map(
                    lambda x: x[:, 0], caches["unit"]
                ),
                "suffix": jax.tree_util.tree_map(
                    lambda x: x[0], caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                out["cross_kv"] = jax.tree_util.tree_map(
                    lambda x: x[0], caches["cross_kv"]
                )
            return out

        def _one_slot_decode(params, token, caches, pos):
            logits, new_caches = decode_step(
                cfg, params, token[None], _add_batch(caches), pos
            )
            return logits[0], _strip_batch(new_caches)

        self._decode_all = jax.jit(
            jax.vmap(
                _one_slot_decode,
                in_axes=(None, 0, _cache_axes(self._caches), 0),
                out_axes=(0, _cache_axes(self._caches)),
            ),
            donate_argnums=(2,),
        )
        self._prefill = jax.jit(
            lambda params, tokens: prefill(
                cfg, params, tokens, max_seq=ecfg.max_seq, remat=False
            )
        )

    # ------------------------------------------------------------- tenants
    def submit(self, req: Request) -> None:
        req.submit_tick = self.tick
        self.queue.append(req)
        self.requests[req.request_id] = req

    # ------------------------------------------------------------ accounting
    def _update_pool(self) -> None:
        for rid, req in self.requests.items():
            if req.state in ("prefill", "decoding", "suspended"):
                self.pool.set_live(rid, self.kv.request_bytes(rid))
        self.peak_used_fraction = max(
            self.peak_used_fraction, self.pool.used_fraction
        )

    def _active(self) -> List[Request]:
        return [
            r
            for r in self.requests.values()
            if r.state in ("prefill", "decoding")
        ]

    # ------------------------------------------------------------ admission
    def _admit(self) -> None:
        free_slots = [i for i, r in enumerate(self._slot_req) if r is None]
        while self.queue and free_slots:
            req = self.queue[0]
            new_bytes = (
                self.kv._page_bytes.get(req.request_id)
                or 0.0
            )
            # capacity check: would this request's prompt fit right now?
            self.kv.register(req.request_id, self.cfg)
            prompt_bytes = self.kv.grow_to(req.request_id, len(req.prompt))
            if (
                self.pool.used_bytes + prompt_bytes
                > self.pool.capacity
            ):
                # no headroom: FAIR fails the request (OOM semantics);
                # MURS leaves it queued (admission control)
                self.kv.release(req.request_id)
                if self.murs is None:
                    self.queue.pop(0)
                    req.state = "failed"
                    req.finish_tick = self.tick
                    self.failed.append(req.request_id)
                    continue
                break
            self.queue.pop(0)
            slot = free_slots.pop(0)
            req.slot = slot
            self._slot_req[slot] = req.request_id
            self._run_prefill(req)

    def _run_prefill(self, req: Request) -> None:
        req.state = "prefill"
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, caches = self._prefill(self.params, tokens)
        # install the request's cache into its slot (unit leaves carry the
        # scan dim first → slot axis is 1; suffix/cross leaves → axis 0)
        slot = req.slot
        new = dict(self._caches)
        new["unit"] = jax.tree_util.tree_map(
            lambda s, o: s.at[:, slot].set(o[:, 0]),
            self._caches["unit"],
            caches["unit"],
        )
        new["suffix"] = jax.tree_util.tree_map(
            lambda s, o: s.at[slot].set(o[0]),
            self._caches["suffix"],
            caches["suffix"],
        )
        if "cross_kv" in self._caches:
            new["cross_kv"] = jax.tree_util.tree_map(
                lambda s, o: s.at[slot].set(o[0]),
                self._caches["cross_kv"],
                caches["cross_kv"],
            )
        self._caches = new
        req.pos = len(req.prompt)
        next_tok = int(jnp.argmax(logits[0, -1]))
        req.generated.append(next_tok)
        req.state = "decoding"
        self._update_pool()

    # --------------------------------------------------------------- decode
    def _decode_tick(self) -> None:
        active = [
            (i, self.requests[rid])
            for i, rid in enumerate(self._slot_req)
            if rid is not None and self.requests[rid].state == "decoding"
        ]
        if not active:
            return
        tokens = jnp.zeros((self.ecfg.n_slots, 1), jnp.int32)
        poss = jnp.zeros((self.ecfg.n_slots,), jnp.int32)
        for i, req in active:
            tokens = tokens.at[i, 0].set(req.generated[-1])
            poss = poss.at[i].set(req.pos)
        logits, self._caches = self._decode_all(
            self.params, tokens, self._caches, poss
        )
        for i, req in active:
            req.pos += 1
            self.kv.grow_to(req.request_id, req.pos)
            nxt = int(jnp.argmax(logits[i, 0]))
            req.generated.append(nxt)
            if req.done:
                self._finish(req)
        self._update_pool()

    def _finish(self, req: Request) -> None:
        req.state = "done"
        req.finish_tick = self.tick
        self.completed.append(req.request_id)
        self._slot_req[req.slot] = None
        self.pool.release_owner(req.request_id)
        self.kv.release(req.request_id)
        self.sampler.forget(req.request_id)
        if self.murs is not None:
            rid = self.murs.on_task_complete()
            if rid is not None:
                self._resume(rid)

    # ----------------------------------------------------------------- MURS
    def _murs_pass(self) -> None:
        assert self.murs is not None
        active = self._active()
        for r in active:
            self.sampler.observe(
                r.request_id,
                processed_bytes=float(r.pos),
                total_bytes=float(r.total_tokens),
                live_bytes=self.kv.request_bytes(r.request_id),
            )
        stats = self.sampler.stats([r.request_id for r in active])
        # expose the online §III classification on each request
        for st in stats:
            self.requests[st.task_id].memory_model = st.model.value
        frozen = self.sampler.stats(
            [
                r.request_id
                for r in self.requests.values()
                if r.state == "suspended"
            ]
        )
        decision = self.murs.propose(
            self.pool, stats, now=float(self.tick), suspended=frozen
        )
        for rid in decision.suspend:
            req = self.requests[rid]
            if req.state == "decoding":
                req.state = "suspended"
                self.suspensions += 1
        for rid in decision.resume:
            self._resume(rid)

    def _resume(self, rid: str) -> None:
        req = self.requests.get(rid)
        if req is not None and req.state == "suspended":
            req.state = "decoding"

    # ----------------------------------------------------------------- tick
    def step(self) -> None:
        self._admit()
        self._decode_tick()
        if self.murs is not None and self.tick % self.ecfg.murs_period_ticks == 0:
            self._murs_pass()
        # pool overcommitted → the stock path: OFFLOAD the fattest request's
        # pages to host DRAM (the TPU "spill", paper Table III) when enabled,
        # else evict/fail.  MURS's suspension keeps usage below this line —
        # "avoiding the spill" (§VI-E) — but the guard applies to both.
        if self.murs is None and self.pool.used_fraction > 1.0:
            victim = max(
                self._active(), key=lambda r: self.kv.request_bytes(r.request_id),
                default=None,
            )
            if victim is not None:
                if self.ecfg.offload_enabled and victim.state == "decoding":
                    self.kv.offload(victim.request_id)
                    self.pool.release_owner(victim.request_id)
                    victim.state = "offloaded"
                    victim.offloads += 1
                    victim.reload_at = self.tick + self.ecfg.offload_reload_ticks
                else:
                    victim.state = "failed"
                    victim.finish_tick = self.tick
                    self.failed.append(victim.request_id)
                    self._slot_req[victim.slot] = None
                    self.pool.release_owner(victim.request_id)
                    self.kv.release(victim.request_id)
        # offloaded requests finish their PCIe reload and re-register
        for r in self.requests.values():
            if r.state == "offloaded" and self.tick >= r.reload_at:
                self.kv.register(r.request_id, self.cfg)
                self.kv.grow_to(r.request_id, r.pos)
                r.state = "decoding"
                self._update_pool()
        self.tick += 1

    def run(self, max_ticks: int = 1000) -> Dict[str, Any]:
        while self.tick < max_ticks:
            pending = self.queue or any(
                r.state in ("prefill", "decoding", "suspended", "offloaded")
                for r in self.requests.values()
            )
            if not pending:
                break
            self.step()
        lat = [
            r.finish_tick - r.submit_tick
            for r in self.requests.values()
            if r.state == "done"
        ]
        return {
            "completed": len(self.completed),
            "failed": len(self.failed),
            "suspensions": self.suspensions,
            "peak_used_fraction": self.peak_used_fraction,
            "offload_events": self.kv.offload_events,
            "mean_latency_ticks": sum(lat) / len(lat) if lat else None,
            "ticks": self.tick,
            "tokens_generated": sum(
                len(r.generated) for r in self.requests.values()
            ),
            "memory_models": {
                r.request_id: r.memory_model for r in self.requests.values()
            },
        }
