"""Multi-tenant continuous-batching serving engine on the policy layer.

The paper's scheduler compiled into a JAX serving runtime: multiple tenants
submit requests into one engine (one model, one HBM pool — the "service
mode" of MURS §II).  Each request is a task of the pluggable
:class:`repro.sched.SchedulingPolicy`:

    processed  = tokens consumed so far (prompt + generated)
    live bytes = its KV/state footprint from the PagedKVManager
    rate       = Δlive/Δtokens — measured online by the MURS Sampler, which
                 classifies full-attention decodes as linear, MLA as shallow-
                 linear, sliding-window/mamba as constant (paper §III models)

Every ``period`` ticks the policy runs against the pool: requests proposed
for suspension stop being scheduled (their KV pages stay resident — exactly
Spark's suspended tasks); one suspended request resumes per completion
(FIFO, starvation-free under MURS) and all resume when pressure drops below
yellow.  :class:`FairPolicy` is the stock baseline: no pressure response,
so the engine's reactive path (page-granular demotion of running work, or
hard failure when demotion is disabled) fires when the pool overcommits.
Admission is uniform — every policy queues at the door; what differs is
the admission line (``admission_headroom``) and how fast headroom appears
(a suspending policy demotes frozen KV to the host tier, a
pressure-oblivious one waits for completions or pays the reactive path).

TIERED KV (:mod:`repro.serve.tiers`): below the HBM page pool sit a host
tier with REAL capacity and int8-compressed page storage
(``repro.dist.compression.quantize``/``dequantize`` — the page's actual KV
values round-trip through the codes), and a disk tier whose traffic is the
paper's "data spilling" metric.  Demotion and promotion are page-granular
and ASYNCHRONOUS over a modeled PCIe link (latency ∝ compressed bytes, so
compression directly buys ticks): suspended-frozen pages and cold cached
prefixes demote individually while decode continues on resident pages — a
request stalls only when it is actually scheduled against a non-resident
page.  ``SchedulingPolicy.demotion_pressure(group)`` (sibling of
``cache_pressure``) lets :class:`MursPolicy` demote low-usage-rate
tenants' frozen KV *proactively*, before the reactive spill path fires —
the mechanism behind the paper's ~90% spill reduction.

The hot loop is CONTINUOUS BATCHING with CHUNKED PREFILL: prompts are
consumed in token-budgeted chunks (``prefill_chunk_tokens`` per tick)
interleaved with decode ticks, so one long prompt never stalls every
in-flight decode the way a monolithic prefill call does.  Decode runs
slot-batched: one jitted vmapped decode step advances every active slot per
tick with per-slot positions; prefill continuation shares the same cache
layout through a single-slot jitted step.  KV lives in the paged pool of
:class:`PagedKVManager` — free-list block allocator, per-request page
tables, the same tables the Pallas ``paged_decode`` kernel consumes.

PREFIX SHARING: admission matches each prompt against the pool's token
trie (:class:`repro.serve.kv_cache.PrefixCache`).  Matched pages are
acquired by reference (refcount + 1, zero new bytes) and their KV is
installed from a snapshot taken when the prefix was first prefetched —
prefill compute is SKIPPED for cached tokens; chunked prefill starts at
the first uncached token.  Any later append into a shared page goes
through copy-on-write, so a shared page is never mutated.  Cold cached
prefixes evict under pressure in LRU order crossed with the policy's
``cache_pressure`` hint (MURS: low-usage-rate tenants first).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.memory_manager import MemoryPool
from repro.core.sampler import Sampler
from repro.sched import FairPolicy, MursConfig, MursPolicy, SchedulingPolicy
from repro.models import (
    decode_step,
    decode_step_paged,
    init_cache,
    paged_decode_supported,
    prefill,
)
from repro.roofline.analysis import tick_cost_model
from repro.serve.kv_cache import (
    CACHE_OWNER,
    DEMOTED,
    PagedKVManager,
)
from repro.serve.ledger import PageClass, PressurePlan
from repro.serve.report import (
    COMPLETED,
    FAILED,
    UNFINISHED,
    RequestOutcome,
    ServeReport,
)
from repro.serve.tiers import TierConfig, wire_bytes_for


@dataclass
class Request:
    """One serving request: a prompt, a decode budget, and the engine's
    working state (slot, materialized position, generated tokens).

    The engine mutates the instance in place as it moves through the
    lifecycle — submit fresh objects per run."""

    request_id: str
    tenant: str
    prompt: List[int]
    max_new_tokens: int
    submit_tick: int = 0
    slot: int = -1
    pos: int = 0  # tokens materialized in the cache so far
    generated: List[int] = field(default_factory=list)
    # queued|prefill|decoding|suspended|offloaded|importing|done|failed
    state: str = "queued"
    finish_tick: int = -1
    #: MURS §III classification of this request's memory behaviour, as
    #: measured online by the sampler (constant/sub_linear/linear/super_linear)
    memory_model: str = "constant"
    offloads: int = 0  # times this request was a reactive-demotion victim
    #: prompt tokens covered by a prefix-cache match (0 = cold)
    cached_tokens: int = 0
    #: KV-snapshot key of the matched prefix (the caching prompt's tokens)
    snap_key: Optional[Tuple[int, ...]] = None
    first_token_tick: int = -1  # tick the first generated token appeared
    #: engine hit counters already incremented for this request — a
    #: suspend/resume replay re-installs the snapshot but must not
    #: re-count the dedup'd prefill work
    hit_counted: bool = False
    #: why the request failed ("" while not failed) — surfaced in the
    #: ServeReport outcome row
    fail_reason: str = ""
    #: arch name this request targets ("" → whatever model the engine
    #: that first sees it serves).  In a heterogeneous fleet the cluster
    #: router only places the request on replicas hosting this model;
    #: an engine handed a request for a model it does not serve fails it
    #: with a typed ``wrong_model`` outcome instead of silently decoding
    #: through the wrong weights
    model: str = ""

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + self.max_new_tokens

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def feed_tokens(self) -> List[int]:
        """Every token whose KV must be materialized before the next decode
        step: the prompt plus all generated tokens but the last (which is
        fed BY the next decode step).  This is also the replay sequence
        that rebuilds a slot cache after suspension moved the request out
        of its batch row."""
        if self.generated:
            return self.prompt + self.generated[:-1]
        return self.prompt

    @property
    def prefilling(self) -> bool:
        return self.pos < len(self.feed_tokens)


@dataclass
class MigrationTicket:
    """A request's portable state, as extracted by
    :meth:`ServingEngine.export_request` — everything another replica
    needs to continue it:

    * ``request`` — the :class:`Request` itself (tokens generated so far,
      position, tenant);
    * ``slot_cache`` — the full slot cache subtree
      (:meth:`ServingEngine._extract_slot`) when the request still held a
      batch row: bit-exact, so the target continues with identical
      numerics;
    * ``page_payloads`` — per-page KV values (frozen-payload captures and
      dequantized tier blocks) for slotless requests; complete coverage
      lets the target install pages instead of replaying prefill;
    * ``raw_bytes`` / ``wire_bytes`` — the migration's traffic accounting
      (wire = compressed bytes that cross the inter-replica link);
    * ``full_wire_bytes`` / ``precopy_wire_bytes`` / ``delta_pages`` —
      filled only by a DELTA cutover (``export_request`` with a
      ``baseline`` pre-copy): what a monolithic full copy would have
      shipped at cutover, what the pre-copy already shipped while the
      source kept serving, and how many dirty pages the delta re-sent
      (DESIGN.md §11).
    """

    request: Request
    slot_cache: Optional[Dict[str, Any]] = None
    page_payloads: Dict[int, np.ndarray] = field(default_factory=dict)
    raw_bytes: float = 0.0
    wire_bytes: float = 0.0
    source_tick: int = 0
    full_wire_bytes: float = 0.0
    precopy_wire_bytes: float = 0.0
    delta_pages: int = 0


@dataclass
class PrecopySnapshot:
    """Phase one of an incremental (delta) migration: a copy of the
    request's resident page payloads taken at ``epoch`` WHILE THE SOURCE
    KEEPS SERVING the request.  The cluster ships these bytes in the
    background; at cutover :meth:`ServingEngine.export_request` receives
    the snapshot as its ``baseline`` and re-ships only the pages the
    write-epoch ledger (:meth:`PagedKVManager.pages_written_since`) says
    changed after ``epoch`` — the dirty delta (DESIGN.md §11)."""

    request_id: str
    epoch: int
    payloads: Dict[int, np.ndarray] = field(default_factory=dict)
    raw_bytes: float = 0.0
    wire_bytes: float = 0.0


class _AdmissionQueue:
    """The engine's admission queue, indexed for O(1) membership and
    O(tenants) per-tick policy input instead of an O(queue) rebuild.

    Semantics match the plain list it replaced exactly: iteration yields
    requests in arrival order, and :meth:`tenant_counts` presents tenants
    in the order of their OLDEST queued request — the same key order the
    legacy ``by_tenant`` dict had, which :meth:`BasePolicy.assign`'s
    persistent round-robin cursor is sensitive to.  Per-request sequence
    numbers (monotonic, never reused) make that ordering survive
    mid-queue removals, where a naive per-tenant dict would not.
    """

    def __init__(self) -> None:
        self._order: Dict[str, Request] = {}  # rid → request, arrival order
        self._seq: Dict[str, int] = {}  # rid → global arrival sequence
        self._by_tenant: Dict[str, Dict[str, Request]] = {}
        self._next_seq = 0

    def append(self, req: Request) -> None:
        rid = req.request_id
        self._order[rid] = req
        self._seq[rid] = self._next_seq
        self._next_seq += 1
        self._by_tenant.setdefault(req.tenant, {})[rid] = req

    def remove(self, req: Request) -> None:
        rid = req.request_id
        del self._order[rid]
        del self._seq[rid]
        bucket = self._by_tenant[req.tenant]
        del bucket[rid]
        if not bucket:
            del self._by_tenant[req.tenant]

    def head(self, tenant: str) -> Optional[Request]:
        bucket = self._by_tenant.get(tenant)
        if not bucket:
            return None
        return next(iter(bucket.values()))

    def tenant_counts(self, exclude: Any = ()) -> Dict[str, int]:
        """``{tenant: queued}`` keyed in oldest-head-request order."""
        rows = []
        for tenant, bucket in self._by_tenant.items():
            if tenant in exclude:
                continue
            rows.append((self._seq[next(iter(bucket))], tenant, len(bucket)))
        rows.sort()
        return {tenant: n for _, tenant, n in rows}

    def __contains__(self, req: Request) -> bool:
        return req.request_id in self._order

    def __iter__(self):
        return iter(self._order.values())

    def __len__(self) -> int:
        return len(self._order)

    def __bool__(self) -> bool:
        return bool(self._order)


@dataclass
class EngineConfig:
    """Engine knobs: pool size, policy, tiering, kernels (see
    docs/OPERATIONS.md for the tuning guide)."""

    n_slots: int = 4
    max_seq: int = 128
    hbm_capacity_bytes: float = 1e6  # KV pool budget (simulated pressure)
    #: scheduling policy instance; None → resolved from ``scheduler``
    policy: Optional[SchedulingPolicy] = None
    #: legacy spelling: a MursConfig → MursPolicy, None → FairPolicy
    scheduler: Optional[MursConfig] = None
    #: engine ticks per unit of the policy's ``period`` — the seasonal
    #: pass runs every ``round(policy.period * murs_period_ticks)`` ticks
    murs_period_ticks: int = 1
    greedy: bool = True
    #: prefill token budget per engine tick — prompts longer than this are
    #: split into chunks interleaved with decode ticks (continuous batching)
    prefill_chunk_tokens: int = 64
    #: demote running work to the tier hierarchy instead of hard failure
    #: when the pool overcommits (False → OOM semantics, the paper's OME)
    offload_enabled: bool = True
    #: host-tier capacity for demoted pages (bytes AT REST, compressed);
    #: None → 4× the HBM pool
    host_capacity_bytes: Optional[float] = None
    #: HBM↔host link rate in bytes/tick; None → hbm_capacity/8 (a 1/8-pool
    #: transfer per tick) — compression halves the bytes that cross it
    pcie_bytes_per_tick: Optional[float] = None
    #: disk→host read rate; None → a quarter of the PCIe rate
    disk_bytes_per_tick: Optional[float] = None
    #: int8-compress demoted pages in the host tier
    tier_compress: bool = True
    #: pool fraction above which the engine PROACTIVELY demotes (frozen
    #: KV of tenants the policy's ``demotion_pressure`` marks, then cold
    #: cached pages) — the "before the reactive path" knob.  The default
    #: sits just ABOVE MursPolicy's red line (0.8): out of the box only
    #: excursions past it trigger demotion, so resumes rarely wait on
    #: promotion DMAs; deployments that want eager tiering (the
    #: benchmark's proactive leg) lower it to the policy's band
    demote_threshold: float = 0.85
    #: max page demotions initiated per proactive pass (bounds churn)
    demote_batch_pages: int = 4
    #: the reactive path frees DOWN TO this pool fraction, not merely out
    #: of overcommit: stopping at exactly-full leaves zero free pages, so
    #: promotions (and therefore every stalled victim) starve — the
    #: classic all-slots-stalled wedge.  Only applies when demotion is
    #: enabled; the hard-failure path still fires on true overcommit.
    reactive_watermark: float = 0.9
    #: prefix-sharing paged KV cache: admission matches prompts against the
    #: token trie, cached pages are shared by refcount (COW on append) and
    #: prefill is skipped up to the first uncached token
    prefix_cache: bool = True
    #: use the pre-vectorization O(live)-per-tick bookkeeping scans
    #: (full pool rescan, projected-demand resummation, state sweeps)
    #: instead of the incremental dirty-set/counter paths.  Semantics are
    #: identical by construction; the flag exists so the benchmark can
    #: measure the ticks/sec delta honestly
    legacy_bookkeeping: bool = False
    #: decode through the paged Pallas kernel when the architecture
    #: qualifies (pure full-attention stacks — see
    #: ``models.paged_decode_supported``): all active rows batch their
    #: live page tables into ONE ``paged_decode_attention`` call per
    #: layer.  False keeps the dense vmapped decode as a differential
    #: oracle (same spirit as ``legacy_bookkeeping``): identical greedy
    #: tokens by construction, so tests can diff the two paths
    paged_decode: bool = True
    #: quantize the paged KV pools to int8 (per pool-row absmax scales)
    #: and decode through ``paged_decode_attention_int8``.  Off by
    #: default: the f32 ``paged_decode_attention`` path stays the
    #: differential oracle (tests diff the two).  Only takes effect when
    #: ``paged_decode`` is active for the architecture.
    paged_decode_int8: bool = False
    #: run the Pallas kernel in interpret mode (Python emulation, what CPU
    #: CI exercises); None → auto: interpret everywhere except a real TPU
    #: backend, where the kernel compiles to Mosaic
    kernel_interpret: Optional[bool] = None
    #: host-side KV snapshots backing prefill-skip, LRU-bounded so a
    #: long-lived engine serving many distinct prompts cannot grow host
    #: memory without bound (each snapshot is one slot's full cache
    #: subtree).  Beyond the bound, matches on snapshot-less trie nodes
    #: still dedup pages — they just recompute the prefill (COW-guarded).
    max_prefix_snapshots: int = 64

    def resolve_policy(self) -> SchedulingPolicy:
        """The configured policy instance: ``policy`` wins, a legacy
        ``scheduler`` config wraps into MursPolicy, else FairPolicy."""
        if self.policy is not None and self.scheduler is not None:
            raise ValueError("pass either policy= or scheduler=, not both")
        if self.policy is not None:
            return self.policy
        if self.scheduler is not None:
            return MursPolicy(self.scheduler)
        return FairPolicy()


class ServingEngine:
    """One replica: continuous-batching paged serving over a single
    simulated HBM pool (DESIGN.md §2), scheduled through a pluggable
    :class:`~repro.sched.protocol.SchedulingPolicy`."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig) -> None:
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        #: the model this replica hosts, as an explicit spec (arch id +
        #: memory class + byte model) — every byte-accounting and
        #: migration gate below keys off this, not an implicit global
        self.spec = cfg.spec()
        self.pool = MemoryPool(capacity=ecfg.hbm_capacity_bytes)
        pcie = (
            ecfg.pcie_bytes_per_tick
            if ecfg.pcie_bytes_per_tick is not None
            else max(ecfg.hbm_capacity_bytes / 8.0, 1.0)
        )
        self.kv = PagedKVManager(
            capacity_bytes=ecfg.hbm_capacity_bytes,
            enable_prefix_cache=ecfg.prefix_cache,
            tier_config=TierConfig(
                host_capacity_bytes=(
                    ecfg.host_capacity_bytes
                    if ecfg.host_capacity_bytes is not None
                    else 4.0 * ecfg.hbm_capacity_bytes
                ),
                pcie_bytes_per_tick=pcie,
                disk_bytes_per_tick=(
                    ecfg.disk_bytes_per_tick
                    if ecfg.disk_bytes_per_tick is not None
                    else max(pcie / 4.0, 1.0)
                ),
                compress=ecfg.tier_compress,
            ),
        )
        self.policy: SchedulingPolicy = ecfg.resolve_policy()
        # eviction order consults the active policy's pressure plan:
        # LRU × the plan's COLD_CACHED score (the scores close over the
        # policy's live rate state, so binding the plan once is safe)
        _wiring_plan = self.policy.pressure()
        self.kv.cache_pressure_fn = lambda g: _wiring_plan.score(
            PageClass.COLD_CACHED, g
        )
        self.sampler = Sampler()
        self.tick = 0
        self.queue = _AdmissionQueue()
        self._restore: List[str] = []  # resumed/reloaded, waiting for a slot
        self.requests: Dict[str, Request] = {}  # full history (lookup/report)
        #: not-yet-terminal requests — every per-tick scan walks this, so
        #: tick cost is bounded by the in-flight set, not request history
        self._live: Dict[str, Request] = {}
        # ---- incremental bookkeeping (kept in BOTH modes; the
        # legacy_bookkeeping flag only selects which representation the
        # read paths consult)
        #: state → live request ids in that state (terminal states are
        #: dropped with the request) — O(1) counts for has_pending,
        #: replica_stats and the per-tick active-slot cost
        self._state_ids: Dict[str, set] = {}
        # (projected-demand bookkeeping lives in the KV manager's
        # MemoryLedger — note_projection/drop_projection in _track_live /
        # _drop_live; the front door's group_demand reads it there)
        #: rids whose state changed since the last pool sync — merged
        #: with the KV manager's allocator dirty set in _update_pool
        self._pool_dirty: set = set()
        self._submitted = 0  # every submission this engine ever accepted
        self.failed: List[str] = []
        self.completed: List[str] = []
        self.suspensions = 0
        self.peak_used_fraction = 0.0
        #: like peak_used_fraction but net of RECLAIMABLE bytes (cold
        #: cached prefixes are one evict_cache() from free — the page-cache
        #: notion of available memory); this is the dedup'd live demand
        self.peak_demand_fraction = 0.0
        self.chunked_prefill_ticks = 0
        self.reactive_offloads = 0  # reactive-demotion victims (stock path)
        self.swap_outs = 0  # frozen (suspended) pages demoted to the tiers
        self.proactive_demotions = 0  # pages demoted by the policy hint
        self.stall_ticks = 0  # request-ticks lost to non-resident KV
        self.transfer_stall_ticks = 0  # … of which waiting on tier DMA
        #: per-page KV payloads captured when a request froze (slot still
        #: attached) — handed to the host tier when its pages demote, so
        #: the int8 round-trip compresses REAL values, not placeholders
        self._frozen_payloads: Dict[str, Dict[int, np.ndarray]] = {}
        self.prefix_hits = 0  # requests that skipped prefill via the trie
        self.prefix_hit_tokens = 0  # prompt tokens whose prefill was skipped
        #: migrated-in requests waiting for a batch row to land in
        #: (rid → ticket); their KV installs from the ticket, not replay
        self._imports: Dict[str, MigrationTicket] = {}
        self.migrations_in = 0
        self.migrations_out = 0
        #: requests submitted here that declared a DIFFERENT model — each
        #: is failed with a typed ``wrong_model`` outcome (the router
        #: should never let this happen; the counter is the evidence)
        self.misroutes = 0
        #: modeled cost of the last step() in SECONDS — the replica's tick
        #: service time a cluster's straggler pass observes.  Derived from
        #: the roofline (weight stream + KV pages touched over HBM
        #: bandwidth vs FLOPs over peak, plus PCIe stall DMAs), not
        #: hand-set constants; deterministic, no wall clock.
        self._tick_cost_model = tick_cost_model(
            cfg, page_tokens=self.kv.page_tokens
        )
        self.last_tick_cost = self._tick_cost_model.idle_s
        self._tick_cost_count = 0
        self._tick_cost_sum = 0.0
        self._tick_cost_min = float("inf")
        self._tick_cost_max = 0.0
        self._tick_cost_values: set = set()  # bounded distinct sample
        self._tick_prefill_tokens = 0
        self._tick_decode_tokens = 0
        #: KV snapshots backing cached prefixes: snap_key (the caching
        #: prompt's token tuple) → (slot cache subtree, first greedy token,
        #: snapshot length).  Pruned when the trie evicts the last node
        #: referencing a snapshot.
        self._snaps: Dict[Tuple[int, ...], Tuple[Any, int, int]] = {}
        self._pruned_at_evictions = 0

        # slot-batched decode state.  Cache layout quirk: "unit" leaves are
        # scan-stacked [reps, batch, ...] (batch on axis 1) while "suffix"
        # (and cross_kv) leaves are [batch, ...] — vmap axes and the
        # batch-insert/strip helpers below account for that.
        self._caches = init_cache(cfg, ecfg.n_slots, ecfg.max_seq)
        self._slot_req: List[Optional[str]] = [None] * ecfg.n_slots

        def _cache_axes(caches):
            axes = {
                "unit": jax.tree_util.tree_map(lambda _: 1, caches["unit"]),
                "suffix": jax.tree_util.tree_map(
                    lambda _: 0, caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                axes["cross_kv"] = jax.tree_util.tree_map(
                    lambda _: 0, caches["cross_kv"]
                )
            return axes

        def _add_batch(caches):
            out = {
                "unit": jax.tree_util.tree_map(
                    lambda x: x[:, None], caches["unit"]
                ),
                "suffix": jax.tree_util.tree_map(
                    lambda x: x[None], caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                out["cross_kv"] = jax.tree_util.tree_map(
                    lambda x: x[None], caches["cross_kv"]
                )
            return out

        def _strip_batch(caches):
            out = {
                "unit": jax.tree_util.tree_map(
                    lambda x: x[:, 0], caches["unit"]
                ),
                "suffix": jax.tree_util.tree_map(
                    lambda x: x[0], caches["suffix"]
                ),
            }
            if "cross_kv" in caches:
                out["cross_kv"] = jax.tree_util.tree_map(
                    lambda x: x[0], caches["cross_kv"]
                )
            return out

        def _one_slot_decode(params, token, caches, pos, active):
            logits, new_caches = decode_step(
                cfg, params, token[None], _add_batch(caches), pos
            )
            # inactive slots (mid-chunked-prefill, stalled, suspended-but-
            # slotted) must not advance: keep their cache bit-for-bit —
            # an unmasked step would write token-0 KV at position 0 and
            # advance recurrent (mamba) state unconditionally
            new_caches = jax.tree_util.tree_map(
                lambda n, o: jnp.where(active, n, o),
                _strip_batch(new_caches),
                caches,
            )
            return logits[0], new_caches

        self._decode_all = jax.jit(
            jax.vmap(
                _one_slot_decode,
                in_axes=(None, 0, _cache_axes(self._caches), 0, 0),
                out_axes=(0, _cache_axes(self._caches)),
            ),
            donate_argnums=(2,),
        )
        self._prefill = jax.jit(
            lambda params, tokens: prefill(
                cfg, params, tokens, max_seq=ecfg.max_seq, remat=False
            )
        )

        def _chunk_scan(params, tokens, caches, slot, pos0):
            """Advance ONE slot by ``len(tokens)`` prompt tokens in a
            single device dispatch (scan over the shared decode_step) —
            the chunked-prefill continuation path of continuous batching.

            Extracts the slot's cache once (keepdims → batch of 1), scans
            the chunk through decode_step, writes the slot back, and
            returns the last token's logits.
            """
            take_u = lambda x: jax.lax.dynamic_index_in_dim(x, slot, 1)
            take_s = lambda x: jax.lax.dynamic_index_in_dim(x, slot, 0)
            sub = {
                "unit": jax.tree_util.tree_map(take_u, caches["unit"]),
                "suffix": jax.tree_util.tree_map(take_s, caches["suffix"]),
            }
            if "cross_kv" in caches:
                sub["cross_kv"] = jax.tree_util.tree_map(
                    take_s, caches["cross_kv"]
                )

            def body(carry, inp):
                tok, p = inp
                logits, carry = decode_step(
                    cfg, params, tok[None, None], carry, p
                )
                return carry, logits[0, 0]

            poss = pos0 + jnp.arange(tokens.shape[0], dtype=jnp.int32)
            new_sub, logits_seq = jax.lax.scan(body, sub, (tokens, poss))
            put_u = lambda s, o: jax.lax.dynamic_update_index_in_dim(s, o, slot, 1)
            put_s = lambda s, o: jax.lax.dynamic_update_index_in_dim(s, o, slot, 0)
            out = {
                "unit": jax.tree_util.tree_map(
                    put_u, caches["unit"], new_sub["unit"]
                ),
                "suffix": jax.tree_util.tree_map(
                    put_s, caches["suffix"], new_sub["suffix"]
                ),
            }
            if "cross_kv" in caches:
                out["cross_kv"] = caches["cross_kv"]  # static during decode
            return logits_seq[-1], out

        self._chunk_scan = jax.jit(_chunk_scan, donate_argnums=(2,))

        # ---- paged-kernel decode: the serving hot path.  Eligible stacks
        # batch every active row's live page table into one
        # paged_decode_attention call per layer (decode_step_paged); the
        # dense vmapped path above stays as the differential oracle and
        # serves cache shapes the kernel doesn't (MLA, SSM, rings, enc-dec)
        self._paged_ok = ecfg.paged_decode and paged_decode_supported(cfg)
        #: int8-quantized kernel path (satellite of the PR 7 stretch):
        #: only meaningful when the paged kernel serves decode at all
        self._paged_int8 = ecfg.paged_decode_int8 and self._paged_ok
        self._kernel_interpret = (
            ecfg.kernel_interpret
            if ecfg.kernel_interpret is not None
            else jax.default_backend() != "tpu"
        )
        self.paged_decode_ticks = 0  # decode ticks served by the kernel
        self.paged_int8_ticks = 0  # … of which through the int8 kernel

        def _paged_step(
            params, caches, tok, row_slot, poss, tables, lens,
            src_slot, src_idx, n_pool,
        ):
            logits, new_caches = decode_step_paged(
                cfg, params, tok, caches, poss, row_slot, tables, lens,
                src_slot, src_idx, page_tokens=self.kv.page_tokens,
                n_pool=n_pool, interpret=self._kernel_interpret,
                int8=self._paged_int8,
            )
            # batch argmax on device: ONE transfer back per tick
            return jnp.argmax(logits[:, 0, :], axis=-1), new_caches

        self._decode_paged = jax.jit(
            _paged_step, static_argnums=(9,), donate_argnums=(1,)
        )

    # ----------------------------------------------------- live bookkeeping
    def _set_state(self, req: Request, new: str) -> None:
        """The one place a live request's state changes: keeps the
        per-state id sets exact and marks the rid for the next pool sync
        (a transition into/out of an accounted state moves pool bytes)."""
        old = req.state
        if new == old:
            return
        ids = self._state_ids.get(old)
        if ids is not None:
            ids.discard(req.request_id)
        self._state_ids.setdefault(new, set()).add(req.request_id)
        req.state = new
        self._pool_dirty.add(req.request_id)
        # suspension is a lifetime-class transition: the ledger restamps
        # the request's sole-held pages PRIVATE_SUFFIX ⇄ FROZEN
        if new == "suspended":
            self.kv.set_frozen(req.request_id, True)
        elif old == "suspended":
            self.kv.set_frozen(req.request_id, False)

    def _track_live(self, req: Request) -> None:
        rid = req.request_id
        self._live[rid] = req
        self._state_ids.setdefault(req.state, set()).add(rid)
        self.kv.ledger.note_projection(
            rid, req.tenant, self.estimate_request_bytes(req)
        )

    def _drop_live(self, req: Request) -> None:
        rid = req.request_id
        if self._live.pop(rid, None) is None:
            return
        ids = self._state_ids.get(req.state)
        if ids is not None:
            ids.discard(rid)
        # the ledger settles per-tenant projections exactly (the bucket
        # is dropped with its last entry), so there is no residue to
        # reset — the old "settle on empty" workaround is gone
        self.kv.ledger.drop_projection(rid)

    # ------------------------------------------------------------- tenants
    def submit(self, req: Request) -> bool:
        """Accept one request into the admission queue; always True (an
        engine never rejects at the door — put a
        :class:`repro.serve.frontdoor.FrontDoor` in front for that)."""
        req.submit_tick = self.tick
        if not req.model:
            req.model = self.cfg.name
        self.requests[req.request_id] = req
        self._submitted += 1
        if req.model != self.cfg.name:
            # a misroute: this replica does not host the request's model.
            # Decoding it through the wrong weights would be silently
            # wrong output — fail typed instead, and count the event so
            # the model_zoo gate can assert the router never causes one.
            self.misroutes += 1
            req.state = "failed"
            req.finish_tick = self.tick
            req.fail_reason = (
                f"wrong_model: replica hosts {self.cfg.name!r}, "
                f"request targets {req.model!r}"
            )
            self.failed.append(req.request_id)
            return True
        self.queue.append(req)
        self._track_live(req)
        return True

    # ------------------------------------------------------------ migration
    def precopy_request(self, request_id: str) -> Optional[PrecopySnapshot]:
        """Phase one of an incremental drain migration: copy the
        request's resident page payloads WITHOUT disturbing it — the
        request keeps its slot, keeps decoding, keeps dirtying pages.
        The cluster ships the snapshot's bytes in the background and
        hands it back to :meth:`export_request` as the ``baseline`` at
        cutover, which then re-ships only the pages written since
        (DESIGN.md §11).

        Call between :meth:`step` calls (the snapshot's epoch is the
        last completed tick).  Returns None when nothing useful can be
        pre-copied: unknown/queued requests, parked imports, recurrent
        constant-state architectures (their state never travels
        page-wise), or a request with no extractable payloads — the
        caller falls back to a monolithic one-shot export.
        """
        req = self._live.get(request_id)
        if (
            req is None
            or req.state == "queued"
            or request_id in self._imports
            or self.spec.constant_state_bytes > 0
        ):
            return None
        table = self.kv.page_table(request_id)
        if not table:
            return None
        snap = PrecopySnapshot(request_id=request_id, epoch=self.tick - 1)
        frozen = self._frozen_payloads.get(request_id, {})
        for idx, pid in enumerate(table):
            if pid == DEMOTED:
                continue  # compressed block travels at cutover instead
            payload = (
                self._page_payload(req.slot, idx)
                if req.slot >= 0
                else frozen.get(idx)
            )
            if payload is not None:
                snap.payloads[idx] = payload
        if not snap.payloads:
            return None
        page_bytes = self.kv.bytes_for(self.cfg, 1)
        snap.raw_bytes = len(snap.payloads) * page_bytes
        snap.wire_bytes = wire_bytes_for(
            snap.raw_bytes, len(snap.payloads), self.ecfg.tier_compress
        )
        return snap

    def export_request(
        self,
        request_id: str,
        baseline: Optional[PrecopySnapshot] = None,
    ) -> Optional[MigrationTicket]:
        """Extract a live request's full state for migration to another
        replica; this engine forgets the request entirely (no double
        accounting — the cluster owns it while its bytes are on the wire).

        What travels depends on where the request's KV currently lives:
        a slot-holding request ships its whole slot cache subtree
        (:meth:`_extract_slot` — bit-exact); a suspended one ships the
        frozen-payload captures; demoted pages leave the tier hierarchy
        as their compressed blocks (:meth:`PagedKVManager.extract_demoted`
        — already int8, already paid the lossy round-trip).  Returns None
        for unknown/terminal requests.

        With ``baseline`` (a :meth:`precopy_request` snapshot of this
        request) the cutover is INCREMENTAL: the ticket carries the
        merged payload set but its ``wire_bytes`` charge only the pages
        the write-epoch ledger marks dirty since the pre-copy — the
        monolithic counterfactual is recorded in ``full_wire_bytes`` so
        the bench can gate ``delta < full``.  When the delta cannot be
        assembled (a dirty page with no extractable payload), the
        monolithic path below runs unchanged.
        """
        req = self._live.get(request_id)
        if req is None:
            return None
        ticket = MigrationTicket(request=req, source_tick=self.tick)
        parked = self._imports.pop(request_id, None)
        if parked is not None:
            # re-exported before it ever landed here: the previous
            # ticket's KV payload is still the request's only copy
            ticket.slot_cache = parked.slot_cache
            ticket.page_payloads = parked.page_payloads
            ticket.raw_bytes = parked.raw_bytes
            ticket.wire_bytes = parked.wire_bytes
        delta_done = False
        if (
            baseline is not None
            and baseline.request_id == request_id
            and parked is None
            and req.state != "queued"
            and self.spec.constant_state_bytes == 0
        ):
            delta_done = self._export_delta(req, ticket, baseline)
        if req.state != "queued" and parked is None and not delta_done:
            if req.slot >= 0:
                ticket.slot_cache = self._extract_slot(req.slot)
            else:
                for idx, payload in self._frozen_payloads.get(
                    request_id, {}
                ).items():
                    if payload is not None:
                        ticket.page_payloads[idx] = payload
            resident_pages = sum(
                1 for pid in self.kv.page_table(request_id) if pid != DEMOTED
            )
            resident_bytes = self.kv.request_bytes(request_id)
            ticket.raw_bytes += resident_bytes
            ticket.wire_bytes += wire_bytes_for(
                resident_bytes, resident_pages, self.ecfg.tier_compress
            )
            for idx, block in self.kv.extract_demoted(request_id).items():
                payload = block.decompress()
                if payload is not None:
                    ticket.page_payloads[idx] = payload
                ticket.raw_bytes += block.raw_bytes
                ticket.wire_bytes += block.stored_bytes
        # forget the request: pool, pages, policy, sampler, slot, queues
        if req in self.queue:
            self.queue.remove(req)
        if request_id in self._restore:
            self._restore.remove(request_id)
        self._release_slot(req)
        self.pool.release_owner(request_id)
        self.kv.release(request_id)
        self.sampler.forget(request_id)
        self.policy.drop(request_id)
        self._frozen_payloads.pop(request_id, None)
        self._imports.pop(request_id, None)
        self._drop_live(req)
        self.requests.pop(request_id, None)
        self.kv.reclaim()
        self._update_pool()
        self.migrations_out += 1
        return ticket

    def _export_delta(
        self,
        req: Request,
        ticket: MigrationTicket,
        baseline: PrecopySnapshot,
    ) -> bool:
        """Assemble the incremental cutover into ``ticket``: merged
        payloads = pre-copied pages overlaid with the pages dirtied
        after the baseline's epoch (plus pages the baseline never saw).
        Returns False — leaving the ticket untouched for the monolithic
        path — when any needed delta payload is unextractable."""
        rid = req.request_id
        table = self.kv.page_table(rid)
        resident = [i for i, pid in enumerate(table) if pid != DEMOTED]
        dirty = self.kv.pages_written_since(rid, baseline.epoch)
        delta_idx = [
            i for i in resident if i in dirty or i not in baseline.payloads
        ]
        frozen = self._frozen_payloads.get(rid, {})
        fresh: Dict[int, np.ndarray] = {}
        for i in delta_idx:
            payload = (
                self._page_payload(req.slot, i)
                if req.slot >= 0
                else frozen.get(i)
            )
            if payload is None:
                return False
            fresh[i] = payload
        merged = dict(baseline.payloads)
        merged.update(fresh)
        if not all(i in merged for i in resident):
            return False  # a clean page the baseline never captured
        ticket.page_payloads = merged
        page_bytes = self.kv.bytes_for(self.cfg, 1)
        delta_raw = len(delta_idx) * page_bytes
        ticket.raw_bytes += delta_raw
        if delta_idx:
            ticket.wire_bytes += wire_bytes_for(
                delta_raw, len(delta_idx), self.ecfg.tier_compress
            )
        ticket.delta_pages = len(delta_idx)
        ticket.precopy_wire_bytes = baseline.wire_bytes
        # the monolithic counterfactual: what one-shot cutover would ship
        resident_bytes = self.kv.request_bytes(rid)
        ticket.full_wire_bytes = wire_bytes_for(
            resident_bytes, len(resident), self.ecfg.tier_compress
        )
        for idx, block in self.kv.extract_demoted(rid).items():
            payload = block.decompress()
            if payload is not None:
                ticket.page_payloads[idx] = payload
            ticket.raw_bytes += block.raw_bytes
            ticket.wire_bytes += block.stored_bytes
            ticket.full_wire_bytes += block.stored_bytes
        return True

    def import_request(self, ticket: MigrationTicket) -> None:
        """Install a migrated request (the target side of a migration).

        A ticket carrying the slot cache subtree — or complete per-page
        payload coverage — lands LIVE: the request waits only for a batch
        row and free pages, then its KV installs via
        :meth:`_install_slot` / :meth:`_install_page_payload` and decode
        continues where the source stopped.  Anything less (partial
        payloads, shared-prefix pages whose values never left the source,
        recurrent constant state) falls back to the replay path the local
        suspend/resume machinery already uses — token-exact, just paying
        the prefill compute again.
        """
        req = ticket.request
        rid = req.request_id
        req.slot = -1
        self.requests[rid] = req
        self._track_live(req)
        self._submitted += 1
        self.migrations_in += 1
        if req.state == "queued":
            self.queue.append(req)
            return
        self.kv.register(
            rid, self.cfg, prompt_tokens=len(req.prompt), tenant=req.tenant
        )
        if ticket.slot_cache is not None or self._payload_covers(ticket):
            self._set_state(req, "importing")
            self._imports[rid] = ticket
        else:
            self._set_state(req, "suspended")
            req.pos = 0
            req.cached_tokens = 0
            req.snap_key = None
            self._restore.append(rid)

    def _payload_covers(self, ticket: MigrationTicket) -> bool:
        """True when per-page payloads alone can rebuild the request's
        cache on this replica: every materialized page shipped a value
        array, and the architecture keeps no recurrent constant state
        (mamba/ring-buffer state never travels page-wise)."""
        if self.spec.constant_state_bytes > 0:
            return False
        req = ticket.request
        pages = (req.pos + self.kv.page_tokens - 1) // self.kv.page_tokens
        return pages > 0 and all(
            ticket.page_payloads.get(i) is not None for i in range(pages)
        )

    def _land_imports(self, free_slots: List[int]) -> None:
        """Attach migrated-in requests to batch rows: allocate their pages
        (never into overcommit — a landing waits for real headroom) and
        install the shipped KV.  Runs before local restores in
        :meth:`_admit`: a migrated request already paid a link crossing;
        making it also queue behind local traffic would double-charge it.
        """
        for rid in list(self._imports):
            if not free_slots:
                return
            ticket = self._imports[rid]
            req = self.requests[rid]
            pages_needed = (
                max(req.pos, 1) + self.kv.page_tokens - 1
            ) // self.kv.page_tokens
            if self.kv.n_pages > 0 and self.kv.free_pages < pages_needed:
                self.kv.evict_cache(pages_needed - self.kv.free_pages)
                if self.kv.free_pages < pages_needed:
                    continue  # no headroom yet: land on a later tick
            slot = free_slots.pop(0)
            req.slot = slot
            self._slot_req[slot] = rid
            self.kv.grow_to(rid, max(req.pos, 1))
            if ticket.slot_cache is not None:
                self._install_slot(slot, ticket.slot_cache)
            else:
                for idx in range(pages_needed):
                    self._install_page_payload(
                        slot, idx, ticket.page_payloads[idx]
                    )
            self.kv.note_write(rid, 0, max(req.pos, 1), self.tick)
            self._set_state(req, "prefill" if req.prefilling else "decoding")
            # fresh rate window on this replica: the sampler must never
            # see the imported progress as one giant burst
            self.sampler.forget(rid)
            del self._imports[rid]
            self._update_pool()

    # ---------------------------------------------------------- checkpointing
    def snapshot_kv(
        self, page_budget: Optional[int] = None
    ) -> Optional[Dict[str, Any]]:
        """One periodic KV snapshot: the page payloads + token progress a
        crash restore needs, ordered by the ledger's
        :class:`~repro.serve.ledger.PageClass` stamp — ``SHARED_PREFIX``
        pages first (they outlive any one request and shield the most
        replay per byte), then private suffix pages; ``SCRATCH`` pages
        would never checkpoint (§11).  ``page_budget`` truncates after
        the ordering, so whatever fits is always the longest-lived state.

        Returns ``{"epoch", "reqs": [{"rid", "pos", "generated",
        "pages": {index: payload}}], "raw_bytes", "stored_bytes"}`` —
        the cluster packs it into a self-describing checkpoint file —
        or None when there is nothing page-wise to persist (recurrent
        constant-state architectures, an empty engine).  Checkpoint
        bytes are accounted against the disk tier
        (:meth:`TieredKVStore.note_checkpoint`) as their own stream,
        distinct from spill.
        """
        if self.spec.constant_state_bytes > 0:
            return None
        # (shared-first rank, rid, idx, payload) — page granularity so a
        # tight budget still captures every request's shared prefix
        candidates: List[Tuple[int, str, int, np.ndarray]] = []
        meta: Dict[str, Request] = {}
        for rid, req in self._live.items():
            if req.state not in ("prefill", "decoding", "suspended"):
                continue
            if req.pos <= 0:
                continue
            frozen = self._frozen_payloads.get(rid, {})
            if req.slot < 0 and not frozen:
                continue
            table = self.kv.page_table(rid)
            shared = self.kv.shared_page_indices(rid)
            pages_needed = (
                req.pos + self.kv.page_tokens - 1
            ) // self.kv.page_tokens
            got_any = False
            for idx in range(min(pages_needed, len(table))):
                if table[idx] == DEMOTED:
                    continue
                payload = (
                    self._page_payload(req.slot, idx)
                    if req.slot >= 0
                    else frozen.get(idx)
                )
                if payload is None:
                    continue
                rank = 0 if idx in shared else 1
                candidates.append((rank, rid, idx, payload))
                got_any = True
            if got_any:
                meta[rid] = req
        if not candidates:
            return None
        candidates.sort(key=lambda c: (c[0], c[1], c[2]))
        if page_budget is not None:
            candidates = candidates[:page_budget]
        reqs: Dict[str, Dict[str, Any]] = {}
        for _, rid, idx, payload in candidates:
            req = meta[rid]
            entry = reqs.setdefault(
                rid,
                {
                    "rid": rid,
                    "pos": req.pos,
                    "generated": list(req.generated),
                    "pages": {},
                },
            )
            entry["pages"][idx] = payload
        page_bytes = self.kv.bytes_for(self.cfg, 1)
        raw = len(candidates) * page_bytes
        stored = wire_bytes_for(
            raw, len(candidates), self.ecfg.tier_compress
        )
        if self.kv.tiers is not None:
            self.kv.tiers.note_checkpoint(raw, stored)
        return {
            "epoch": self.tick - 1,
            "reqs": list(reqs.values()),
            "raw_bytes": raw,
            "stored_bytes": stored,
        }

    def restore_request(
        self, req: Request, page_payloads: Dict[int, np.ndarray]
    ) -> str:
        """Land a crash victim from checkpointed state (the restore side
        of :meth:`snapshot_kv`; ``req.pos`` / ``req.generated`` must
        already be rolled back to the checkpoint's values by the caller).

        Contiguous page coverage from index 0 decides how much replays:
        full coverage lands the request LIVE through the import path
        (zero recompute); partial coverage rolls ``pos`` back to the
        last covered page boundary and chunked prefill replays only the
        uncovered suffix; no coverage falls back to the full replay the
        suspend/resume machinery uses — which still keeps the restored
        ``generated`` tokens, so no decode work repeats even then.
        Returns ``"live"``, ``"suffix"``, ``"replay"``, or ``"queued"``.
        """
        rid = req.request_id
        req.slot = -1
        self.requests[rid] = req
        self._track_live(req)
        self._submitted += 1
        if req.state == "queued" or req.pos <= 0:
            self._set_state(req, "queued")
            req.pos = 0
            self.queue.append(req)
            return "queued"
        self.kv.register(rid, self.cfg, tenant=req.tenant)
        covered = 0
        while page_payloads.get(covered) is not None:
            covered += 1
        pos_covered = covered * self.kv.page_tokens
        outcome = "live"
        if pos_covered < req.pos:
            if covered == 0:
                self._set_state(req, "suspended")
                req.pos = 0
                req.cached_tokens = 0
                req.snap_key = None
                self._restore.append(rid)
                return "replay"
            # roll back to the covered boundary: the suffix replays
            req.pos = pos_covered
            outcome = "suffix"
        ticket = MigrationTicket(
            request=req,
            page_payloads={
                i: page_payloads[i] for i in range(covered)
            },
            source_tick=self.tick,
        )
        if not self._payload_covers(ticket):
            self._set_state(req, "suspended")
            req.pos = 0
            req.cached_tokens = 0
            req.snap_key = None
            self._restore.append(rid)
            return "replay"
        self._set_state(req, "importing")
        self._imports[rid] = ticket
        return outcome

    # ---------------------------------------------------------- cluster view
    @property
    def has_pending(self) -> bool:
        """True while any request still needs engine ticks."""
        if not self.ecfg.legacy_bookkeeping:
            # every non-terminal request is in _live (queued ones are in
            # the admission queue AND _live; terminal states are dropped
            # on finish/fail/export), so membership alone answers this
            return bool(self._live)
        return (
            bool(self.queue)
            or bool(self._imports)
            or any(
                r.state
                in ("prefill", "decoding", "suspended", "offloaded",
                    "importing")
                for r in self._live.values()
            )
        )

    def migratable_requests(self) -> List[Tuple[str, str]]:
        """``(request_id, state)`` of every non-terminal request, cheapest
        migration first: queued work ships zero KV bytes, slotless frozen
        state ships payloads, and running work last — extracting a slot
        cache mid-decode is exact but moves the most bytes."""
        order = {
            "queued": 0,
            "importing": 1,
            "offloaded": 2,
            "suspended": 3,
            "prefill": 4,
            "decoding": 5,
        }
        live = sorted(
            self._live.values(),
            key=lambda r: (
                order.get(r.state, 9), r.submit_tick, r.request_id
            ),
        )
        return [(r.request_id, r.state) for r in live]

    def replica_stats(self) -> Dict[str, Any]:
        """The load surface a cluster router scores placements against
        (see ``SchedulingPolicy.placement_score``), and the admission
        surface a :class:`~repro.serve.frontdoor.FrontDoor` sheds
        against (``capacity_bytes`` / ``projected_bytes``).  ``model``
        and ``memory_class`` declare what this replica hosts — the
        router's capability filter."""
        cap = self.pool.capacity
        if self.ecfg.legacy_bookkeeping:
            # committed future demand: every non-terminal request here
            # will grow to its declared peak — materialized bytes alone
            # make a just-admitted heavy decode look as light as a
            # finished one, which is exactly the placement mistake
            projected_bytes = sum(
                self.estimate_request_bytes(r) for r in self._live.values()
            )
            suspended = float(
                sum(1 for r in self._live.values() if r.state == "suspended")
            )
        else:
            projected_bytes = self.kv.ledger.projected_bytes()
            suspended = float(len(self._state_ids.get("suspended", ())))
        demand = 0.0
        projected = 0.0
        if cap > 0:
            demand = (
                max(self.pool.used_bytes - self.kv.reclaimable_bytes, 0.0)
                / cap
            )
            projected = projected_bytes / cap
        busy = sum(1 for r in self._slot_req if r is not None)
        waiting = len(self.queue) + len(self._restore) + len(self._imports)
        stats = {
            "demand_fraction": demand,
            "projected_fraction": projected,
            "used_fraction": self.pool.used_fraction,
            "slot_load": (busy + waiting) / max(self.ecfg.n_slots, 1),
            "free_slots": float(self.ecfg.n_slots - busy),
            "queued": float(len(self.queue)),
            "live": float(len(self._live)),
            "suspended": suspended,
            "tick_cost": self.last_tick_cost,
            "capacity_bytes": float(cap),
            "projected_bytes": float(projected_bytes),
            "model": self.cfg.name,
            "memory_class": self.spec.memory_class,
        }
        # the class-aware view: per-lifetime-class HBM bytes, straight
        # off the ledger — placement and scale_pressure read these
        by_class = self.kv.ledger.class_breakdown()
        for cls in PageClass:
            stats[f"{cls.value}_bytes"] = by_class.get(cls, 0.0)
        stats["frozen_fraction"] = (
            by_class.get(PageClass.FROZEN, 0.0) / cap if cap > 0 else 0.0
        )
        stats["reclaimable_fraction"] = (
            self.kv.reclaimable_bytes / cap if cap > 0 else 0.0
        )
        return stats

    def tick_cost_stats(self) -> Dict[str, Any]:
        """Distribution of the roofline-derived tick costs this engine
        paid — the bench/gate evidence that costs are DERIVED (seconds,
        varying with the work each tick actually did), not hand-set
        constants.  ``distinct`` counts unique values seen (capped at 64
        samples); > 1 means the cost tracked the load."""
        n = self._tick_cost_count
        return {
            "source": "roofline",
            "ticks": n,
            "mean_s": (self._tick_cost_sum / n) if n else 0.0,
            "min_s": self._tick_cost_min if n else 0.0,
            "max_s": self._tick_cost_max,
            "distinct": len(self._tick_cost_values),
            "paged_decode_ticks": self.paged_decode_ticks,
        }

    def group_demand(self) -> Dict[str, float]:
        """Projected peak bytes per tenant over live requests — the front
        door's shedding input (who is actually filling the pool)."""
        if self.ecfg.legacy_bookkeeping:
            out: Dict[str, float] = {}
            for r in self._live.values():
                out[r.tenant] = (
                    out.get(r.tenant, 0.0) + self.estimate_request_bytes(r)
                )
            return out
        return self.kv.ledger.projected_by_tenant()

    def estimate_request_bytes(self, req: Request) -> float:
        """Page-rounded bytes the request will pin at its declared peak
        (prompt + max_new_tokens — the §III-B projected need, known at
        admission) — the router's inbound-load estimate.  Allocates
        nothing; prompt-only sizing would make a 40-token decode and a
        4-token decode look identical to placement.

        Per-model: the paged term is zero for a constant-state (mamba)
        model, whose whole estimate is its fixed state; an
        encoder-decoder model adds the encoder-side KV its prompt pins
        for the request's lifetime."""
        return (
            self.kv.bytes_for(self.cfg, req.total_tokens)
            + self.spec.constant_state_bytes
            + self.cfg.encoder_bytes(len(req.prompt))
        )

    # ------------------------------------------------------------ accounting
    def _update_pool(self) -> None:
        if self.ecfg.legacy_bookkeeping:
            for rid, req in self._live.items():
                if req.state in (
                    "prefill", "decoding", "suspended", "offloaded"
                ):
                    # offloaded requests still own HBM bytes until the
                    # last page demotes (and again as promotions land) —
                    # skipping them leaves stale live entries pinning the
                    # pool
                    self.pool.set_live(rid, self.kv.request_bytes(rid))
        else:
            # only owners whose attribution actually changed re-sync:
            # every allocator refcount event (incl. co-holders of shared
            # pages) and every state transition marks its rid dirty
            dirty = self.kv.drain_dirty()
            if self._pool_dirty:
                dirty |= self._pool_dirty
                self._pool_dirty = set()
            for rid in dirty:
                req = self._live.get(rid)
                if req is not None and req.state in (
                    "prefill", "decoding", "suspended", "offloaded"
                ):
                    self.pool.set_live(rid, self.kv.request_bytes(rid))
        if self.ecfg.prefix_cache:
            # cold cached prefixes are live pool bytes too — the policy
            # must see them (and eviction must relieve them)
            self.pool.set_live(CACHE_OWNER, self.kv.cache_bytes)
        self.peak_used_fraction = max(
            self.peak_used_fraction, self.pool.used_fraction
        )
        self.kv.ledger.sample_peaks()
        if self.pool.capacity > 0:
            demand = (
                self.pool.used_bytes - self.kv.reclaimable_bytes
            ) / self.pool.capacity
            self.peak_demand_fraction = max(self.peak_demand_fraction, demand)

    def _pressure_plan(self) -> PressurePlan:
        """Ask the policy how to relieve pressure, handing it the
        class-stamped ledger view (the one surface replacing the old
        ``cache_pressure``/``demotion_pressure``/``shed_order`` trio)."""
        return self.policy.pressure(self.kv.ledger.view(self.pool.capacity))

    def _reclaim_one(
        self, cls: PageClass, protect: Sequence[int] = ()
    ) -> bool:
        """Reclaim ONE page of ``cls`` (the plan loops this until the
        deficit clears or the class runs dry).  Returns False when the
        class has nothing left to give."""
        if cls is PageClass.SCRATCH:
            return self.kv.evict_scratch(1) > 0
        if cls is PageClass.COLD_CACHED:
            return self.kv.evict_cache(1, protect=protect) > 0
        if cls is PageClass.FROZEN:
            return self._demote_frozen_page()
        return False

    def _active(self) -> List[Request]:
        return [
            r
            for r in self._live.values()
            if r.state in ("prefill", "decoding")
        ]

    # ------------------------------------------------------------ admission
    def _admit(self) -> None:
        """Admit queued requests while slots and prompt headroom allow.

        A request that does not fit WAITS at the door (stock continuous-
        batching semantics: block until KV pages free up) — for every
        policy, so admission order is never a policy branch.  What differs
        is how fast headroom appears: a suspending policy swaps frozen KV
        to host and frees pages; a pressure-oblivious one waits for
        completions or pays the reactive spill path.
        """
        free_slots = [i for i, r in enumerate(self._slot_req) if r is None]
        # migrated-in requests land first (their KV installs from the
        # ticket, no replay), then local restores
        self._land_imports(free_slots)
        # resumed / promoted requests re-acquire a batch row first — their
        # slot cache is rebuilt by replaying feed_tokens through the
        # chunked-prefill path (their page-pool accounting never moved; a
        # request whose pages are still demoted waits here, resident-gated,
        # while the promotion pass DMAs them back)
        cursor = 0
        while cursor < len(self._restore) and free_slots:
            req = self.requests[self._restore[cursor]]
            if not self.kv.resident(req.request_id):
                cursor += 1
                continue
            self._restore.pop(cursor)
            if self.ecfg.prefix_cache:
                # replay can skip prefill too: a reloaded request re-shares
                # cached pages; a suspended one (pages retained) just reuses
                # the snapshot for the covered positions.  Neither counts
                # as a cache HIT — re-matching your own published prefix is
                # not cross-request sharing (count_stats/hit_counted)
                if self.kv.request_pages(req.request_id) == 0:
                    req.cached_tokens, req.snap_key = self.kv.match_prefix(
                        req.request_id,
                        req.feed_tokens,
                        float(self.tick),
                        count_stats=False,
                    )
                else:
                    req.cached_tokens, req.snap_key = self.kv.peek_prefix(
                        req.feed_tokens
                    )
                req.hit_counted = True
            slot = free_slots.pop(0)
            req.slot = slot
            self._slot_req[slot] = req.request_id
            self._set_state(req, "prefill")
            req.pos = 0
            self._frozen_payloads.pop(req.request_id, None)
            # replay rewinds processed-token counts: restart the rate
            # estimator so the sampler never sees progress go backwards
            # (a stale window would report rate 0 and invert MURS's
            # keep-the-lightest victim ordering)
            self.sampler.forget(req.request_id)
        # a tenant with suspended requests is a known heavy-pressure source:
        # don't admit more of its traffic until its queue drains (the sim's
        # launch gating, §I: "the resources are released from running heavy
        # tasks" — and handed to the light tenants)
        gated = {
            self.requests[tid].tenant
            for tid in self.policy.suspended_queue
            if tid in self.requests
        }
        headroom = self.policy.admission_headroom * self.pool.capacity
        # the policy's placement hook decides which tenant's head-of-line
        # request each free slot goes to (FAIR/MURS: round-robin across
        # tenants, PriorityPolicy: weighted stride) — FIFO within a tenant
        by_tenant: Optional[Dict[str, List[Request]]] = None
        if self.ecfg.legacy_bookkeeping:
            by_tenant = {}
            for r in self.queue:
                if r.tenant not in gated:
                    by_tenant.setdefault(r.tenant, []).append(r)
            pending = {t: len(v) for t, v in by_tenant.items()}
        else:
            # same mapping, same key order (tenants by oldest queued
            # request) — read off the queue's index instead of an
            # O(queue) rebuild every tick
            pending = self.queue.tenant_counts(exclude=gated)
        picks = self.policy.assign(len(free_slots), pending)
        for tenant in picks:
            if not free_slots:
                continue
            if by_tenant is not None:
                bucket = by_tenant.get(tenant)
                req = bucket[0] if bucket else None
            else:
                req = self.queue.head(tenant)
            if req is None:
                continue
            # capacity check: would this request's prompt fit below the
            # policy's admission line right now?  Pure arithmetic — no
            # allocator churn for a request that just waits at the door.
            # Pages a prefix-cache match would share cost nothing new;
            # ``protected`` shields them from this pass's own evictions.
            prompt_bytes, protected = self.kv.admission_probe(
                self.cfg, req.prompt
            )
            # encoder-decoder models pin the encoder-side cross-attention
            # KV at prefill too — admission must count it with the prompt
            prompt_bytes += self.cfg.encoder_bytes(len(req.prompt))
            if prompt_bytes > headroom:
                # can never fit, even into an empty pool: fail fast
                # (OOM semantics) instead of blocking the queue forever
                self.queue.remove(req)
                if by_tenant is not None:
                    by_tenant[tenant].pop(0)
                self._set_state(req, "failed")
                req.finish_tick = self.tick
                req.fail_reason = "prompt exceeds admission headroom"
                self.failed.append(req.request_id)
                self._drop_live(req)
                continue
            # reclaim class by class in the policy plan's order (stock:
            # SCRATCH, then COLD_CACHED, then FROZEN) — scratch and cold
            # cache are cheap drops; frozen suspended KV demotes PAGE BY
            # PAGE and only while that can actually open the door (no
            # more bytes leave HBM than the deficit requires).  The probe
            # above's shareable pages stay protected throughout.
            plan = self._pressure_plan()
            for cls in plan.reclaim_order:
                if cls is PageClass.FROZEN:
                    while (
                        self.pool.used_bytes + prompt_bytes > headroom
                        and self.pool.used_bytes
                        - self.kv.ledger.class_bytes(PageClass.FROZEN)
                        + prompt_bytes
                        <= headroom
                    ):
                        if not self._demote_frozen_page():
                            break
                        self._update_pool()
                else:
                    while self.pool.used_bytes + prompt_bytes > headroom:
                        if not self._reclaim_one(cls, protect=protected):
                            break
                        self._update_pool()
            if self.pool.used_bytes + prompt_bytes > headroom:
                break  # pool-bound: nobody else fits this tick either
            self.queue.remove(req)
            if by_tenant is not None:
                by_tenant[tenant].pop(0)
            self.kv.register(
                req.request_id,
                self.cfg,
                prompt_tokens=len(req.prompt),
                tenant=req.tenant,
            )
            if self.ecfg.prefix_cache:
                # the trie hands over every page of the longest cached
                # prefix by reference — prefill will start at the first
                # uncached token
                req.cached_tokens, req.snap_key = self.kv.match_prefix(
                    req.request_id, req.feed_tokens, float(self.tick)
                )
            self.kv.grow_to(req.request_id, len(req.prompt))
            slot = free_slots.pop(0)
            req.slot = slot
            self._slot_req[slot] = req.request_id
            self._set_state(req, "prefill")
            req.pos = 0
            self._update_pool()

    # --------------------------------------------------------- slot caches
    def _extract_slot(self, slot: int) -> Dict[str, Any]:
        """Copy one slot's cache subtree (the KV snapshot a cached prefix
        is installed from)."""
        sub = {
            "unit": jax.tree_util.tree_map(
                lambda x: x[:, slot], self._caches["unit"]
            ),
            "suffix": jax.tree_util.tree_map(
                lambda x: x[slot], self._caches["suffix"]
            ),
        }
        if "cross_kv" in self._caches:
            sub["cross_kv"] = jax.tree_util.tree_map(
                lambda x: x[slot], self._caches["cross_kv"]
            )
        return sub

    def _install_slot(self, slot: int, sub: Dict[str, Any]) -> None:
        """Write a snapshot subtree into ``slot`` of the batched caches."""
        new = dict(self._caches)
        new["unit"] = jax.tree_util.tree_map(
            lambda s, o: s.at[:, slot].set(o), self._caches["unit"], sub["unit"]
        )
        new["suffix"] = jax.tree_util.tree_map(
            lambda s, o: s.at[slot].set(o),
            self._caches["suffix"],
            sub["suffix"],
        )
        if "cross_kv" in self._caches:
            new["cross_kv"] = jax.tree_util.tree_map(
                lambda s, o: s.at[slot].set(o),
                self._caches["cross_kv"],
                sub["cross_kv"],
            )
        self._caches = new

    # ---------------------------------------------------------- prefix COW
    def _cow_range(self, req: Request, start_pos: int, end_pos: int) -> None:
        """Copy-on-write guard before writing tokens [start_pos, end_pos):
        any shared page in that span is split so the shared copy is never
        mutated.  No-op over private pages."""
        if end_pos <= start_pos:
            return
        page = self.kv.page_tokens
        for idx in range(start_pos // page, (end_pos - 1) // page + 1):
            self.kv.make_private(req.request_id, idx)

    # ---------------------------------------------------------- tier payloads
    def _page_span(self, page_index: int) -> Tuple[int, int]:
        a = page_index * self.kv.page_tokens
        return a, min(a + self.kv.page_tokens, self.ecfg.max_seq)

    def _seq_leaf(self, x) -> bool:
        """True for cache leaves carrying a per-position axis at ``-2``
        (attention K/V ``[..., seq, hd]``, MLA latents ``[seq, rank]``) —
        the leaves a token-span page physically owns.  Constant-state
        leaves (mamba, ring buffers) have no such axis and never demote."""
        return x.ndim >= 2 and x.shape[-2] == self.ecfg.max_seq

    def _page_payload(self, slot: int, page_index: int) -> Optional[np.ndarray]:
        """The REAL bytes of one page: every cache value for the page's
        token span, flattened f32 — what the host tier int8-compresses."""
        a, b = self._page_span(page_index)
        if a >= b:
            return None
        parts = []
        for leaf in jax.tree_util.tree_leaves(self._caches["unit"]):
            x = leaf[:, slot]
            if self._seq_leaf(x):
                parts.append(np.asarray(x[..., a:b, :], np.float32).ravel())
        for leaf in jax.tree_util.tree_leaves(self._caches["suffix"]):
            x = leaf[slot]
            if self._seq_leaf(x):
                parts.append(np.asarray(x[..., a:b, :], np.float32).ravel())
        if not parts:
            return None
        return np.concatenate(parts)

    def _install_page_payload(
        self, slot: int, page_index: int, payload: np.ndarray
    ) -> None:
        """Inverse of :meth:`_page_payload`: write the (dequantized)
        page span back into the slot cache — the lossy int8 round-trip
        lands in the values decode actually attends over."""
        a, b = self._page_span(page_index)
        if a >= b:
            return
        off = 0
        u_leaves, u_def = jax.tree_util.tree_flatten(self._caches["unit"])
        for i, leaf in enumerate(u_leaves):
            x = leaf[:, slot]
            if not self._seq_leaf(x):
                continue
            span = x[..., a:b, :]
            n = int(np.prod(span.shape))
            vals = payload[off : off + n].reshape(span.shape)
            off += n
            idx = (
                (slice(None), slot)
                + (slice(None),) * (leaf.ndim - 4)
                + (slice(a, b), slice(None))
            )
            u_leaves[i] = leaf.at[idx].set(vals.astype(leaf.dtype))
        s_leaves, s_def = jax.tree_util.tree_flatten(self._caches["suffix"])
        for i, leaf in enumerate(s_leaves):
            x = leaf[slot]
            if not self._seq_leaf(x):
                continue
            span = x[..., a:b, :]
            n = int(np.prod(span.shape))
            vals = payload[off : off + n].reshape(span.shape)
            off += n
            idx = (
                (slot,)
                + (slice(None),) * (leaf.ndim - 3)
                + (slice(a, b), slice(None))
            )
            s_leaves[i] = leaf.at[idx].set(vals.astype(leaf.dtype))
        new = dict(self._caches)
        new["unit"] = jax.tree_util.tree_unflatten(u_def, u_leaves)
        new["suffix"] = jax.tree_util.tree_unflatten(s_def, s_leaves)
        self._caches = new

    # -------------------------------------------------------------- prefill
    def _install_prefill(self, req: Request, tokens: List[int]) -> Any:
        """Monolithic prefill of ``tokens`` into the request's slot; returns
        the last-position logits."""
        arr = jnp.asarray(tokens, jnp.int32)[None]
        logits, caches = self._prefill(self.params, arr)
        # install the request's cache into its slot (unit leaves carry the
        # scan dim first → slot axis is 1; suffix/cross leaves → axis 0)
        slot = req.slot
        new = dict(self._caches)
        new["unit"] = jax.tree_util.tree_map(
            lambda s, o: s.at[:, slot].set(o[:, 0]),
            self._caches["unit"],
            caches["unit"],
        )
        new["suffix"] = jax.tree_util.tree_map(
            lambda s, o: s.at[slot].set(o[0]),
            self._caches["suffix"],
            caches["suffix"],
        )
        if "cross_kv" in self._caches:
            new["cross_kv"] = jax.tree_util.tree_map(
                lambda s, o: s.at[slot].set(o[0]),
                self._caches["cross_kv"],
                caches["cross_kv"],
            )
        self._caches = new
        req.pos = len(tokens)
        self.kv.note_write(req.request_id, 0, len(tokens), self.tick)
        return logits[0, -1]

    def _finish_prefill(self, req: Request, last_logits) -> None:
        if req.generated:
            # replay after suspension/offload: the cache is rebuilt; the
            # next decode step feeds generated[-1] — nothing new to sample
            self._set_state(req, "decoding")
            return
        next_tok = int(jnp.argmax(last_logits))
        self._publish_prefix(req, next_tok)
        req.generated.append(next_tok)
        req.first_token_tick = self.tick
        self._set_state(req, "decoding")

    def _publish_prefix(self, req: Request, first_tok: int) -> None:
        """Insert a freshly prefilled prompt's pages into the trie and
        snapshot its slot KV so later identical/overlapping prompts skip
        prefill.  The request keeps decoding into its own pages: its first
        append into the now-shared terminal page copy-on-writes."""
        if not self.ecfg.prefix_cache or req.slot < 0:
            return
        feed = tuple(req.feed_tokens)
        inserted = self.kv.insert_prefix(
            req.request_id, feed, req.tenant, feed, float(self.tick)
        )
        if inserted and feed not in self._snaps:
            while len(self._snaps) >= self.ecfg.max_prefix_snapshots:
                # LRU: dict order is maintained by the touch in
                # _install_cached_prefix, so the head is the coldest
                self._snaps.pop(next(iter(self._snaps)))
            self._snaps[feed] = (
                self._extract_slot(req.slot),
                first_tok,
                len(feed),
            )

    def _install_cached_prefix(self, req: Request) -> None:
        """Skip prefill for trie-matched tokens: install the prefix's KV
        snapshot into the request's slot and continue from the first
        uncached token.  An exact-prompt hit finishes prefill outright —
        zero prefill compute, first token this tick."""
        snap = self._snaps.get(req.snap_key) if req.snap_key else None
        feed = req.feed_tokens
        if snap is None:
            # snapshot pruned between match and slot assignment: recompute
            # from scratch — writes into the still-shared pages COW first
            req.cached_tokens = 0
            req.snap_key = None
            return
        self._snaps[req.snap_key] = self._snaps.pop(req.snap_key)  # LRU touch
        caches_sub, first_tok, snap_len = snap
        self._install_slot(req.slot, caches_sub)
        self.kv.note_write(req.request_id, 0, max(snap_len, 1), self.tick)
        matched = min(req.cached_tokens, len(feed))
        count = not req.hit_counted  # replays must not re-count dedup work
        if count:
            self.prefix_hits += 1
            req.hit_counted = True
        if matched >= len(feed) and snap_len == len(feed):
            req.pos = len(feed)
            if count:
                self.prefix_hit_tokens += len(feed)
            if req.generated:
                # replay: next decode feeds last tok
                self._set_state(req, "decoding")
            else:
                req.generated.append(first_tok)
                req.first_token_tick = self.tick
                self._set_state(req, "decoding")
        else:
            # partial hit (or full-page hit needing last-position logits):
            # chunked prefill resumes at the first position whose logits or
            # KV the snapshot cannot provide
            req.pos = min(matched, len(feed) - 1)
            if count:
                self.prefix_hit_tokens += req.pos

    def _prefill_tick(self) -> None:
        """Consume up to ``prefill_chunk_tokens`` prompt tokens this tick.

        Short prompts take the monolithic fast path (one fused prefill
        call, same numerics as before); longer prompts start with one
        budget-sized monolithic chunk and continue through the single-slot
        decode path a chunk per tick — decode slots keep ticking in
        between, which is the whole point of chunked prefill.
        """
        budget = self.ecfg.prefill_chunk_tokens
        chunked = False
        for rid in list(self._slot_req):
            if rid is None:
                continue
            req = self.requests[rid]
            if req.state != "prefill":
                continue
            if not self.kv.resident(rid):
                self.stall_ticks += 1  # KV not fully in HBM: wait
                if self.kv.has_demoted(rid):
                    self.transfer_stall_ticks += 1  # tier DMA pending
                continue
            if req.pos == 0 and req.cached_tokens > 0:
                # prefix-cache hit: KV for the matched tokens installs
                # from the snapshot — no prefill compute, no budget, so
                # this runs even when a long cold prefill drained the
                # budget (an exact hit must never queue behind compute)
                self._install_cached_prefix(req)
                if req.state != "prefill":
                    continue  # exact hit: first token already sampled
            if budget <= 0:
                continue  # compute paths below need budget; hits don't
            feed = req.feed_tokens
            if req.pos == 0:
                if len(feed) <= budget:
                    self.kv.grow_to(rid, len(feed))
                    self._cow_range(req, 0, len(feed))
                    logits = self._install_prefill(req, feed)
                    budget -= len(feed)
                    self._tick_prefill_tokens += len(feed)
                    self._finish_prefill(req, logits)
                else:
                    # power-of-two first chunk: a partial leftover budget
                    # still starts the prompt (no starvation behind short
                    # traffic) while keeping the compiled shapes bounded
                    w = 1 << (budget.bit_length() - 1)
                    self.kv.grow_to(rid, w)
                    self._cow_range(req, 0, w)
                    self._install_prefill(req, feed[:w])
                    budget -= w
                    self._tick_prefill_tokens += w
                    chunked = True
            else:
                take = min(budget, len(feed) - req.pos)
                budget -= take
                self._tick_prefill_tokens += max(take, 0)
                last = None
                if take > 0:
                    self.kv.grow_to(rid, req.pos + take)
                    self._cow_range(req, req.pos, req.pos + take)
                    self.kv.note_write(
                        rid, req.pos, req.pos + take, self.tick
                    )
                # power-of-two buckets: O(log chunk) dispatches per tick
                # and a bounded set of compiled scan widths
                while take > 0:
                    w = 1 << (take.bit_length() - 1)
                    toks = jnp.asarray(feed[req.pos:req.pos + w], jnp.int32)
                    last, self._caches = self._chunk_scan(
                        self.params, toks, self._caches, req.slot,
                        jnp.int32(req.pos),
                    )
                    req.pos += w
                    take -= w
                chunked = True
                if not req.prefilling and last is not None:
                    self._finish_prefill(req, last)
            self.kv.grow_to(req.request_id, max(req.pos, 1))
        if chunked:
            self.chunked_prefill_ticks += 1
        self._update_pool()

    # --------------------------------------------------------------- decode
    def _decode_tick(self) -> float:
        """One decode tick over the resident active slots.  Returns the
        KV bytes the tick's attention read (the roofline's HBM traffic
        term), derived from the ledger's per-owner attribution — not a
        separately maintained tally."""
        active = []
        for i, rid in enumerate(self._slot_req):
            if rid is None or self.requests[rid].state != "decoding":
                continue
            if not self.kv.resident(rid):
                # tokens on overflow or demoted pages are not in HBM —
                # attention cannot read them; the request stalls until
                # reclaim() / promotion pages them back in
                self.stall_ticks += 1
                if self.kv.has_demoted(rid):
                    self.transfer_stall_ticks += 1
                continue
            active.append((i, self.requests[rid]))
        if not active:
            return 0.0
        self._tick_decode_tokens = len(active)
        kv_bytes_read = sum(
            self.kv.request_bytes(req.request_id) for _, req in active
        )
        if self._paged_ok and self.kv.n_pages > 0:
            try:
                nxt = self._decode_paged_batch(active)
            except ValueError:
                # a running request briefly overlaps an in-flight demotion
                # (its table carries DEMOTED ids): the dense slot caches
                # still hold every value, so fall back for this tick
                nxt = self._decode_dense_batch(active)
        else:
            nxt = self._decode_dense_batch(active)
        for r, (i, req) in enumerate(active):
            req.pos += 1
            self.kv.grow_to(req.request_id, req.pos)
            # the KV write landed at position pos-1: if that page is shared
            # (an exact-prompt hit decoding past its cached terminal page),
            # split it first — shared pages are never mutated.  The paged
            # path addressed this page through a synthetic pool id, so
            # this is the FIRST allocator mutation either way: both decode
            # paths drive the same allocator event sequence.
            self.kv.make_private(
                req.request_id, (req.pos - 1) // self.kv.page_tokens
            )
            self.kv.note_write(
                req.request_id, req.pos - 1, req.pos, self.tick
            )
            req.generated.append(int(nxt[r]))
            if req.done:
                self._finish(req)
        self._update_pool()
        return kv_bytes_read

    def _decode_dense_batch(self, active) -> np.ndarray:
        """Dense vmapped decode over all slots (the differential oracle).

        Inputs are staged host-side in numpy and shipped in ONE
        device_put; the argmax runs device-side over the whole batch and
        comes back in one transfer — no per-slot dispatches or syncs.
        Returns next tokens aligned with ``active`` order.
        """
        n = self.ecfg.n_slots
        tokens = np.zeros((n, 1), np.int32)
        poss = np.zeros((n,), np.int32)
        mask = np.zeros((n,), np.bool_)
        for i, req in active:
            tokens[i, 0] = req.generated[-1]
            poss[i] = req.pos
            mask[i] = True
        tokens, poss, mask = jax.device_put((tokens, poss, mask))
        logits, self._caches = self._decode_all(
            self.params, tokens, self._caches, poss, mask
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        return nxt[[i for i, _ in active]]

    def _decode_paged_batch(self, active):
        """One decode tick through the paged Pallas kernel.

        Batches every active row's LIVE page table (the same tables the
        byte accounting runs on) into a single ``decode_step_paged`` call:
        rows sorted longest-first, table width and pool bound trimmed to
        powers of two (``kv.gather_plan``), pad rows carrying an
        out-of-bounds slot so their writes drop.  Returns next tokens
        aligned with ``active`` order — the sort exists only to trim the
        kernel grid; bookkeeping (and the order-sensitive finish→resume
        chain) must see the same row order as the dense oracle.
        """
        P = self.kv.page_tokens
        # longest first: the trimmed width follows row 0, so the kernel's
        # page grid never sweeps past the longest resident request
        order = sorted(active, key=lambda sr: (-sr[1].pos, sr[0]))
        tables, src_slot, src_idx, n_pool = self.kv.gather_plan(
            [req.request_id for _, req in order],
            [slot for slot, _ in order],
        )
        rows = len(order)
        # this tick's KV write lands in page pos // P, which may not exist
        # yet (page boundary) or may be shared (exact-prompt hit on a
        # cached terminal page).  Address it through a per-row SYNTHETIC
        # pool id mapped to the row's own slot cache instead of mutating
        # the allocator here: grow/COW/release then run ONLY in the shared
        # post-decode bookkeeping, in exactly the dense oracle's order —
        # the kernel wiring must not perturb the allocator event sequence
        # the scheduling policy observes.
        n_pool2 = 1 << max(n_pool + rows - 1, 0).bit_length()
        src_slot = np.pad(src_slot, (0, n_pool2 - n_pool))
        src_idx = np.pad(src_idx, (0, n_pool2 - n_pool))
        need = max(req.pos // P + 1 for _, req in order)
        w = 1 << max(max(need, tables.shape[1]) - 1, 0).bit_length()
        b = 1 << (rows - 1).bit_length()  # pow2 rows: bounded jit cache
        tok = np.zeros((b, 1), np.int32)
        # pad rows write at slot == n_slots: out of bounds, mode="drop"
        row_slot = np.full((b,), self.ecfg.n_slots, np.int32)
        poss = np.zeros((b,), np.int32)
        lens = np.zeros((b,), np.int32)
        tab = np.zeros((b, w), np.int32)
        for r, (slot, req) in enumerate(order):
            tok[r, 0] = req.generated[-1]
            row_slot[r] = slot
            poss[r] = req.pos
            lens[r] = req.pos + 1  # dense decode attends k_pos <= pos
            tab[r, : tables.shape[1]] = tables[r]
            wp = req.pos // P
            sid = n_pool + r
            tab[r, wp] = sid
            src_slot[sid] = slot
            src_idx[sid] = wp
        staged = jax.device_put(
            (tok, row_slot, poss, tab, lens, src_slot, src_idx)
        )
        nxt, self._caches = self._decode_paged(
            self.params, self._caches, *staged, n_pool2
        )
        self.paged_decode_ticks += 1
        if self._paged_int8:
            self.paged_int8_ticks += 1
        nxt = np.asarray(nxt)
        row_of = {slot: r for r, (slot, _) in enumerate(order)}
        return nxt[[row_of[slot] for slot, _ in active]]

    def _finish(self, req: Request) -> None:
        self._set_state(req, "done")
        req.finish_tick = self.tick
        self.completed.append(req.request_id)
        self._drop_live(req)
        self._release_slot(req)
        self.pool.release_owner(req.request_id)
        self.kv.release(req.request_id)
        self.sampler.forget(req.request_id)
        self._frozen_payloads.pop(req.request_id, None)
        rid = self.policy.on_task_complete(req.request_id)
        if rid is not None:
            self._resume(rid)

    # ----------------------------------------------------------------- policy
    def _policy_pass(self) -> None:
        active = self._active()
        for r in active:
            self.sampler.observe(
                r.request_id,
                processed_bytes=float(r.pos),
                total_bytes=float(r.total_tokens),
                live_bytes=self.kv.request_bytes(r.request_id),
                group=r.tenant,
            )
        stats = self.sampler.stats([r.request_id for r in active])
        # expose the online §III classification on each request, and tell
        # the policy the DECLARED architecture class of each group it is
        # about to score (on this engine, every group runs this model)
        seen_groups = set()
        for st in stats:
            self.requests[st.task_id].memory_model = st.model.value
        for r in active:
            if r.tenant not in seen_groups:
                seen_groups.add(r.tenant)
                self.policy.note_group_class(
                    r.tenant, self.spec.memory_class
                )
        frozen = self.sampler.stats(
            [
                r.request_id
                for r in self._live.values()
                if r.state == "suspended"
            ]
        )
        decision = self.policy.propose(
            self.pool, stats, now=float(self.tick), suspended=frozen
        )
        for rid in decision.suspend:
            req = self.requests[rid]
            if req.state in ("decoding", "prefill"):
                self._set_state(req, "suspended")
                self.suspensions += 1
                if req.slot >= 0:
                    # capture the frozen pages' REAL KV values while the
                    # slot is still attached: if the policy later demotes
                    # them, the host tier compresses these bytes
                    self._frozen_payloads[rid] = {
                        idx: self._page_payload(req.slot, idx)
                        for idx in self.kv.demotable_indices(rid)
                    }
                self._release_slot(req)
        for rid in decision.resume:
            self._resume(rid)

    def _release_slot(self, req: Request) -> None:
        """Free the request's batch row (its KV pages stay accounted) — in
        a paged runtime batch rows are virtual, so a suspended request must
        not block admission of new work."""
        if req.slot >= 0:
            self._slot_req[req.slot] = None
            req.slot = -1

    def _resume(self, rid: str) -> None:
        req = self.requests.get(rid)
        if req is None:
            return
        if req.state == "suspended":
            # re-acquire a batch row; the slot cache is rebuilt by replay.
            # If frozen pages were demoted, the promotion pass DMAs them
            # back first (the restore loop is residency-gated).
            if rid not in self._restore:
                self._restore.append(rid)

    # ----------------------------------------------------------------- tick
    def step(self) -> None:
        """Advance one tick: admit, prefill a chunk, decode the batch,
        then the policy/demotion passes; updates ``last_tick_cost``."""
        stalls0 = self.stall_ticks
        self._tick_prefill_tokens = 0
        self._tick_decode_tokens = 0
        self._admit()
        self._prefill_tick()
        kv_bytes_read = self._decode_tick()
        # roofline-derived tick service time (modeled seconds): bytes
        # moved this tick — weight stream + the KV pages of the requests
        # actually decoded + prefill writes — over HBM bandwidth, vs
        # FLOPs over peak, plus one PCIe page DMA per stall.  Straggler
        # detection, placement scoring and the overload bench inherit
        # hardware-meaningful units from here (deterministic — no wall
        # clock in the simulation).
        cost = self._tick_cost_model.tick_seconds(
            decode_tokens=self._tick_decode_tokens,
            prefill_tokens=self._tick_prefill_tokens,
            kv_bytes_read=kv_bytes_read,
            stall_events=self.stall_ticks - stalls0,
        )
        self.last_tick_cost = cost
        self._tick_cost_count += 1
        self._tick_cost_sum += cost
        self._tick_cost_min = min(self._tick_cost_min, cost)
        self._tick_cost_max = max(self._tick_cost_max, cost)
        if len(self._tick_cost_values) < 64:
            self._tick_cost_values.add(round(cost, 15))
        period_ticks = max(
            round(self.policy.period * self.ecfg.murs_period_ticks), 1
        )
        if self.tick % period_ticks == 0:
            self._policy_pass()
        self._proactive_demotion()
        self._resolve_overcommit()
        # advance the tier hierarchy: completed promotions swap back into
        # page tables; pages a slot is still attached to get their
        # (dequantized) values written back into the cache
        for rid, idx, payload in self.kv.tick_tiers(float(self.tick)):
            req = self.requests.get(rid)
            if req is not None and req.slot >= 0 and payload is not None:
                self._install_page_payload(req.slot, idx, payload)
                self.kv.note_page_write(rid, idx, self.tick)
        self._promotion_pass()
        self.kv.reclaim()
        if (
            self.ecfg.prefix_cache
            and self.kv.cache_evictions != self._pruned_at_evictions
        ):
            # drop KV snapshots no trie node references anymore
            live = self.kv.live_snap_keys()
            self._snaps = {k: v for k, v in self._snaps.items() if k in live}
            self._pruned_at_evictions = self.kv.cache_evictions
        self.tick += 1

    def _frozen_victims(self, require_pressure: bool) -> List[Request]:
        """Suspended requests whose frozen KV may demote, best victim
        first: highest plan ``FROZEN`` score (the policy's hint — MURS
        marks low-usage-rate tenants), then fattest.  With
        ``require_pressure`` only positively-marked tenants qualify (the
        proactive pass is policy-opt-in; the reactive paths take anyone).
        """
        if self.ecfg.legacy_bookkeeping:
            frozen = [
                r
                for r in self._live.values()
                if r.state == "suspended"
            ]
        else:
            frozen = [
                self.requests[rid]
                for rid in sorted(self._state_ids.get("suspended", ()))
            ]
        victims = [
            r
            for r in frozen
            if r.request_id not in self._restore
            and self.kv.demotable_indices(r.request_id)
        ]
        plan = self._pressure_plan()
        if require_pressure:
            # the FIFO head resumes next (one per completion): demoting
            # its pages proactively would just buy a promotion stall —
            # keep it hot, demote from the back of the queue forward
            queue = self.policy.suspended_queue
            head = queue[0] if queue else None
            victims = [
                r
                for r in victims
                if plan.score(PageClass.FROZEN, r.tenant) > 0.0
                and r.request_id != head
            ]
        victims.sort(
            key=lambda r: (
                -plan.score(PageClass.FROZEN, r.tenant),
                -self.kv.request_bytes(r.request_id),
                r.request_id,
            )
        )
        return victims

    def _demote_frozen_page(self, require_pressure: bool = False) -> bool:
        """Demote ONE frozen page (best victim's last demotable page) to
        the tier hierarchy.  Nobody stalls — the owner is suspended; the
        page DMAs back when the policy resumes it.  Returns False when
        nothing is demotable."""
        victims = self._frozen_victims(require_pressure)
        if not victims:
            return False
        victim = victims[0]
        rid = victim.request_id
        idx = self.kv.demotable_indices(rid)[-1]
        payload = self._frozen_payloads.get(rid, {}).pop(idx, None)
        if not self.kv.demote_page(rid, idx, payload, float(self.tick)):
            return False
        self.swap_outs += 1
        return True

    def _proactive_demotion(self) -> None:
        """The demotion_pressure mechanism: above ``demote_threshold``
        pool usage, demote cold cached pages and positively-marked
        tenants' frozen KV — *before* the reactive spill path fires.
        FAIR/base mark nobody (pressure 0.0 everywhere), so the stock
        baseline only ever pays the reactive path below."""
        if self.pool.capacity <= 0:
            return
        budget = self.ecfg.demote_batch_pages
        line = self.ecfg.demote_threshold
        plan = self._pressure_plan()
        while budget > 0 and self.pool.used_fraction >= line:
            # walk the plan's proactive order (stock: frozen KV first —
            # it is the class the policy explicitly marked, it stalls
            # nobody, and demoting it leaves the warm prefix cache and
            # its hit rate intact; cold cached pages second, node-
            # preserving: the trie survives as host nodes, promotable
            # on the next match)
            reclaimed = False
            for cls in plan.proactive_order:
                if cls is PageClass.FROZEN:
                    reclaimed = self._demote_frozen_page(
                        require_pressure=True
                    )
                elif cls is PageClass.COLD_CACHED:
                    reclaimed = self._any_demotion_pressure(
                        plan
                    ) and self.kv.demote_cold_page(float(self.tick))
                elif cls is PageClass.SCRATCH:
                    reclaimed = self.kv.evict_scratch(1) > 0
                if reclaimed:
                    break
            if not reclaimed:
                break
            budget -= 1
            self.proactive_demotions += 1
            self._update_pool()

    def _any_demotion_pressure(self, plan: PressurePlan) -> bool:
        """True when the policy marks ANY live tenant for demotion —
        gates cold-page demotion so a pressure-oblivious policy keeps
        stock (evict-on-shortage) cache behaviour."""
        if self.ecfg.legacy_bookkeeping:
            tenants = {r.tenant for r in self._live.values()}
        else:
            tenants = self.kv.ledger.projected_tenants()
        return any(
            plan.score(PageClass.FROZEN, t) > 0.0 for t in tenants
        )

    def _promotion_pass(self) -> None:
        """Start tier→HBM DMAs for pages that are now wanted, inside the
        free-page budget (never promote into overcommit).

        Stalled RUNNING work is handled first, and atomically: a request
        is promoted only when ALL of its demoted pages fit the budget — a
        partial promotion leaves it just as stalled while handing the
        reactive path a fresh page to demote, which is the
        demote/promote ping-pong livelock.  When a stalled request cannot
        be fully restored (and nothing of it is in flight), it stops
        holding a batch row hostage: its remaining pages demote and it
        rejoins through the restore queue once real headroom exists.
        Then requests the policy resumed, then reactive victims coming
        back (both slotless, so partial progress across ticks is fine)."""
        budget = self.kv.free_pages - self.kv.inflight_promotions
        now = float(self.tick)
        for r in list(self._live.values()):
            if r.slot < 0 or r.state not in ("prefill", "decoding"):
                continue
            rid = r.request_id
            demoted = self.kv.demoted_page_count(rid)
            if demoted == 0:
                continue
            if self.kv.pending_transfers(rid):
                continue  # its own DMAs are still in the air: wait
            if 0 < demoted <= budget:
                budget -= self.kv.promote_request(rid, demoted, now)
            else:
                for idx in reversed(self.kv.demotable_indices(rid)):
                    self.kv.demote_page(
                        rid, idx, self._page_payload(r.slot, idx), now
                    )
                self._set_state(r, "offloaded")
                self._release_slot(r)
        wanted: List[str] = []
        for rid in self._restore:
            if self.kv.has_demoted(rid):
                wanted.append(rid)
        for r in self._live.values():
            # reactive victims auto-return once there is headroom: queue
            # them for a batch row (the restore loop is residency-gated,
            # so they wait there until their DMAs land)
            if r.state == "offloaded":
                if r.request_id not in self._restore:
                    self._restore.append(r.request_id)
                if r.request_id not in wanted:
                    wanted.append(r.request_id)
        for rid in wanted:
            if budget <= 0:
                break
            budget -= self.kv.promote_request(rid, budget, float(self.tick))

    def _resolve_overcommit(self) -> None:
        """Restore HBM residency when the page pool is overcommitted.

        One path for every policy (no scheduler branches), each stage
        LOOPED until the overcommit clears or the stage runs dry — a
        single fat victim may not cover the deficit, and leaving overflow
        pages standing stalls decode for a full tick per victim:

          1. reclaim class by class in the pressure plan's order (stock:
             SCRATCH, then cold cached prefixes — both stall nobody and
             free pages an overflow entry can reclaim into — then
             SUSPENDED requests' frozen pages, across however many
             victims it takes: the multi-victim bugfix);
          2. the stock reactive spill: demote the fattest ACTIVE
             request's pages one by one (it stalls on its own non-resident
             pages but keeps its slot cache; with demotion disabled, fail
             it — the paper's OME).
        """

        # a tick where every slot stalled skips the decode-path pool
        # refresh — resolving against that stale snapshot demotes pages
        # that were already freed (the promote/demote flip-flop livelock)
        self._update_pool()

        def hard_over() -> bool:
            return self.kv.overflow_pages > 0 or self.pool.used_fraction > 1.0

        if not hard_over():
            return
        # the watermark is the STOP line, never the trigger: once hard
        # overcommit fired, free down past exactly-full so promotions
        # have budget — but a merely-full pool is left alone (a steady
        # 90–100% working set must not churn through demotion)
        line = (
            self.ecfg.reactive_watermark if self.ecfg.offload_enabled else 1.0
        )

        def over() -> bool:
            return (
                self.kv.overflow_pages > 0
                or self.pool.used_fraction > line
            )

        plan = self._pressure_plan()
        for cls in plan.reclaim_order:
            while over() and self._reclaim_one(cls):
                self.kv.reclaim()
                self._update_pool()
        while over():
            if not self.ecfg.offload_enabled:
                if not hard_over():
                    break
                # no tier below HBM: the stock engine throws — fail the
                # fattest active request (the paper's OME scenario)
                victim = max(
                    self._active(),
                    key=lambda r: self.kv.request_bytes(r.request_id),
                    default=None,
                )
                if victim is None:
                    break
                self._fail(victim)
                continue
            victim = max(
                (
                    r
                    for r in self._active()
                    if self.kv.demotable_indices(r.request_id)
                ),
                key=lambda r: self.kv.request_bytes(r.request_id),
                default=None,
            )
            if victim is None:
                break  # nothing left to demote: overflow must wait
            rid = victim.request_id
            self.reactive_offloads += 1
            victim.offloads += 1
            for idx in reversed(self.kv.demotable_indices(rid)):
                payload = (
                    self._page_payload(victim.slot, idx)
                    if victim.slot >= 0
                    else None
                )
                if not self.kv.demote_page(rid, idx, payload, float(self.tick)):
                    break
                self.kv.reclaim()
                self._update_pool()
                if not over():
                    break
            if not self.kv.demotable_indices(rid):
                # fully demoted: free the batch row for someone resident;
                # the request replays when its pages promote back
                if victim.state in ("decoding", "prefill"):
                    self._set_state(victim, "offloaded")
                self._release_slot(victim)
        self.kv.reclaim()

    def _fail(self, victim: Request) -> None:
        self._set_state(victim, "failed")
        victim.finish_tick = self.tick
        victim.fail_reason = "pool overcommit with offload disabled (OOM)"
        self.failed.append(victim.request_id)
        self._drop_live(victim)
        self.pool.release_owner(victim.request_id)
        self.kv.release(victim.request_id)
        self.sampler.forget(victim.request_id)
        self.policy.drop(victim.request_id)
        self._release_slot(victim)
        self._frozen_payloads.pop(victim.request_id, None)
        self.kv.reclaim()
        self._update_pool()

    def run(self, max_ticks: int = 1000) -> ServeReport:
        """Tick until drained or the budget runs out; returns the typed
        :class:`~repro.serve.report.ServeReport` (the legacy dict payload
        rides in ``report.extras``)."""
        while self.tick < max_ticks:
            if not self.has_pending:
                break
            self.step()
        return self.report()

    def memory_stats(self) -> Dict[str, Any]:
        """The ledger's class-stamped memory breakdown for this replica:
        per-class and per-tier byte totals, per-class peaks, projected
        bytes, the derived host→disk spill, and the
        ``ledger_matches_recount`` self-check (the gate hard bit)."""
        return self.kv.ledger.stats()

    def report(self) -> ServeReport:
        """Build the ServeReport for the run so far (also usable
        mid-flight — unfinished requests show up as such)."""
        lat = [
            r.finish_tick - r.submit_tick
            for r in self.requests.values()
            if r.state == "done"
        ]
        # ttft_ticks and latency_ticks must describe the SAME population
        # (completed requests): a request that emitted a first token and
        # was then shed/failed used to leak into the TTFT percentiles,
        # silently flattering them under shedding.  Failed-request TTFT
        # is reported separately — it is a real signal (work wasted past
        # first token), just not part of the serving-SLO distribution.
        ttft = [
            r.first_token_tick - r.submit_tick
            for r in self.requests.values()
            if r.state == "done" and r.first_token_tick >= 0
        ]
        ttft_failed = [
            r.first_token_tick - r.submit_tick
            for r in self.requests.values()
            if r.state == "failed" and r.first_token_tick >= 0
        ]
        prefix = dict(self.kv.prefix_stats())
        prefix["requests_hit"] = self.prefix_hits
        prefix["prefill_tokens_skipped"] = self.prefix_hit_tokens
        legacy = {
            "policy": self.policy.name,
            "model": self.cfg.name,
            "memory_class": self.spec.memory_class,
            "misroutes": self.misroutes,
            "paged_int8_ticks": self.paged_int8_ticks,
            "completed": len(self.completed),
            "failed": len(self.failed),
            "suspensions": self.suspensions,
            "peak_used_fraction": self.peak_used_fraction,
            "peak_demand_fraction": self.peak_demand_fraction,
            "offload_events": self.reactive_offloads,
            "swap_events": self.swap_outs,
            "proactive_demotions": self.proactive_demotions,
            "tiers": self.kv.tier_stats(),
            "stall_ticks": self.stall_ticks,
            "transfer_stall_ticks": self.transfer_stall_ticks,
            "mean_latency_ticks": sum(lat) / len(lat) if lat else None,
            "latency_ticks": sorted(lat),
            "ttft_ticks": sorted(ttft),
            "ttft_failed_ticks": sorted(ttft_failed),
            "prefix_cache": prefix,
            "ticks": self.tick,
            "tick_cost": self.tick_cost_stats(),
            "chunked_prefill_ticks": self.chunked_prefill_ticks,
            "migrations_in": self.migrations_in,
            "migrations_out": self.migrations_out,
            "tokens_generated": sum(
                len(r.generated) for r in self.requests.values()
            ),
            "memory_models": {
                r.request_id: r.memory_model for r in self.requests.values()
            },
            "memory": self.memory_stats(),
        }
        outcomes: List[RequestOutcome] = []
        for r in self.requests.values():
            if r.state == "done":
                outcomes.append(
                    RequestOutcome(
                        request_id=r.request_id,
                        tenant=r.tenant,
                        outcome=COMPLETED,
                        submit_tick=r.submit_tick,
                        finish_tick=r.finish_tick,
                        first_token_tick=r.first_token_tick,
                        tokens=len(r.generated),
                        model=r.model,
                    )
                )
            elif r.state == "failed":
                outcomes.append(
                    RequestOutcome(
                        request_id=r.request_id,
                        tenant=r.tenant,
                        outcome=FAILED,
                        submit_tick=r.submit_tick,
                        finish_tick=r.finish_tick,
                        first_token_tick=r.first_token_tick,
                        tokens=len(r.generated),
                        reason=r.fail_reason,
                        model=r.model,
                    )
                )
            else:
                outcomes.append(
                    RequestOutcome(
                        request_id=r.request_id,
                        tenant=r.tenant,
                        outcome=UNFINISHED,
                        submit_tick=r.submit_tick,
                        first_token_tick=r.first_token_tick,
                        tokens=len(r.generated),
                        reason=f"still {r.state} at tick budget",
                        model=r.model,
                    )
                )
        rep = ServeReport(
            policy=self.policy.name,
            submitted=self._submitted,
            ticks=self.tick,
            tokens_generated=legacy["tokens_generated"],
            throughput_tokens_per_tick=(
                legacy["tokens_generated"] / max(1, self.tick)
            ),
            outcomes=outcomes,
            tiering=legacy["tiers"],
            prefix=prefix,
            memory=legacy["memory"],
            extras=legacy,
        )
        rep.refresh_summaries()
        rep.apply_slo()  # no SLO at engine level: goodput = completion rate
        return rep
