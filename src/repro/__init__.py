"""MURS reproduction: service-oriented memory management for a
production-scale JAX/Pallas training + serving stack."""

__version__ = "0.1.0"
