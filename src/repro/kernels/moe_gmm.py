"""Grouped expert matmul Pallas kernel (capacity-dispatched MoE GEMM).

Computes ``y[e] = x[e] @ w[e]`` for E experts at once — the FLOPs hot spot
of every MoE layer after dispatch.  Tiling: grid = (E, C/bc, F/bf, D/bd)
with the contraction (D) axis innermost so a [bc, bf] f32 accumulator tile
stays in VMEM across the K sweep.  All tile dims default to 128/512 —
MXU-aligned (128×128 systolic array).

VMEM at defaults (bc=128, bf=128, bd=512, bf16 in): x 128 KiB + w 128 KiB +
acc 64 KiB ≈ 0.3 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # [bc, bd]
    w = w_ref[0]  # [bd, bf]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(di == n_d - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(
    x: jax.Array,  # [E, C, D]
    w: jax.Array,  # [E, D, F]
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    f = w.shape[-1]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    if c % block_c or f % block_f or d % block_d:
        raise ValueError(
            f"dims (C={c}, F={f}, D={d}) must divide blocks "
            f"({block_c}, {block_f}, {block_d})"
        )
    n_d = d // block_d
    kernel = functools.partial(_gmm_kernel, n_d=n_d)
    return pl.pallas_call(
        kernel,
        grid=(e, c // block_c, f // block_f, n_d),
        in_specs=[
            pl.BlockSpec(
                (1, block_c, block_d), lambda e, ci, fi, di: (e, ci, di)
            ),
            pl.BlockSpec(
                (1, block_d, block_f), lambda e, ci, fi, di: (e, di, fi)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_c, block_f), lambda e, ci, fi, di: (e, ci, fi)
        ),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
