"""Pure-jnp oracles for every Pallas kernel (the correctness reference)."""

from __future__ import annotations

import math
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # [BH, Sq, hd]
    k: jax.Array,  # [BH, Sk, hd]
    v: jax.Array,  # [BH, Sk, hd]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    sq, sk = q.shape[1], k.shape[1]
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key → zero output (matches kernel's safe-divide)
    any_valid = mask.any(axis=-1)[None, :, None]
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    out = jnp.where(any_valid, out, 0.0)
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # [BH, hd]
    k: jax.Array,  # [BH, S, hd]
    v: jax.Array,  # [BH, S, hd]
    cur_pos: int,  # attend to positions [0, cur_pos]
    *,
    window: int = 0,
) -> jax.Array:
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum(
        "bd,bkd->bk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    k_pos = jnp.arange(k.shape[1])
    mask = k_pos <= cur_pos
    if window > 0:
        mask &= k_pos > (cur_pos - window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p, v.astype(jnp.float32)).astype(q.dtype)


def grouped_matmul_ref(
    x: jax.Array,  # [E, C, d]
    w: jax.Array,  # [E, d, f]
) -> jax.Array:
    return jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def ssd_scan_ref(
    x: jax.Array,  # [B, S, nh, hd]
    dt: jax.Array,  # [B, S, nh]  (f32, post-softplus)
    A: jax.Array,  # [nh]        (negative)
    Bm: jax.Array,  # [B, S, ds]
    C: jax.Array,  # [B, S, ds]
) -> jax.Array:
    """Sequential (non-chunked) SSD recurrence — the gold reference.

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t ⊗ x_t;   y_t = C_t · h_t
    """
    b, s, nh, hd = x.shape
    ds = Bm.shape[-1]

    def step(h, inputs):
        xt, dtt, Bt, Ct = inputs  # [b,nh,hd], [b,nh], [b,ds], [b,ds]
        decay = jnp.exp(dtt * A[None, :])  # [b,nh]
        h = decay[:, :, None, None] * h + jnp.einsum(
            "bd,bhp->bhpd", Bt, dtt[..., None] * xt
        )
        y = jnp.einsum("bhpd,bd->bhp", h, Ct)
        return h, y

    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        Bm.transpose(1, 0, 2).astype(jnp.float32),
        C.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3)  # [B, S, nh, hd] f32


def paged_decode_attention_ref(
    q: jax.Array,  # [BH, hd]
    k_pool: jax.Array,  # [n_pages, page, hd]
    v_pool: jax.Array,
    page_table: jax.Array,  # [BH, max_pages]
    seq_lens: jax.Array,  # [BH]
) -> jax.Array:
    """Gather-based oracle: materialize each request's KV then attend."""
    bh, hd = q.shape
    page = k_pool.shape[1]
    max_pages = page_table.shape[1]
    k = k_pool[page_table].reshape(bh, max_pages * page, hd)
    v = v_pool[page_table].reshape(bh, max_pages * page, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum(
        "bd,bkd->bk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    tok = jnp.arange(max_pages * page)[None, :]
    s = jnp.where(tok < seq_lens[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention_int8_ref(
    q: jax.Array,  # [BH, hd]
    k_pool: jax.Array,  # [n_pages, page, hd] int8 codes
    v_pool: jax.Array,
    k_scales: jax.Array,  # [n_pages] f32
    v_scales: jax.Array,
    page_table: jax.Array,  # [BH, max_pages]
    seq_lens: jax.Array,  # [BH]
) -> jax.Array:
    """Dequantize the whole pool up front, then run the f32 oracle — the
    exact two-pass flow the in-kernel dequant is meant to eliminate."""
    k = k_pool.astype(jnp.float32) * k_scales[:, None, None]
    v = v_pool.astype(jnp.float32) * v_scales[:, None, None]
    return paged_decode_attention_ref(q, k, v, page_table, seq_lens)
