"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
interpreter executes the kernel body in Python for correctness validation)
and False on TPU, where the kernels compile to Mosaic.
"""

from __future__ import annotations

import functools

import jax

from . import ref
from .decode_attention import decode_attention as _decode_attention
from .flash_attention import flash_attention as _flash_attention
from .moe_gmm import grouped_matmul as _grouped_matmul
from .ssd_scan import ssd_scan as _ssd_scan


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal=True, window=0, q_offset=0,
    block_q=128, block_k=128, interpret=None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret")
)
def decode_attention(q, k, v, cur_pos, *, window=0, block_k=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _decode_attention(
        q, k, v, cur_pos, window=window, block_k=block_k, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret")
)
def grouped_matmul(x, w, *, block_c=128, block_f=128, block_d=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _grouped_matmul(
        x, w, block_c=block_c, block_f=block_f, block_d=block_d,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, C, *, chunk=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd_scan(x, dt, A, Bm, C, chunk=chunk, interpret=interpret)


__all__ = [
    "flash_attention",
    "decode_attention",
    "grouped_matmul",
    "ssd_scan",
    "ref",
]


from .paged_decode import paged_decode_attention as _paged_decode_attention
from .paged_decode import (
    paged_decode_attention_int8 as _paged_decode_attention_int8,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, page_table, seq_lens, *,
                           interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged_decode_attention(
        q, k_pool, v_pool, page_table, seq_lens, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_int8(q, k_pool, v_pool, k_scales, v_scales,
                                page_table, seq_lens, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged_decode_attention_int8(
        q, k_pool, v_pool, k_scales, v_scales, page_table, seq_lens,
        interpret=interpret,
    )


__all__.append("paged_decode_attention")
__all__.append("paged_decode_attention_int8")
