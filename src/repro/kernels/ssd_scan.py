"""Mamba-2 SSD chunked-scan Pallas kernel.

The SSD block decomposition (Dao & Gu, arXiv:2405.21060 §6) splits the
sequence into chunks: a *quadratic, attention-like* intra-chunk term that
maps onto the MXU, plus a rank-(d_state) *inter-chunk* state carried
sequentially.  TPU mapping: grid = (B·NH, n_chunks) with the chunk axis
innermost (sequential), the running state [hd, ds] resident in VMEM scratch
across chunks, and every intra-chunk contraction expressed as an MXU matmul
(chunk=128/256 aligns the Q×Q decay matrix to the systolic array).

Heads are processed independently (B and C are shared across heads in
Mamba-2 with n_groups=1, so they are broadcast per head outside).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # [1, Q, hd]
    dt_ref,  # [1, Q, 1]   (dt · A already folded: dA = dt * A[head])
    dtb_ref,  # [1, Q, 1]  raw dt (the B⊗x weight)
    b_ref,  # [1, Q, ds]
    c_ref,  # [1, Q, ds]
    y_ref,  # [1, Q, hd]
    h_ref,  # VMEM scratch [hd, ds] — running inter-chunk state
    *, n_chunks: int, chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)  # [Q, hd]
    dA = dt_ref[0, :, 0].astype(jnp.float32)  # [Q]  (negative)
    dt = dtb_ref[0, :, 0].astype(jnp.float32)  # [Q]
    B = b_ref[0].astype(jnp.float32)  # [Q, ds]
    C = c_ref[0].astype(jnp.float32)  # [Q, ds]

    seg = jnp.cumsum(dA)  # [Q]
    total = seg[-1]

    # ---- intra-chunk: attention-form  M = (C Bᵀ) ∘ L ∘ dt_j
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q]
    rel = seg[:, None] - seg[None, :]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    # mask before exp (upper-triangle rel > 0 overflows; see blocks._ssd_scan)
    L = jnp.exp(jnp.where(causal, rel, -jnp.inf))
    M = scores * L * dt[None, :]
    y = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, hd]

    # ---- inter-chunk: y += exp(seg_i) · C_i · H_in
    h_in = h_ref[...]  # [hd, ds]
    y += jnp.exp(seg)[:, None] * jax.lax.dot_general(
        C, h_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # ---- state update:  H = exp(total)·H_in + Σ_j exp(total−seg_j)·dt_j·x_jᵀB_j
    w = jnp.exp(total - seg) * dt  # [Q]
    outer = jax.lax.dot_general(
        x * w[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [hd, ds]
    h_ref[...] = jnp.exp(total) * h_in + outer

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(
    x: jax.Array,  # [B, S, nh, hd]
    dt: jax.Array,  # [B, S, nh]  (f32, post-softplus)
    A: jax.Array,  # [nh] (negative)
    Bm: jax.Array,  # [B, S, ds]
    C: jax.Array,  # [B, S, ds]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns y [B, S, nh, hd] (f32). D-skip and gating stay outside."""
    b, s, nh, hd = x.shape
    ds = Bm.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} must divide chunk {chunk}")
    n_chunks = s // chunk

    # flatten (B, nh) → BH and broadcast shared B/C per head
    xf = x.transpose(0, 2, 1, 3).reshape(b * nh, s, hd)
    dA = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(b * nh, s, 1)
    dtf = dt.transpose(0, 2, 1).reshape(b * nh, s, 1)
    Bf = jnp.broadcast_to(Bm[:, None], (b, nh, s, ds)).reshape(b * nh, s, ds)
    Cf = jnp.broadcast_to(C[:, None], (b, nh, s, ds)).reshape(b * nh, s, ds)

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b * nh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda h, ci: (h, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, ci: (h, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, ci: (h, ci, 0)),
            pl.BlockSpec((1, chunk, ds), lambda h, ci: (h, ci, 0)),
            pl.BlockSpec((1, chunk, ds), lambda h, ci: (h, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda h, ci: (h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nh, s, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xf, dA, dtf, Bf, Cf)
    return y.reshape(b, nh, s, hd).transpose(0, 2, 1, 3)
