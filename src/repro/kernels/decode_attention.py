"""Flash-decode Pallas kernel: one query token vs a long KV cache.

Decode attention is memory-bound (the whole KV cache streams through once
per token), so the kernel's job is to keep that stream at full HBM bandwidth
with zero materialization of logits in HBM: grid = (BH, kv_blocks), online
max/sum accumulators in VMEM scratch, [1, hd] output written once.

The valid-length bound ``cur_pos`` is a *runtime* scalar (serving-time
cache fill level) passed via scalar prefetch (SMEM), so one compiled kernel
serves every request length.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(
    pos_ref,  # SMEM scalar-prefetch: [1] int32 (cur_pos)
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, window: int, block_k: int, n_k: int,
):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cur_pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32)  # [1, hd]
    k = k_ref[0].astype(jnp.float32)  # [bk, hd]
    v = v_ref[0].astype(jnp.float32)  # [bk, hd]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [1, bk]
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = k_pos <= cur_pos
    if window > 0:
        mask &= k_pos > (cur_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # [BH, hd]
    k: jax.Array,  # [BH, S, hd]
    v: jax.Array,  # [BH, S, hd]
    cur_pos,  # int32 scalar (runtime)
    *,
    window: int = 0,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    bh, s, hd = k.shape
    block_k = min(block_k, s)
    if s % block_k:
        raise ValueError(f"cache length {s} must divide block_k {block_k}")
    n_k = s // block_k
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, block_k=block_k, n_k=n_k
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, ki, pos: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ki, pos: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ki, pos: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, ki, pos: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    pos = jnp.asarray(cur_pos, jnp.int32).reshape(1)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, 1, hd), q.dtype),
        interpret=interpret,
    )(pos, q[:, None, :], k, v)
    return out[:, 0, :]
