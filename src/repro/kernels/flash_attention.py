"""Flash attention Pallas TPU kernel (causal / sliding-window, GQA-ready).

Tiling: grid = (batch×heads, q_blocks, kv_blocks); the kv axis is the
innermost (sequential on TPU), so the online-softmax accumulators live in
VMEM scratch across kv steps.  Block shapes default to 128×128 — MXU-aligned
(the systolic array is 128×128) — with the f32 accumulator [bq, hd] kept
resident in VMEM for the whole kv sweep (HBM traffic: Q once, K/V once,
O once — the flash property).

VMEM budget at defaults (bq=bk=128, hd≤256, bf16 in / f32 acc):
    q 64 KiB + k 64 KiB + v 64 KiB + acc 128 KiB + stats 1 KiB ≈ 0.3 MiB
— far under the ~16 MiB/core limit, leaving room for double buffering.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int, q_offset: int,
    block_q: int, block_k: int, n_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # [bq, hd]
    k = k_ref[0].astype(jnp.float32)  # [bk, hd]
    v = v_ref[0].astype(jnp.float32)  # [bk, hd]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)  # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [BH, Sq, hd] (batch×heads flattened, KV pre-repeated)
    k: jax.Array,  # [BH, Sk, hd]
    v: jax.Array,  # [BH, Sk, hd]
    *,
    causal: bool = True,
    window: int = 0,  # 0 = disabled
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, hd = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(
            f"seq lengths ({sq}, {sk}) must divide blocks ({block_q}, {block_k})"
        )
    n_q = sq // block_q
    n_k = sk // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, n_k=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running sum
        ],
        interpret=interpret,
    )(q, k, v)
