"""Paged flash-decode Pallas kernel (vLLM-style block-table indirection).

Serving engines fragment each request's KV cache into fixed-size PAGES drawn
from a shared pool — the free-list ``PageBlockAllocator`` in
``repro.serve.kv_cache``, whose per-request page tables
(``PagedKVManager.table_array``) are exactly the ``page_table`` operand
below; decode attention must then gather a request's pages via its block
table.  On TPU the indirection maps onto
**scalar-prefetched BlockSpec index_maps**: the page table lives in SMEM and
the grid's page step picks which pool page the next VMEM DMA fetches —
no gather materialization, the KV stream stays at HBM bandwidth.

Layout:
    q           [BH, hd]               one query token per request×head
    k/v pool    [n_pages, page, hd]    the shared page pool (per head-group)
    page_table  [BH, max_pages] int32  pool index of each logical page
    seq_lens    [BH] int32             valid tokens per request

Grid = (BH, max_pages), page axis innermost/sequential; online-softmax
accumulators persist in VMEM scratch across the page sweep.  Pages past a
request's length are masked entirely (their DMA is wasted but harmless;
production tables sort requests by length to trim the grid).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    table_ref,  # scalar-prefetch: [BH, max_pages] int32
    lens_ref,  # scalar-prefetch: [BH] int32
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, page: int, n_pages: int,
):
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = lens_ref[b]
    q = q_ref[0].astype(jnp.float32)  # [1, hd]
    k = k_ref[0].astype(jnp.float32)  # [page, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [1, page]
    tok = pi * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(tok < seq_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def _paged_kernel_int8(
    table_ref,  # scalar-prefetch: [BH, max_pages] int32
    lens_ref,  # scalar-prefetch: [BH] int32
    k_scale_ref,  # scalar-prefetch: [n_pool_pages] f32 per-page K scale
    v_scale_ref,  # scalar-prefetch: [n_pool_pages] f32 per-page V scale
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, page: int, n_pages: int,
):
    """int8-KV page sweep: pool pages are ``dist/compression.py`` codes
    (symmetric int8, amax/127 scale) and the dequant happens HERE, between
    the DMA and the dot — a page promoted from the compressed host tier
    never needs the separate dequantize/write-back pass ``tick_tiers``
    otherwise runs."""
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = lens_ref[b]
    pid = table_ref[b, pi]
    q = q_ref[0].astype(jnp.float32)  # [1, hd]
    k = k_ref[0].astype(jnp.float32) * k_scale_ref[pid]  # [page, hd]
    v = v_ref[0].astype(jnp.float32) * v_scale_ref[pid]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [1, page]
    tok = pi * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(tok < seq_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def paged_decode_attention_int8(
    q: jax.Array,  # [BH, hd]
    k_pool: jax.Array,  # [n_pool_pages, page, hd] int8 codes
    v_pool: jax.Array,  # [n_pool_pages, page, hd] int8 codes
    k_scales: jax.Array,  # [n_pool_pages] f32 per-page scale
    v_scales: jax.Array,  # [n_pool_pages] f32 per-page scale
    page_table: jax.Array,  # [BH, max_pages] int32
    seq_lens: jax.Array,  # [BH] int32
    *,
    interpret: bool = False,
) -> jax.Array:
    bh, hd = q.shape
    _, page, _ = k_pool.shape
    max_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _paged_kernel_int8, scale=scale, page=page, n_pages=max_pages
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bh, max_pages),
        in_specs=[
            pl.BlockSpec(
                (1, 1, hd), lambda b, pi, table, lens, ks, vs: (b, 0, 0)
            ),
            pl.BlockSpec(
                (1, page, hd),
                lambda b, pi, table, lens, ks, vs: (table[b, pi], 0, 0),
            ),
            pl.BlockSpec(
                (1, page, hd),
                lambda b, pi, table, lens, ks, vs: (table[b, pi], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, hd), lambda b, pi, table, lens, ks, vs: (b, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, 1, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      k_scales.astype(jnp.float32), v_scales.astype(jnp.float32),
      q[:, None, :], k_pool, v_pool)
    return out[:, 0, :]


def paged_decode_attention(
    q: jax.Array,  # [BH, hd]
    k_pool: jax.Array,  # [n_pool_pages, page, hd]
    v_pool: jax.Array,  # [n_pool_pages, page, hd]
    page_table: jax.Array,  # [BH, max_pages] int32
    seq_lens: jax.Array,  # [BH] int32
    *,
    interpret: bool = False,
) -> jax.Array:
    bh, hd = q.shape
    _, page, _ = k_pool.shape
    max_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _paged_kernel, scale=scale, page=page, n_pages=max_pages
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bh, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, pi, table, lens: (b, 0, 0)),
            # the indirection: the page axis fetches pool page table[b, pi]
            pl.BlockSpec(
                (1, page, hd), lambda b, pi, table, lens: (table[b, pi], 0, 0)
            ),
            pl.BlockSpec(
                (1, page, hd), lambda b, pi, table, lens: (table[b, pi], 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, pi, table, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, 1, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      q[:, None, :], k_pool, v_pool)
    return out[:, 0, :]
