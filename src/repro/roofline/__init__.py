"""Roofline analysis of dry-run compile records."""
