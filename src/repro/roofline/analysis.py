"""Three-term roofline analysis from dry-run artifacts.

Per (arch × shape × mesh) cell, from the compiled dry-run record:

    compute    = HLO_FLOPs_per_device   / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device   / HBM_bandwidth_per_chip
    collective = collective_bytes_per_device / ICI_link_bandwidth

``cost_analysis()`` of an SPMD executable reports the per-device partitioned
module, so all three terms are per-chip seconds directly (the global
formulation of the assignment divides global quantities by chip count —
identical numbers).  MODEL_FLOPS uses 6·N·T (train) / 2·N·T (inference)
with N = active params, plus the causal-attention term; the ratio
MODEL_FLOPS / (HLO_FLOPs × chips) measures how much compiled compute is
"useful" (remat recompute, dispatch overheads and padding show up here).

TPU v5e chip constants (assignment-specified).
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import ARCHS, SHAPES

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
PCIE_BW = 32e9  # bytes/s host link (Gen4 ×16 class) — KV offload/promote path

CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole step (global, all chips)."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len

    counts: Dict[str, int] = {}
    for blk in (
        list(cfg.block_pattern) * cfg.resolved_pattern_repeats
        + list(cfg.suffix_blocks)
    ):
        counts[blk] = counts.get(blk, 0) + 1
    full_attn = counts.get("attn", 0) + counts.get("shared_attn", 0)
    local_attn = counts.get("local_attn", 0)
    qk_dim = cfg.n_heads * cfg.head_dim if cfg.n_heads else 0

    if shape.kind == "train":
        tokens = b * s
        flops = 6.0 * n_active * tokens
        # causal attention: fwd 2·(QK+PV)·0.5, bwd ×2 → 6·S²·qk·0.5
        flops += 6.0 * full_attn * b * s * s * qk_dim
        flops += 6.0 * local_attn * b * s * min(s, cfg.sliding_window) * qk_dim
        return flops
    if shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_active * tokens
        flops += 2.0 * full_attn * b * s * s * qk_dim
        flops += 2.0 * local_attn * b * s * min(s, cfg.sliding_window) * qk_dim
        return flops
    # decode: one token over a seq_len cache
    flops = 2.0 * n_active * b
    flops += 4.0 * full_attn * b * s * qk_dim  # QK + PV over the cache
    flops += 4.0 * local_attn * b * min(s, cfg.sliding_window) * qk_dim
    if cfg.ssm is not None and counts.get("mamba"):
        ssm = cfg.ssm
        flops += (
            4.0 * counts["mamba"] * b
            * ssm.n_heads(cfg.d_model) * ssm.head_dim * ssm.d_state
        )
    return flops


def memory_traffic_bytes(arch: str, shape_name: str) -> float:
    """Analytic minimum HBM traffic per step (global bytes).

    The compiled ``cost_analysis()`` on the CPU backend does NOT scale
    while-loop bodies by trip count (scan-over-layers ⇒ up to L× FLOP/byte
    undercount), so the roofline's primary memory term is this analytic
    envelope (raw compiled numbers stay in the table for reference):

      decode : weights once per step + whole KV cache + constant states
      prefill: weights + KV written + activations (8 B/elem/layer envelope)
      train  : params+opt traffic (20·N: bf16 p r/w, f32 m,v r/w, grads)
               + activations 24 B/elem/layer (fwd save + bwd touch, bf16)
    """
    from repro.serve.kv_cache import constant_state_bytes, kv_bytes_per_token

    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    kv_tok = kv_bytes_per_token(cfg)
    states = constant_state_bytes(cfg)
    d, L = cfg.d_model, cfg.n_layers

    if shape.kind == "decode":
        experts_touched = 1.0
        weights = 2.0 * n_total  # bf16; all experts touched at batch≥128
        kv = b * (kv_tok * s + states)
        acts = b * L * d * 24.0
        return weights + kv + acts
    if shape.kind == "prefill":
        tokens = b * s
        weights = 2.0 * n_active * max(1.0, 1.0)  # streamed once (batched)
        kv_write = b * (kv_tok * s + states)
        acts = tokens * L * d * 8.0
        return weights + kv_write + acts
    tokens = b * s
    opt_traffic = 20.0 * n_total
    acts = tokens * L * d * 24.0
    return opt_traffic + acts


@dataclass(frozen=True)
class ServingTickCost:
    """Roofline-derived cost (seconds) of one :class:`ServingEngine` tick.

    Built once per engine from its ``ArchConfig`` via :func:`tick_cost_model`;
    the engine feeds it per-tick work counters and gets back the same
    three-term roofline the dry-run analysis applies to offline shapes:

        memory_s  = (weight stream + KV pages touched + activations) / HBM_BW
        compute_s = 2·N_active·tokens / PEAK_FLOPS
        stall_s   = stalled page traffic / PCIE_BW   (serial: DMA blocks decode)

        tick_seconds = max(memory_s, compute_s) + stall_s

    Decode is HBM-bound at serving batch sizes (the *Managed Big Data
    Analytics Frameworks* throughput analysis in PAPERS.md is the same
    argument at the framework level), so memory_s dominates in practice;
    the max() keeps the model honest if a config ever flips compute-bound.
    A tick that ran no forward pass (admission/bookkeeping only) costs one
    ``idle_s`` — small but nonzero so cluster straggler statistics, which
    multiply observed tick cost by host slowdown, keep a live signal.
    """

    weight_bytes: float  # bf16 weight stream, read once per forward tick
    active_params: float  # FLOP term: 2·active_params per token
    kv_write_bytes_per_token: float  # KV appended per prefilled token
    act_bytes_per_token: float  # activation traffic envelope per token
    page_bytes: float  # one KV page (the stall DMA unit)
    idle_s: float = 1e-6
    hbm_bw: float = HBM_BW
    peak_flops: float = PEAK_FLOPS
    pcie_bw: float = PCIE_BW

    def tick_seconds(
        self,
        *,
        decode_tokens: int = 0,
        prefill_tokens: int = 0,
        kv_bytes_read: float = 0.0,
        stall_events: int = 0,
    ) -> float:
        """Seconds for one tick that decoded ``decode_tokens`` requests
        (reading ``kv_bytes_read`` of resident KV), consumed
        ``prefill_tokens`` of prompt, and hit ``stall_events`` page-pool
        stalls (each charged one page DMA over the host link)."""
        tokens = decode_tokens + prefill_tokens
        stall_s = stall_events * (self.page_bytes / self.pcie_bw)
        if tokens <= 0:
            return self.idle_s + stall_s
        mem = (
            self.weight_bytes
            + kv_bytes_read
            + prefill_tokens * self.kv_write_bytes_per_token
            + tokens * self.act_bytes_per_token
        ) / self.hbm_bw
        comp = 2.0 * self.active_params * tokens / self.peak_flops
        return max(mem, comp) + stall_s


def tick_cost_model(cfg, page_tokens: int = 16) -> ServingTickCost:
    """Build a :class:`ServingTickCost` from an ``ArchConfig`` instance.

    Mirrors the decode branch of :func:`memory_traffic_bytes` but takes the
    config object directly (the serving engine holds a cfg, not an ARCHS
    key) and splits the per-step envelope into per-token coefficients the
    engine can scale by its actual per-tick batch."""
    from repro.serve.kv_cache import kv_bytes_per_token

    kv_tok = kv_bytes_per_token(cfg)
    return ServingTickCost(
        weight_bytes=2.0 * cfg.param_count(),
        active_params=float(cfg.active_param_count()),
        kv_write_bytes_per_token=float(kv_tok),
        act_bytes_per_token=cfg.n_layers * cfg.d_model * 24.0,
        page_bytes=float(kv_tok * page_tokens),
    )


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    compute_s: float  # analytic useful compute (remat-adjusted) / peak
    memory_s: float  # analytic traffic / HBM bw
    collective_s: float  # HLO-parsed collective payload / ICI
    model_flops: float
    hlo_flops_global: float
    hlo_compute_s: float  # raw compiled cost_analysis (scan-undercounted)
    hlo_memory_s: float
    temp_bytes: Optional[int]
    collectives: Dict[str, dict]

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time bound: no-overlap = max of the three terms
        (each unit is independently saturable)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        if self.hlo_flops_global <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops_global

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the step-time bound:
        (useful FLOPs / chips / step_time) / peak — the §Perf score."""
        chips = CHIPS[self.mesh]
        if self.step_time_s <= 0:
            return 0.0
        return (self.model_flops / chips / self.step_time_s) / PEAK_FLOPS


def load_cell(record: dict) -> Optional[RooflineCell]:
    if record.get("skipped") or "error" in record:
        return None
    cost = record.get("cost", {})
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll = record.get("collectives", {})
    coll_bytes = sum(v.get("bytes", 0) for v in coll.values())
    chips = CHIPS[record["mesh"]]
    arch, shape = record["arch"], record["shape"]
    mflops = model_flops(arch, shape)
    remat = 4.0 / 3.0 if record.get("kind") == "train" else 1.0
    traffic = memory_traffic_bytes(arch, shape)
    return RooflineCell(
        arch=arch,
        shape=shape,
        mesh=record["mesh"],
        compute_s=mflops * remat / chips / PEAK_FLOPS,
        memory_s=traffic / chips / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        model_flops=mflops,
        hlo_flops_global=flops_dev * chips,
        hlo_compute_s=flops_dev / PEAK_FLOPS,
        hlo_memory_s=bytes_dev / HBM_BW,
        temp_bytes=record.get("memory", {}).get("temp_size_in_bytes"),
        collectives=coll,
    )


def load_all(dryrun_dir: str, mesh: str = "16x16") -> List[RooflineCell]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        cell = load_cell(rec)
        if cell is not None:
            cells.append(cell)
    return cells


def markdown_table(cells: List[RooflineCell]) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| roofline frac | HLO compute s | HLO memory s | temp GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for c in cells:
        temp = f"{c.temp_bytes / 2**30:.1f}" if c.temp_bytes else "–"
        rows.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | {c.memory_s:.3e} "
            f"| {c.collective_s:.3e} | **{c.bottleneck}** "
            f"| {c.roofline_fraction:.3f} "
            f"| {c.hlo_compute_s:.2e} | {c.hlo_memory_s:.2e} | {temp} |"
        )
    return hdr + "\n".join(rows) + "\n"


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    cells = load_all(args.dir, args.mesh)
    print(markdown_table(cells))
    worst = sorted(cells, key=lambda c: c.roofline_fraction)[:3]
    coll = sorted(cells, key=lambda c: -c.collective_s)[:3]
    print("\nworst roofline fraction:", [(c.arch, c.shape) for c in worst])
    print("most collective-bound:", [(c.arch, c.shape) for c in coll])


if __name__ == "__main__":
    main()
