"""Config registry: one module per assigned architecture."""

from typing import Dict

from .base import (
    ArchConfig,
    MEMORY_CLASSES,
    MLAConfig,
    MoEConfig,
    ModelSpec,
    SSMConfig,
    ShapeConfig,
    SHAPES,
)
from .granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from .deepseek_v2_236b import CONFIG as deepseek_v2_236b
from .internlm2_1_8b import CONFIG as internlm2_1_8b
from .stablelm_1_6b import CONFIG as stablelm_1_6b
from .gemma3_1b import CONFIG as gemma3_1b
from .qwen1_5_110b import CONFIG as qwen1_5_110b
from .internvl2_26b import CONFIG as internvl2_26b
from .whisper_base import CONFIG as whisper_base
from .mamba2_2_7b import CONFIG as mamba2_2_7b
from .zamba2_1_2b import CONFIG as zamba2_1_2b

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        granite_moe_3b_a800m,
        deepseek_v2_236b,
        internlm2_1_8b,
        stablelm_1_6b,
        gemma3_1b,
        qwen1_5_110b,
        internvl2_26b,
        whisper_base,
        mamba2_2_7b,
        zamba2_1_2b,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ArchConfig",
    "MEMORY_CLASSES",
    "ModelSpec",
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCHS",
    "get_arch",
]
