"""whisper-base [arXiv:2212.04356; unverified].

Encoder-decoder, 6+6 layers, d_model=512 8H d_ff=2048 vocab=51865.  The conv
frontend is a STUB: input_specs() provides precomputed frame embeddings at
the post-conv rate (seq_len // 2 encoder positions).  Shape adaptation
(DESIGN.md §4): train_4k = enc 2048 frames + dec seq 448; prefill = encoder
forward + cross-KV build; decode = decoder step against the cross-KV.
long_500k skipped (full attention).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,            # decoder layers
    enc_layers=6,
    enc_seq_divisor=2,     # conv stub downsamples 2x
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    frontend="audio_stub",
)
