"""zamba2-1.2b [arXiv:2411.15242; hf].

38 blocks, d_model=2048: Mamba2 backbone (d_state=64) with a SHARED
full-attention block invoked every 6th position (32H kv=32, d_ff=8192 MLP in
the shared block).  Block program: (mamba ×5, shared_attn) ×6 + mamba ×2.
Hybrid: runs long_500k (mamba decode state constant; shared-attn KV linear).
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_000,
    d_head=64,
    block_pattern=("mamba",) * 5 + ("shared_attn",),
    pattern_repeats=6,
    suffix_blocks=("mamba", "mamba"),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    applicable_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
