"""internvl2-26b [arXiv:2404.16821; hf].

InternViT-6B + InternLM2-20B backbone; this entry specifies the language
BACKBONE (48L d_model=6144 48H GQA kv=8 d_ff=16384 vocab=92553).  The vision
frontend is a STUB: input_specs() provides precomputed patch embeddings
(256 tokens/image tile after pixel-shuffle) that are concatenated with the
token embeddings.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,
    vocab=92_553,
    frontend="vision_stub",
    vision_tokens=256,
)
