"""mamba2-2.7b [arXiv:2405.21060; unverified].

64L d_model=2560, attention-free SSD (state-space duality), d_state=128.
Decode state is CONSTANT-size — the constant-model arch in the MURS
classification; long_500k applies (sub-quadratic by construction).
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    block_pattern=("mamba",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    applicable_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
