"""Architecture + shape configuration system.

Every assigned architecture is described by an :class:`ArchConfig`; layer
stacking uses a *block program* — ``pattern × repeats + suffix`` — so that
heterogeneous stacks (gemma3's 5:1 local:global, zamba2's mamba+shared-attn)
scan over the repeating unit while staying O(1) in HLO size.

Shapes are the four assigned input-shape cells; ``applicable_shapes`` encodes
the per-family skips mandated by the assignment (see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ----------------------------------------------------------------- shapes
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ------------------------------------------------------------- sub-configs
@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention dims [arXiv:2405.04434]."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD dims [arXiv:2405.21060]."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


# --------------------------------------------------------- memory classes
#: how an architecture's per-request serving state grows (DESIGN.md §12):
#: ``paged_kv`` grows linearly with context (pool pages), ``constant_state``
#: is O(1) regardless of context (mamba conv+SSD state, sliding windows),
#: ``encoder_decoder`` adds a one-shot encoder-side block at prefill on top
#: of decoder KV, and ``zero_kv`` holds no serving state at all (degenerate
#: configs; useful as the zero-pool control).
MEMORY_CLASSES: Tuple[str, ...] = (
    "paged_kv",
    "constant_state",
    "encoder_decoder",
    "zero_kv",
)


# ------------------------------------------------------------- arch config
@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # default d_model // n_heads
    # block program: pattern repeated, then suffix (each entry a block type:
    # "attn" | "local_attn" | "mamba" | "shared_attn")
    block_pattern: Tuple[str, ...] = ("attn",)
    pattern_repeats: Optional[int] = None  # default n_layers / len(pattern)
    suffix_blocks: Tuple[str, ...] = ()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    sliding_window: int = 1024  # for "local_attn" blocks
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # encoder-decoder (whisper): encoder layer count; decoder = n_layers
    enc_layers: int = 0
    enc_seq_divisor: int = 1  # encoder positions = seq_len // divisor
    frontend: str = "none"  # none | audio_stub | vision_stub
    #: number of frontend patch/frame embeddings for VLM (per sample)
    vision_tokens: int = 0
    #: which of the four shape cells apply (long_500k skipped for pure
    #: full-attention archs per the assignment; see DESIGN.md §4)
    applicable_shapes: Tuple[str, ...] = (
        "train_4k",
        "prefill_32k",
        "decode_32k",
    )
    #: reduced-config overrides used by smoke tests (CPU-runnable)
    smoke_overrides: Dict[str, object] = field(default_factory=dict)

    @property
    def head_dim(self) -> int:
        if self.d_head is not None:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def resolved_pattern_repeats(self) -> int:
        if self.pattern_repeats is not None:
            return self.pattern_repeats
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern {self.block_pattern}; set pattern_repeats + suffix"
        )
        return self.n_layers // len(self.block_pattern)

    def __post_init__(self) -> None:
        total = self.resolved_pattern_repeats * len(self.block_pattern) + len(
            self.suffix_blocks
        )
        if total != self.n_layers:
            raise ValueError(
                f"{self.name}: block program covers {total} layers, "
                f"config says {self.n_layers}"
            )

    # ------------------------------------------------------------- helpers
    def smoke(self) -> "ArchConfig":
        """A reduced same-family config for CPU smoke tests."""
        base = dict(
            n_layers=len(self.block_pattern) * 2 + len(self.suffix_blocks),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            d_ff=128,
            vocab=512,
            d_head=16,
            pattern_repeats=2,
            vision_tokens=min(self.vision_tokens, 8),
            enc_layers=2 if self.enc_layers else 0,
        )
        if self.moe is not None:
            base["moe"] = MoEConfig(
                num_experts=8,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                num_shared_experts=self.moe.num_shared_experts,
                d_ff_shared=64 if self.moe.num_shared_experts else 0,
            )
        if self.mla is not None:
            base["mla"] = MLAConfig(
                kv_lora_rank=32,
                q_lora_rank=48,
                qk_nope_head_dim=16,
                qk_rope_head_dim=8,
                v_head_dim=16,
            )
        if self.ssm is not None:
            base["ssm"] = SSMConfig(
                d_state=16, expand=2, head_dim=16, chunk_size=32
            )
        base["sliding_window"] = min(self.sliding_window, 16)
        base.update(self.smoke_overrides)
        return dataclasses.replace(self, name=f"{self.name}-smoke", **base)

    def param_count(self) -> float:
        """Analytic total parameter count (for 6·N·D roofline terms)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = 2.0 * self.vocab * d if not self.tie_embeddings else self.vocab * d
        counts = self._block_counts()
        for blk, cnt in counts.items():
            total += cnt * self._block_params(blk)
        # final norm
        total += d
        if self.enc_layers:
            total += self.enc_layers * (
                4 * d * d + 2 * self.d_ff * d  # self-attn + mlp (enc)
            )
        return total

    def active_param_count(self) -> float:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        moe = self.moe
        dense_total = self.param_count()
        all_expert = L * moe.num_experts * 3 * d * moe.d_ff_expert
        active_expert = L * moe.top_k * 3 * d * moe.d_ff_expert
        return dense_total - all_expert + active_expert

    def _block_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for b in (
            list(self.block_pattern) * self.resolved_pattern_repeats
            + list(self.suffix_blocks)
        ):
            counts[b] = counts.get(b, 0) + 1
        return counts

    # ----------------------------------------------------- serving byte model
    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> float:
        """Marginal HBM bytes appended per decoded token — the pool-page
        growth rate the paged KV manager allocates against.

        Full-attention blocks append one K+V (or one MLA latent) per
        token; local/sliding-window blocks are bounded by the window and
        mamba blocks by their state, so both contribute 0 here (their
        bytes live in :meth:`constant_state_bytes`)."""
        counts = self._block_counts()
        per_tok = 0.0
        if self.mla is not None:
            lat = self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
            per_tok += (
                counts.get("attn", 0) + counts.get("local_attn", 0)
            ) * lat * dtype_bytes
        else:
            kv = 2 * self.n_kv_heads * self.head_dim * dtype_bytes
            per_tok += counts.get("attn", 0) * kv
            per_tok += counts.get("shared_attn", 0) * kv
        return per_tok

    def constant_state_bytes(self, dtype_bytes: int = 2) -> float:
        """Fixed per-request state bytes, independent of context length:
        mamba conv tail + SSD state (f32), and sliding-window KV rings
        for non-MLA local-attention blocks."""
        counts = self._block_counts()
        total = 0.0
        if self.ssm is not None and counts.get("mamba", 0):
            di = self.ssm.d_inner(self.d_model)
            conv = (
                (self.ssm.d_conv - 1)
                * (di + 2 * self.ssm.d_state)
                * dtype_bytes
            )
            state = (
                self.ssm.n_heads(self.d_model)
                * self.ssm.head_dim
                * self.ssm.d_state
                * 4
            )
            total += counts["mamba"] * (conv + state)
        if self.mla is None and counts.get("local_attn", 0):
            kv = 2 * self.n_kv_heads * self.head_dim * dtype_bytes
            total += counts["local_attn"] * kv * self.sliding_window
        return total

    def encoder_bytes(self, prompt_tokens: int, dtype_bytes: int = 2) -> float:
        """One-shot encoder-side bytes an encoder-decoder pays at prefill:
        cross-attention K+V over the encoder positions, per encoder layer.
        0 for decoder-only architectures."""
        if not self.enc_layers or prompt_tokens <= 0:
            return 0.0
        enc_positions = max(1, prompt_tokens // max(1, self.enc_seq_divisor))
        kv = 2 * self.n_kv_heads * self.head_dim * dtype_bytes
        return float(self.enc_layers * enc_positions * kv)

    def context_bytes(self, n_tokens: int, dtype_bytes: int = 2) -> float:
        """Total per-request serving bytes at a context of ``n_tokens`` —
        linear term + constant state + encoder side.  Monotone
        non-decreasing in ``n_tokens`` for every architecture (the smoke
        test's invariant)."""
        n = max(0, n_tokens)
        return (
            self.kv_bytes_per_token(dtype_bytes) * n
            + self.constant_state_bytes(dtype_bytes)
            + self.encoder_bytes(n, dtype_bytes)
        )

    def memory_class(self) -> str:
        """Which of :data:`MEMORY_CLASSES` this architecture belongs to.

        Encoder-decoder wins over the others (whisper also carries
        decoder KV); otherwise any linear KV growth makes it
        ``paged_kv`` (zamba2's shared-attn KV keeps the hybrid here),
        pure O(1) state is ``constant_state`` (mamba2), and a config
        with no serving state at all is ``zero_kv``."""
        if self.enc_layers:
            return "encoder_decoder"
        if self.kv_bytes_per_token() > 0:
            return "paged_kv"
        if self.constant_state_bytes() > 0:
            return "constant_state"
        return "zero_kv"

    def spec(self) -> "ModelSpec":
        """The frozen :class:`ModelSpec` serving layers key slots,
        replicas, and policy decisions by."""
        return ModelSpec.from_config(self)

    def _block_params(self, blk: str) -> float:
        d = self.d_model
        hd = self.head_dim
        if blk == "mamba":
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
            return (
                d * (2 * di + 2 * self.ssm.d_state + nh)
                + di * d
                + self.ssm.d_conv * (di + 2 * self.ssm.d_state)
                + 2 * nh
            )
        # attention blocks
        if self.mla is not None:
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * qk_dim
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank
                * self.n_heads
                * (m.qk_nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        else:
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
                self.n_heads * hd
            ) * d
        if blk == "shared_attn":
            attn += 2 * d * d  # zamba-style in/out adapters around shared block
        # MLP
        if self.moe is not None:
            moe = self.moe
            mlp = moe.num_experts * 3 * d * moe.d_ff_expert + d * moe.num_experts
            mlp += moe.num_shared_experts * 3 * d * moe.d_ff_shared
        else:
            mlp = 3 * d * self.d_ff  # gated (SwiGLU) MLP
        return attn + mlp + 2 * d  # + norms


# -------------------------------------------------------------- model spec
@dataclass(frozen=True)
class ModelSpec:
    """The serving identity of one architecture: arch id + memory class +
    the byte-model scalars every layer above configs keys decisions by
    (engine admission, pool geometry, policy scoring, cluster routing).

    Derived from :class:`ArchConfig` via :meth:`from_config` /
    :meth:`ArchConfig.spec`; hashable and frozen so it can key dicts and
    cross replica boundaries by value."""

    arch: str
    memory_class: str  # one of MEMORY_CLASSES
    kv_bytes_per_token: float
    constant_state_bytes: float
    enc_layers: int = 0
    enc_seq_divisor: int = 1

    def __post_init__(self) -> None:
        if self.memory_class not in MEMORY_CLASSES:
            raise ValueError(
                f"{self.arch}: unknown memory class "
                f"{self.memory_class!r}; expected one of {MEMORY_CLASSES}"
            )

    @classmethod
    def from_config(cls, cfg: ArchConfig) -> "ModelSpec":
        """Snapshot the config's serving-relevant byte model."""
        return cls(
            arch=cfg.name,
            memory_class=cfg.memory_class(),
            kv_bytes_per_token=cfg.kv_bytes_per_token(),
            constant_state_bytes=cfg.constant_state_bytes(),
            enc_layers=cfg.enc_layers,
            enc_seq_divisor=cfg.enc_seq_divisor,
        )

    @property
    def grows_with_context(self) -> bool:
        """True when per-request bytes scale with context length — the
        axis MURS usage-rate classification runs on.  A constant-state
        tenant's demand is flat no matter how long it decodes."""
        return self.kv_bytes_per_token > 0
