"""qwen1.5-110b [hf:Qwen/Qwen1.5-0.5B family; hf]. Dense GQA + QKV bias."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49_152,
    vocab=152_064,
    qkv_bias=True,
)
