"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40 experts top-8.
Note: the assignment header says "MoE 40e top-8" while its bracket note says
32 experts; we follow the primary spec (40).  40 % 16 != 0, so experts are
NOT sharded over the model axis — expert-internal TP shards d_ff_expert
(512/16 = 32) instead (see DESIGN.md §4).
"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49_155,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
)
