"""deepseek-v2-236b [arXiv:2405.04434; hf].

60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400;
MLA kv_lora=512 (+64 rope); MoE: 2 shared + 160 routed, top-6.
160 % 16 == 0 → expert parallelism over the model axis (10 experts/device).
MLA's latent KV cache (512+64 per token, head-count independent) is the
sub-linear serve-memory motif in the MURS classification.
"""

from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,          # routed expert intermediate size
    vocab=102_400,
    d_head=128,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_ff_expert=1536,
        num_shared_experts=2,
        d_ff_shared=1536,
    ),
)
