"""gemma3-1b [hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144; 5:1 local:global
sliding-window interleave (window 512), 128k-native context.  Block program:
(local ×5, global) ×4 + (local ×2) = 26 layers.  Runs long_500k: the locals
are O(window); the globals' 512k decode KV is linear-per-token (DESIGN.md §4).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262_144,
    d_head=256,
    block_pattern=("local_attn",) * 5 + ("attn",),
    pattern_repeats=4,
    suffix_blocks=("local_attn", "local_attn"),
    sliding_window=512,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    applicable_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
