"""AdamW from scratch (no optax): decoupled weight decay, global-norm clip,
linear-warmup + cosine-decay schedule.  Optimizer state is f32 regardless of
parameter dtype (mixed-precision training: bf16 params, f32 moments)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array  # i32 scalar
    m: PyTree  # f32, like params
    v: PyTree  # f32, like params


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def update(
    cfg: AdamWConfig, grads: PyTree, state: AdamWState, params: PyTree
) -> tuple[PyTree, AdamWState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) * scale, grads
        )
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree_util.tree_map(
        lambda m, g: cfg.b1 * m + (1.0 - cfg.b1) * g, state.m, grads
    )
    new_v = jax.tree_util.tree_map(
        lambda v, g: cfg.b2 * v + (1.0 - cfg.b2) * g * g, state.v, grads
    )

    def step_param(p, m, v):
        mh = m / b1c
        vh = v / b2c
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    new_params = jax.tree_util.tree_map(step_param, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
