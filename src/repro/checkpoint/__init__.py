"""Checkpointing for training state and serving KV (DESIGN.md §11).

:func:`save` / :func:`restore` move arbitrary pytrees through an atomic,
compressed, codec-portable on-disk format; :func:`restore_leaves` reads
self-describing flat checkpoints (the serving cluster's periodic KV
snapshots) without a target structure; :class:`AsyncCheckpointer`
backgrounds the serialize-and-write; :func:`latest_step_path` is the
resume discovery both :class:`repro.dist.fault.RestartManager` and
``ServingCluster.crash_replica`` use.
"""

from .checkpointing import (
    AsyncCheckpointer,
    latest_step_path,
    restore,
    restore_leaves,
    save,
)

__all__ = [
    "AsyncCheckpointer",
    "latest_step_path",
    "restore",
    "restore_leaves",
    "save",
]
