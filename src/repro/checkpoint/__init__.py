from .checkpointing import AsyncCheckpointer, latest_step_path, restore, save

__all__ = ["AsyncCheckpointer", "latest_step_path", "restore", "save"]
