"""Checkpointing: atomic, compressed, async-capable, elastically reshardable.

Format: one ``<name>.ckpt`` file containing a compressed msgpack map
  { "meta": {step, tree: <treedef repr>}, "leaves": [ {dtype, shape, data} ] }
compressed with zstd when the ``zstandard`` package is available, zlib
otherwise; the codec is detected from the frame magic on restore, so files
written with either codec restore everywhere.

Restore never requires the saving mesh: leaves are loaded host-side and
``jax.device_put`` with the *current* sharding rules — elastic rescale
(checkpoint written on 256 chips restores onto 512 or onto 1 CPU device).
Writes are atomic (tmp + rename) and optionally asynchronous (snapshot to
host first, background thread serializes), so the train loop never blocks
on disk.
"""

from __future__ import annotations

import os
import tempfile
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # hermetic containers: fall back to stdlib zlib
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but zstandard is not installed"
            )
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)

PyTree = Any

_DTYPE_FIX = {"bfloat16": jnp.bfloat16}


def _to_host(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: np.asarray(x), tree)


def _pack_leaf(x: np.ndarray) -> dict:
    if x.dtype == jnp.bfloat16:
        return {
            "dtype": "bfloat16",
            "shape": list(x.shape),
            "data": x.view(np.uint16).tobytes(),
        }
    return {"dtype": str(x.dtype), "shape": list(x.shape), "data": x.tobytes()}


def _unpack_leaf(d: dict) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        raw = np.frombuffer(d["data"], dtype=np.uint16).reshape(d["shape"])
        return raw.view(jnp.bfloat16)
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(
        d["shape"]
    )


def save(path: str, tree: PyTree, *, step: int = 0) -> None:
    """Atomic synchronous save."""
    host = _to_host(tree)
    leaves, treedef = jax.tree_util.tree_flatten(host)
    payload = {
        "meta": {"step": step, "n_leaves": len(leaves)},
        "leaves": [_pack_leaf(np.asarray(l)) for l in leaves],
    }
    blob = _compress(msgpack.packb(payload, use_bin_type=True))
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)  # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(
    path: str,
    like: PyTree,
    *,
    shardings: Optional[PyTree] = None,
) -> tuple[PyTree, int]:
    """Restore into the structure of ``like``; reshard onto ``shardings``.

    ``like`` may be a tree of arrays OR ShapeDtypeStructs (no allocation
    needed to describe the target).  Returns (tree, step).
    """
    with open(path, "rb") as f:
        blob = f.read()
    payload = msgpack.unpackb(_decompress(blob), raw=False)
    _, treedef = jax.tree_util.tree_flatten(like)
    leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    if len(leaves) != treedef.num_leaves:
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, target needs "
            f"{treedef.num_leaves} — structure mismatch"
        )
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings
        )
    else:
        tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return tree, payload["meta"]["step"]


def restore_leaves(path: str) -> tuple[list, int]:
    """Restore a checkpoint's raw leaf LIST without a ``like`` tree.

    For self-describing checkpoints — trees saved as a flat list whose
    first leaf is its own manifest (the serving cluster's KV snapshots:
    ``repro.serve.cluster.ServingCluster`` packs a msgpack manifest leaf
    followed by one array per checkpointed page) — no target structure
    exists before the file is read, so :func:`restore`'s treedef check
    is a chicken-and-egg.  Returns ``(leaves, step)`` host-side; the
    caller interprets the leaves.
    """
    with open(path, "rb") as f:
        blob = f.read()
    payload = msgpack.unpackb(_decompress(blob), raw=False)
    leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    return leaves, payload["meta"]["step"]


class AsyncCheckpointer:
    """Snapshot-then-serialize-in-background checkpointer.

    ``save`` snapshots device arrays to host (blocking only for the D2H
    copy), then a worker thread compresses and writes.  ``wait`` joins the
    in-flight write (call before exiting or before depending on the file).
    """

    def __init__(self) -> None:
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, path: str, tree: PyTree, *, step: int = 0) -> None:
        """Snapshot to host synchronously, then write on a background
        thread; a previous in-flight save is awaited first."""
        self.wait()
        host = _to_host(tree)  # synchronous D2H snapshot

        def work():
            try:
                save(path, host, step=step)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Block until the in-flight save (if any) finishes; re-raises
        any error the writer thread hit."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step_path(directory: str, prefix: str = "ckpt") -> Optional[str]:
    """Find the newest ``<prefix>_<step>.ckpt`` in ``directory``."""
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for name in os.listdir(directory):
        if name.startswith(prefix + "_") and name.endswith(".ckpt"):
            try:
                s = int(name[len(prefix) + 1 : -5])
            except ValueError:
                continue
            if s > best_step:
                best, best_step = os.path.join(directory, name), s
    return best
