"""Per-architecture sharding presets for the dry-run launcher.

``arch_overrides`` adapts the default logical-axis map to one
(architecture × mesh × shape) cell; ``batch_shardings`` resolves the input
pytree (tokens/labels, modality stubs, decode caches) to NamedShardings.
"""

from __future__ import annotations

from typing import Any, Dict

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import MeshAxes, Rules, path_str

PyTree = Any


def arch_overrides(
    cfg: ArchConfig, mesh, shape: ShapeConfig
) -> Dict[str, MeshAxes]:
    """Logical-axis overrides for one (arch × mesh × shape) cell.

    Defaults already handle the dense case (batch over data(+pod), TP dims
    over model, fsdp over data); this adds the per-family deviations.
    """
    o: Dict[str, MeshAxes] = {}
    if cfg.moe is not None:
        # experts across the model axis; the expert-internal dim stays
        # unsharded by default (serve presets may move it onto "data")
        o["expert"] = "model"
        o["expert_mlp"] = None
    if cfg.ssm is not None:
        # SSD head/state dims are small; keep the inner (expand) dim on the
        # model axis via the default "mlp" mapping — nothing extra needed.
        pass
    if shape.global_batch == 1:
        # long-context single-stream decode: nothing to shard over data via
        # the batch axis — pin the KV sequence axis there instead
        o["batch"] = None
        o["kv_seq"] = "data"
    if shape.kind == "decode" and cfg.n_kv_heads:
        # decode caches enter the step as pjit *arguments*, where shardings
        # must divide the dim exactly (unlike in-graph constraints, which
        # pad): GQA head counts smaller than the model axis fall back to
        # replicated heads + model-sharded KV sequence
        model_size = dict(
            zip(mesh.axis_names, mesh.devices.shape)
        ).get("model", 1)
        if cfg.n_kv_heads % model_size:
            o["kv_heads"] = None
            o.setdefault("kv_seq", "model")
    return o


def _cache_axes(cfg: ArchConfig, core_ndim: int):
    """Logical axes for one cache leaf, ignoring a leading scan dim.

    KV caches are [B, kv_heads, S, hd]; MLA latents [B, S, rank]; mamba
    conv tails [B, tail, d] and SSD states [B, heads, hd, d_state].
    """
    if core_ndim == 4:
        return ("batch", "kv_heads", "kv_seq", None)
    if core_ndim == 3:
        # the middle axis is the KV sequence only for MLA latent caches;
        # for mamba conv tails it is a (tiny) window — keep it replicated
        return ("batch", "kv_seq" if cfg.mla is not None else None, None)
    return ("batch",) + (None,) * (core_ndim - 1)


def batch_shardings(cfg: ArchConfig, rules: Rules, specs: PyTree) -> PyTree:
    """NamedShardings for an input-spec pytree (train, prefill or decode)."""

    def one(key_path, leaf):
        path = path_str(key_path)
        ndim = len(leaf.shape)
        if ndim == 0:
            return rules.sharding(())
        if path.startswith("caches/"):
            # unit caches carry a leading scan (pattern-repeats) dim
            lead = 1 if path.startswith("caches/unit/") else 0
            axes = (None,) * lead + _cache_axes(cfg, ndim - lead)
        elif path.endswith("embeds"):  # modality stubs [B, T, d]
            axes = ("batch",) + (None,) * (ndim - 2) + ("embed",)
        else:  # tokens / labels / anything batched-first
            axes = ("batch",) + (None,) * (ndim - 1)
        return rules.fitted_sharding(axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, specs)
