"""Int8 gradient compression with error feedback.

Symmetric per-tensor int8 quantization (scale = amax/127, round to
nearest) plus an error-feedback accumulator [arXiv:1901.09847-style]: the
residual of each compression step is added to the next gradient before
quantizing, so the *sum* of compressed gradients tracks the sum of true
gradients — the optimizer sees an unbiased-in-the-limit stream while every
cross-host gradient exchange moves 4× fewer bytes than f32.

All functions are pure jnp and jit-safe (the trainer runs
:func:`compress_grads` inside the donated train step).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

_QMAX = 127.0


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x → (int8 codes, f32 scale); |dequantize(q, s) − x| ≤ s/2."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, jnp.float32(1e-30)) / _QMAX
    q = jnp.clip(jnp.round(xf / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init(params: PyTree) -> PyTree:
    """Zero error-feedback residuals, one f32 buffer per parameter."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compress_grads(grads: PyTree, ef: PyTree) -> Tuple[PyTree, PyTree, jax.Array]:
    """(grads, residuals) → (dequantized grads, new residuals, max |error|).

    Per leaf: t = g + e;  q = Q(t);  ĝ = Q⁻¹(q);  e' = t − ĝ.  Telescoping
    over steps, Σ ĝ = Σ g − e_final, so the carried residual is the whole
    compression bias.
    """
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    e_leaves = treedef.flatten_up_to(ef)
    deq_leaves, new_e_leaves, errs = [], [], []
    for g, e in zip(g_leaves, e_leaves):
        t = g.astype(jnp.float32) + e
        q, scale = quantize(t)
        deq = dequantize(q, scale)
        deq_leaves.append(deq.astype(g.dtype))
        new_e_leaves.append(t - deq)
        errs.append(jnp.max(jnp.abs(t - deq)))
    return (
        jax.tree_util.tree_unflatten(treedef, deq_leaves),
        jax.tree_util.tree_unflatten(treedef, new_e_leaves),
        jnp.max(jnp.stack(errs)) if errs else jnp.float32(0.0),
    )


def compressed_bytes(params: PyTree) -> int:
    """Wire size of one compressed gradient exchange (int8 + f32 scale)."""
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(jnp.size(leaf)) + 4 for leaf in leaves)
