"""SPMD sharding rules: logical axes, activation constraints, param specs.

The model code names *logical* axes ("batch", "heads", "mlp", …); a
:class:`Rules` object maps them onto the *mesh* axes of the current
topology ("data", "model", optionally "pod").  Three consumers:

* activations — ``shard(x, ("batch", "seq", "embed"))`` inside the model
  is a no-op until a :func:`use_rules` context is active, at which point it
  lowers to ``jax.lax.with_sharding_constraint`` (the GSPMD hint that pins
  layer boundaries).  Tests and single-host smoke runs never enter the
  context, so the same model code runs unsharded.
* parameters — regex rules over the param *path* ("layers/b0/attn/wq")
  resolve each weight to a PartitionSpec; leading scan/stack dims that the
  rule does not mention are padded with ``None`` (replicated), so the same
  rule covers a single block and its scan-stacked unit.
* mesh hygiene — each mesh axis is used at most once per spec (GSPMD
  rejects duplicates): when two logical axes resolve to the same mesh axis
  the *first* one wins and the second gets ``None``.
"""

from __future__ import annotations

import contextlib
import re
from contextvars import ContextVar
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

PyTree = Any
#: a logical axis resolves to one mesh axis, several (e.g. batch over
#: ("pod", "data")), or None (replicated)
MeshAxes = Union[None, str, Tuple[str, ...]]

#: default logical-axis → mesh-axis map.  Axes absent from the active mesh
#: are dropped at resolve time, so ("pod", "data") degrades to "data" on a
#: single-pod mesh.
DEFAULT_AXIS_MAP: Dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",  # dedup nulls this whenever batch already took "data"
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "expert_mlp": "model",
    "kv_seq": None,  # serve presets may map the KV seq axis onto "model"
    # parameters
    "fsdp": "data",  # the d_model axis of every weight (ZeRO-3 style)
}

#: ordered (path-regex, logical axes) param rules — first match wins.
#: Paths are "/"-joined pytree key paths, e.g. "layers/b0/attn/wq".
DEFAULT_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/tokens$", ("vocab", "fsdp")),
    (r"lm_head$", ("fsdp", "vocab")),
    # GQA attention
    (r"w[qkv]$", ("fsdp", "heads")),
    (r"b[qkv]$", ("heads",)),
    (r"wo$", ("heads", "fsdp")),
    # MLA: latent ranks replicated, head-expanded dims model-parallel
    (r"wq_a$", ("fsdp", None)),
    (r"wq_b$", (None, "heads")),
    (r"wkv_a$", ("fsdp", None)),
    (r"wkv_b$", (None, "heads")),
    # MoE (before the dense-MLP rules: "moe/gate" must not match "gate$")
    (r"router$", ("fsdp", "expert")),
    (r"moe/(gate|up)$", ("expert", "fsdp", "expert_mlp")),
    (r"moe/down$", ("expert", "expert_mlp", "fsdp")),
    # dense / shared-expert MLP
    (r"(gate|up)$", ("fsdp", "mlp")),
    (r"down$", ("mlp", "fsdp")),
    # Mamba-2
    (r"w_[zx]$", ("fsdp", "mlp")),
    (r"w_(B|C|dt)$", ("fsdp", None)),
    (r"out_proj$", ("mlp", "fsdp")),
    # adapters / modality projections
    (r"(in_adapter|out_adapter|vision_proj|audio_proj)$", ("fsdp", None)),
    # norms, biases, conv tails, A_log/D … fall through to replicated
)


@dataclass(frozen=True)
class Rules:
    """Resolved sharding rules for one mesh: axis map + param-path rules."""

    mesh: Mesh
    axis_map: Dict[str, MeshAxes]
    param_rules: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...]

    # ------------------------------------------------------------ resolve
    def resolve(self, logical: Optional[str]) -> MeshAxes:
        """Logical axis → mesh axes (unknown names are an error: a typo in
        a shard() call should fail loudly, not silently replicate)."""
        if logical is None:
            return None
        if logical not in self.axis_map:
            raise KeyError(
                f"unknown logical axis {logical!r}; known: "
                f"{sorted(self.axis_map)}"
            )
        return self.axis_map[logical]

    def entries(
        self, axes: Sequence[Optional[str]]
    ) -> Tuple[Union[None, str, Tuple[str, ...]], ...]:
        """Per-dimension PartitionSpec entries with mesh-axis dedup."""
        present = set(self.mesh.axis_names)
        used: set = set()
        out = []
        for ax in axes:
            r = self.resolve(ax)
            parts = (r,) if isinstance(r, str) else (r or ())
            parts = tuple(p for p in parts if p in present and p not in used)
            used.update(parts)
            if not parts:
                out.append(None)
            elif len(parts) == 1:
                out.append(parts[0])
            else:
                out.append(parts)
        return tuple(out)

    def spec(self, axes: Sequence[Optional[str]]) -> PartitionSpec:
        return PartitionSpec(*self.entries(axes))

    def sharding(self, axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))

    def fit(
        self,
        entries: Sequence[Union[None, str, Tuple[str, ...]]],
        shape: Sequence[int],
    ) -> PartitionSpec:
        """Drop mesh axes that do not divide the dim they would shard.

        pjit *argument* shardings must divide dims exactly (in-graph
        constraints pad, arguments don't) — e.g. a 49155-row vocab or an
        8-head KV cache cannot split 16 ways; those dims degrade to
        replicated instead of failing the lower.
        """
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        out = []
        for dim, e in zip(shape, tuple(entries)):
            parts = (e,) if isinstance(e, str) else tuple(e or ())
            keep, total = [], 1
            for p in parts:
                if dim % (total * sizes[p]) == 0:
                    keep.append(p)
                    total *= sizes[p]
            if not keep:
                out.append(None)
            elif len(keep) == 1:
                out.append(keep[0])
            else:
                out.append(tuple(keep))
        return PartitionSpec(*out)

    def fitted_sharding(
        self, axes: Sequence[Optional[str]], shape: Sequence[int]
    ) -> NamedSharding:
        return NamedSharding(self.mesh, self.fit(self.entries(axes), shape))

    # ------------------------------------------------------------- params
    def spec_for_path(self, path: str, ndim: int) -> PartitionSpec:
        for pattern, axes in self.param_rules:
            if re.search(pattern, path):
                entries = self.entries(axes)
                if len(entries) < ndim:  # leading scan/stack dims
                    entries = (None,) * (ndim - len(entries)) + entries
                elif len(entries) > ndim:
                    entries = entries[-ndim:] if ndim else ()
                return PartitionSpec(*entries)
        return PartitionSpec()  # unknown → replicated


def make_rules(
    mesh: Mesh,
    *,
    overrides: Optional[Dict[str, MeshAxes]] = None,
    param_rules: Optional[Sequence[Tuple[str, Tuple[Optional[str], ...]]]] = None,
) -> Rules:
    """Build :class:`Rules` for ``mesh``; ``overrides`` remap logical axes
    (e.g. ``{"fsdp": None}`` for ZeRO-1, ``{"kv_seq": "model"}`` for
    sequence-sharded serving caches)."""
    axis_map = dict(DEFAULT_AXIS_MAP)
    if overrides:
        axis_map.update(overrides)
    return Rules(
        mesh=mesh,
        axis_map=axis_map,
        param_rules=tuple(param_rules or DEFAULT_PARAM_RULES),
    )


# ------------------------------------------------------------- path helpers
def path_str(key_path: Sequence[Any]) -> str:
    """Pytree key path → "layers/b0/attn/wq"-style string."""
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:  # pragma: no cover - future key types
            parts.append(str(k))
    return "/".join(parts)


def param_spec_for_path(path: str, rules: Rules, ndim: int) -> PartitionSpec:
    """PartitionSpec for one parameter identified by its tree path."""
    return rules.spec_for_path(path, ndim)


def param_shardings(params: PyTree, rules: Rules) -> PyTree:
    """NamedSharding tree matching ``params`` (arrays or ShapeDtypeStructs).

    Shapes are known here, so non-divisible dims degrade to replicated
    (see :meth:`Rules.fit`)."""

    def one(kp, leaf):
        spec = rules.spec_for_path(path_str(kp), len(leaf.shape))
        return NamedSharding(rules.mesh, rules.fit(spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params)


# -------------------------------------------------------- activation hook
_ACTIVE_RULES: ContextVar[Optional[Rules]] = ContextVar(
    "repro_dist_active_rules", default=None
)


def current_rules() -> Optional[Rules]:
    return _ACTIVE_RULES.get()


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Activate ``rules`` for :func:`shard` calls traced in this context."""
    token = _ACTIVE_RULES.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE_RULES.reset(token)


def shard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Activation sharding constraint; identity when no rules are active.

    ``axes`` names one logical axis (or None) per array dimension.
    """
    rules = _ACTIVE_RULES.get()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(axes))
