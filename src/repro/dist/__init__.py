"""Distribution substrate: SPMD sharding rules, per-arch presets, gradient
compression, and fault tolerance.

The four modules cover the scale-out concerns the rest of the repo programs
against:

* :mod:`repro.dist.sharding` — logical-axis → mesh-axis rules, the
  :func:`shard` activation-constraint hook, and regex param-path rules.
* :mod:`repro.dist.presets` — per-architecture overrides and input/batch
  shardings for the dry-run launcher.
* :mod:`repro.dist.compression` — int8 quantization with error-feedback
  gradient compression.
* :mod:`repro.dist.fault` — straggler detection, checkpoint-restoring
  restart policy, and elastic resharding across mesh layouts.
"""

from repro.dist import compression, fault, presets, sharding
from repro.dist.sharding import (
    Rules,
    current_rules,
    make_rules,
    param_shardings,
    param_spec_for_path,
    shard,
    use_rules,
)

__all__ = [
    "Rules",
    "compression",
    "current_rules",
    "fault",
    "make_rules",
    "param_shardings",
    "param_spec_for_path",
    "presets",
    "shard",
    "sharding",
    "use_rules",
]
