"""Fault tolerance: stragglers, restart-from-checkpoint, elastic reshard.

Three pieces the trainer composes:

* :class:`StragglerDetector` — per-host step-time statistics; a host whose
  mean exceeds ``ratio ×`` the across-host median is flagged, and
  :meth:`rebalance_weights` yields inverse-speed work weights.
* :class:`RestartManager` — resume from the newest checkpoint in a
  directory, with a bounded-retry exponential-backoff policy for
  crash/preemption loops.
* :func:`elastic_reshard` — place a host-side checkpoint tree onto the
  *current* mesh under the current rules; because restore is host-side
  bytes + ``device_put``, a checkpoint written on one topology restores
  onto any other (grow/shrink/CPU).

The serving cluster composes the same pieces (DESIGN.md §8, §11):
:class:`StragglerDetector` runs over per-replica tick service times to
trigger live KV migration, and ``ServingCluster`` applies the
:class:`RestartManager` retry/backoff policy per crashed *request* —
with KV checkpoints standing in for parameter checkpoints, so a restore
replays only the checkpoint-uncovered suffix.
"""

from __future__ import annotations

import collections
import logging
import statistics
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.checkpoint.checkpointing import latest_step_path, restore
from repro.dist.sharding import Rules, path_str

PyTree = Any
log = logging.getLogger(__name__)


class StragglerDetector:
    """Flag hosts whose mean step time exceeds ``ratio``× the median."""

    def __init__(
        self,
        min_samples: int = 5,
        ratio: float = 1.5,
        window: int = 64,
    ) -> None:
        self.min_samples = min_samples
        self.ratio = ratio
        self._times: Dict[str, Deque[float]] = collections.defaultdict(
            lambda: collections.deque(maxlen=window)
        )

    def observe(self, host: str, step_time_s: float) -> None:
        self._times[host].append(step_time_s)

    def forget(self, host: str) -> None:
        """Drop a host's samples — it restarted or was replaced, so its
        history describes a process that no longer exists."""
        self._times.pop(host, None)

    def _means(self) -> Dict[str, float]:
        return {
            h: sum(ts) / len(ts)
            for h, ts in self._times.items()
            if len(ts) >= self.min_samples
        }

    def stragglers(self) -> List[str]:
        means = self._means()
        if len(means) < 2:
            return []
        median = statistics.median(means.values())
        return sorted(h for h, m in means.items() if m > self.ratio * median)

    def rebalance_weights(self) -> Dict[str, float]:
        """Work weights ∝ host speed (1/mean step time), summing to 1.

        Means come from :meth:`_means` — the same ``min_samples``-gated
        statistics :meth:`stragglers` consults — so one noisy first sample
        from a fresh host cannot skew the whole weight vector.  Hosts
        still below ``min_samples`` keep their current share: they are
        excluded from the inverse-speed ranking and assigned the uniform
        weight (no evidence = no penalty, no bonus).  When NO host has
        enough samples yet the fallback is explicit: every observed host
        weighs equally.
        """
        means = self._means()
        observed = [h for h, ts in self._times.items() if ts]
        if not observed:
            return {}
        if not means:
            # explicit all-hosts fallback: nobody has min_samples yet, so
            # there is no trustworthy speed signal — split work evenly
            return {h: 1.0 / len(observed) for h in observed}
        inv = {h: 1.0 / max(m, 1e-9) for h, m in means.items()}
        unranked = [h for h in observed if h not in means]
        if unranked:
            # under-sampled hosts take the mean ranked weight
            uniform = sum(inv.values()) / len(inv)
            for h in unranked:
                inv[h] = uniform
        total = sum(inv.values())
        return {h: v / total for h, v in inv.items()}


class RestartManager:
    """Resume-from-latest + bounded retries with exponential backoff."""

    def __init__(
        self,
        ckpt_dir: str,
        *,
        max_retries: int = 3,
        backoff_s: float = 1.0,
        max_backoff_s: float = 60.0,
    ) -> None:
        if max_backoff_s < backoff_s:
            raise ValueError(
                f"max_backoff_s ({max_backoff_s}) must be >= backoff_s "
                f"({backoff_s})"
            )
        self.ckpt_dir = ckpt_dir
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.max_backoff_s = max_backoff_s
        self.failures = 0
        self.last_heartbeat: Optional[Tuple[int, float]] = None

    # ------------------------------------------------------------- resume
    def resume(self, like: PyTree) -> Tuple[Optional[PyTree], int]:
        """(restored tree, step) from the newest checkpoint, or (None, 0)."""
        path = latest_step_path(self.ckpt_dir)
        if path is None:
            return None, 0
        tree, step = restore(path, like)
        log.info("resumed from %s at step %d", path, step)
        return tree, step

    # ------------------------------------------------------ retry policy
    def should_retry(self) -> bool:
        return self.failures < self.max_retries

    def on_failure(self, exc: BaseException) -> float:
        """Record a failure; returns the backoff delay in seconds.

        Exponential growth is CAPPED at ``max_backoff_s``: a long
        preemption loop (every retry failing for hours) must produce a
        bounded sleep, not an uncapped ``2**n`` that quietly reaches
        hour-scale delays before the retry budget runs out.
        """
        self.failures += 1
        delay = min(
            self.backoff_s * (2.0 ** (self.failures - 1)), self.max_backoff_s
        )
        log.warning(
            "step failed (%s: %s) — retry %d/%d after %.1fs",
            type(exc).__name__, exc, self.failures, self.max_retries, delay,
        )
        return delay

    def on_success(self) -> None:
        self.failures = 0

    def record_heartbeat(self, step: int) -> None:
        self.last_heartbeat = (step, time.monotonic())


def elastic_reshard(tree: PyTree, rules: Rules) -> PyTree:
    """Place a (host-side) tree onto ``rules.mesh`` under the param rules.

    The checkpoint format stores plain host arrays, so restoring onto a
    different mesh shape is just a fresh placement decision: every leaf is
    ``device_put`` with the spec its path resolves to under the *current*
    rules (unknown paths → replicated).
    """

    def place(key_path, leaf):
        arr = jnp.asarray(leaf)
        spec = rules.spec_for_path(path_str(key_path), arr.ndim)
        spec = rules.fit(spec, arr.shape)  # the new mesh may not divide
        return jax.device_put(arr, NamedSharding(rules.mesh, spec))

    return jax.tree_util.tree_map_with_path(place, tree)
