from .train_step import lm_loss, make_train_step
from .trainer import Trainer, TrainerConfig

__all__ = ["lm_loss", "make_train_step", "Trainer", "TrainerConfig"]
