"""Trainer: the fault-tolerant training loop.

Composes: data pipeline → jitted train step (remat + microbatching +
optional int8-EF gradient compression) → async checkpointing → straggler
detection → restart-from-latest.  The loop is crash-safe: any exception
inside a step falls back to the RestartManager policy (restore latest
checkpoint, bounded retries with backoff).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax

from repro.checkpoint.checkpointing import AsyncCheckpointer
from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import DataConfig, DataPipeline
from repro.dist import compression
from repro.dist.fault import RestartManager, StragglerDetector
from repro.models import init_model
from repro.optim import adamw
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 10
    microbatches: int = 1
    remat: bool = True
    grad_compression: bool = False
    seed: int = 0
    log_every: int = 5
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    #: MURS-adaptive accumulation: a probe returning HBM pool used-fraction
    #: drives the microbatch factor through the yellow/red thresholds
    #: (repro.train.pressure).  None disables.
    hbm_probe: Optional[Callable[[], float]] = None


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        tcfg: Optional[TrainerConfig] = None,
        *,
        batch: Optional[int] = None,
        seq: Optional[int] = None,
    ) -> None:
        self.cfg = cfg
        self.shape = shape
        self.tcfg = tcfg or TrainerConfig()
        self.batch = batch
        self.seq = seq
        self.ckpt = AsyncCheckpointer()
        self.restart = RestartManager(self.tcfg.ckpt_dir)
        self.straggler = StragglerDetector()
        self.metrics_log: list = []
        #: wire size of one compressed gradient exchange (grad_compression)
        self.compressed_wire_bytes: Optional[int] = None
        self._adaptive = None
        self._step_cache: Dict[int, Any] = {}
        if self.tcfg.hbm_probe is not None:
            from repro.train.pressure import PressureAdaptiveAccumulator

            global_batch = batch if batch is not None else shape.global_batch
            self._adaptive = PressureAdaptiveAccumulator(
                probe=self.tcfg.hbm_probe,
                # the factor slices the batch: never exceed it (keep it a
                # power of two ≤ batch so slices stay equal-sized)
                max_factor=1 << (max(global_batch, 1).bit_length() - 1),
            )
            self._adaptive.factor = max(self.tcfg.microbatches, 1)

    # ----------------------------------------------------------- build/run
    def build(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = init_model(self.cfg, key)
        opt_state = adamw.init(params)
        step_fn = make_train_step(
            self.cfg,
            self.tcfg.opt,
            microbatches=self.tcfg.microbatches,
            remat=self.tcfg.remat,
        )
        if self.tcfg.grad_compression:
            # wrap: grads→EF-int8→optimizer (compression inside the jit)
            from repro.dist.sharding import shard
            from repro.train.train_step import lm_loss

            def step_with_compression(params, opt_state, ef, batch):
                # same batch pin as make_train_step: no-op without rules
                batch = {
                    k: shard(v, ("batch",) + (None,) * (v.ndim - 1))
                    for k, v in batch.items()
                }
                loss, grads = jax.value_and_grad(
                    lambda p, b: lm_loss(self.cfg, p, b, remat=self.tcfg.remat)
                )(params, batch)
                grads, ef, cerr = compression.compress_grads(grads, ef)
                new_p, new_o, gnorm = adamw.update(
                    self.tcfg.opt, grads, opt_state, params
                )
                return new_p, new_o, ef, {
                    "loss": loss,
                    "grad_norm": gnorm,
                    "compression_err": cerr,
                    "step": new_o.step,
                }

            self._jit_step = jax.jit(step_with_compression, donate_argnums=(0, 1, 2))
            self._ef = compression.init(params)
            self.compressed_wire_bytes = compression.compressed_bytes(params)
        else:
            self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
            self._ef = None
        return params, opt_state

    def run(self) -> Dict[str, Any]:
        params, opt_state = self.build()
        # resume-from-latest (fault tolerance)
        restored, start_step = self.restart.resume((params, opt_state))
        if restored is not None:
            params, opt_state = restored
        pipeline = DataPipeline(
            self.cfg, self.shape, DataConfig(seed=self.tcfg.seed),
            batch=self.batch, seq=self.seq,
        )
        host = f"host{jax.process_index()}"
        step = start_step
        try:
            while step < self.tcfg.steps:
                batch = next(pipeline)
                t0 = time.monotonic()
                # MURS-adaptive accumulation: re-jit only on factor change
                if self._adaptive is not None and self._ef is None:
                    factor = self._adaptive.step()
                    if factor not in self._step_cache:
                        self._step_cache[factor] = jax.jit(
                            make_train_step(
                                self.cfg, self.tcfg.opt,
                                microbatches=factor, remat=self.tcfg.remat,
                            ),
                            donate_argnums=(0, 1),
                        )
                    self._jit_step = self._step_cache[factor]
                try:
                    if self._ef is not None:
                        params, opt_state, self._ef, metrics = self._jit_step(
                            params, opt_state, self._ef, batch
                        )
                    else:
                        params, opt_state, metrics = self._jit_step(
                            params, opt_state, batch
                        )
                    jax.block_until_ready(metrics["loss"])
                    self.restart.on_success()
                except Exception as exc:  # crash/preempt → restore + retry
                    if not self.restart.should_retry():
                        raise
                    time.sleep(min(self.restart.on_failure(exc), 0.1))
                    restored, step = self.restart.resume((params, opt_state))
                    if restored is not None:
                        params, opt_state = restored
                    continue
                dt = time.monotonic() - t0
                self.straggler.observe(host, dt)
                step += 1
                if step % self.tcfg.log_every == 0 or step == self.tcfg.steps:
                    self.metrics_log.append(
                        {
                            "step": step,
                            "loss": float(metrics["loss"]),
                            "grad_norm": float(metrics["grad_norm"]),
                            "step_time_s": dt,
                            "stragglers": self.straggler.stragglers(),
                        }
                    )
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(
                        f"{self.tcfg.ckpt_dir}/ckpt_{step}.ckpt",
                        (params, opt_state),
                        step=step,
                    )
                    self.restart.record_heartbeat(step)
        finally:
            pipeline.close()
            self.ckpt.wait()
        return {
            "final_step": step,
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "log": self.metrics_log,
        }
