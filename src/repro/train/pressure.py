"""Pressure-adaptive gradient accumulation — MURS applied to training.

The paper's scheduler manages a shared pool by reducing the parallelism of
memory-heavy work when usage crosses the yellow threshold.  The training
analogue of "number of running tasks" is the **microbatch width**: fewer
tokens in flight per backward = smaller live-activation set, at the cost of
more accumulation steps.  This controller drives that trade-off with the
MURS thresholds and hysteresis:

    usage ≥ red     → double the accumulation factor immediately (halve the
                      in-flight activations) — the ComputeSpill analogue
    usage ≥ yellow  → double after ``patience`` consecutive hot steps
    usage < relax·yellow for ``patience`` steps → halve (recover throughput)

``probe`` abstracts the pool reading: on TPU it is
``device.memory_stats()['bytes_in_use'] / bytes_limit``; tests and the CPU
container inject synthetic probes.  The Trainer re-jits the step only when
the factor changes (cached per factor).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List

from repro.sched import MursConfig


@dataclass
class PressureAdaptiveAccumulator:
    probe: Callable[[], float]  # → pool used fraction in [0, 1]
    config: MursConfig = field(default_factory=MursConfig)
    min_factor: int = 1
    max_factor: int = 64
    patience: int = 3
    relax: float = 0.5  # shrink when usage < relax × yellow
    factor: int = 1
    _hot: int = 0
    _cool: int = 0
    history: List[dict] = field(default_factory=list)

    def step(self) -> int:
        """Observe pressure, maybe adapt; returns the factor to use next."""
        usage = float(self.probe())
        cfg = self.config
        changed = None
        if usage >= cfg.red and self.factor < self.max_factor:
            self.factor = min(self.factor * 2, self.max_factor)
            changed = "red-double"
            self._hot = self._cool = 0
        elif usage >= cfg.yellow:
            self._hot += 1
            self._cool = 0
            if self._hot >= self.patience and self.factor < self.max_factor:
                self.factor = min(self.factor * 2, self.max_factor)
                changed = "yellow-double"
                self._hot = 0
        elif usage < self.relax * cfg.yellow:
            self._cool += 1
            self._hot = 0
            if self._cool >= self.patience and self.factor > self.min_factor:
                self.factor = max(self.factor // 2, self.min_factor)
                changed = "cool-halve"
                self._cool = 0
        else:
            self._hot = self._cool = 0
        self.history.append(
            {"usage": usage, "factor": self.factor, "event": changed}
        )
        return self.factor
