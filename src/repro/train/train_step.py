"""Train step: causal-LM loss, grad accumulation (microbatching), AdamW.

The step is a pure function of (params, opt_state, batch) — jit/pjit-able.
Microbatch accumulation runs as a lax.scan over microbatch slices so HLO
size is O(1) in the accumulation factor (and remat applies per layer-unit
inside the model).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import shard
from repro.models import forward
from repro.models.transformer import forward_hidden
from repro.optim import adamw

PyTree = Any


def lm_loss(
    cfg: ArchConfig,
    params,
    batch: Dict[str, jax.Array],
    *,
    remat: bool = True,
    loss_chunk: Optional[int] = None,
) -> jax.Array:
    """Next-token cross-entropy, mean over non-padding positions.

    ``loss_chunk`` enables the chunked-vocab loss: the [B, S, vocab] f32
    logits tensor (38 GiB for qwen at 4k×16/device!) is never materialized —
    a lax.scan over sequence chunks computes per-chunk NLL against the
    unembedding, cutting peak temp memory by O(S/chunk)× on the logits term.
    """
    tokens = batch["tokens"]
    labels = batch["labels"]
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    if loss_chunk is None:
        logits = forward(cfg, params, tokens, extra=extra or None, remat=remat)
        # modality frontends prepend positions (vision tokens) — loss runs
        # on the trailing text positions only
        logits = logits[:, -labels.shape[1] :]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)  # label −1 = padding
        labels_safe = jnp.maximum(labels, 0)
        nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    hidden = forward_hidden(cfg, params, tokens, extra=extra or None, remat=remat)
    hidden = hidden[:, -labels.shape[1] :]
    w = params["embed"]["tokens"].T if cfg.tie_embeddings else params["lm_head"]
    b, s, d = hidden.shape
    chunk = min(loss_chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    pad = n_chunks * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    h_c = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    l_c = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # recompute chunk logits in backward — never more than
    # ONE [B, chunk, V] f32 tensor lives at a time in either pass
    def body(carry, inputs):
        nll_sum, n_tok = carry
        h, lab = inputs
        logits = jnp.einsum(
            "bsd,dv->bsv", h.astype(jnp.float32), w.astype(jnp.float32)
        )
        logp = jax.nn.log_softmax(logits, axis=-1)
        mask = (lab >= 0).astype(jnp.float32)
        safe = jnp.maximum(lab, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return (nll_sum + jnp.sum(nll * mask), n_tok + jnp.sum(mask)), None

    (nll_sum, n_tok), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (h_c, l_c)
    )
    return nll_sum / jnp.maximum(n_tok, 1.0)


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    microbatches: int = 1,
    remat: bool = True,
    loss_chunk: Optional[int] = None,
):
    """Build a (params, opt_state, batch) → (params, opt_state, metrics)
    step with ``microbatches``-way gradient accumulation."""

    def loss_fn(params, micro_batch):
        return lm_loss(
            cfg, params, micro_batch, remat=remat, loss_chunk=loss_chunk
        )

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state: adamw.AdamWState, batch):
        # pin the host batch to the data axis before any compute (no-op
        # unless a repro.dist.sharding rules context is active)
        batch = {
            k: shard(v, ("batch",) + (None,) * (v.ndim - 1))
            for k, v in batch.items()
        }
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:

            def micro(i, carry_batch):
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatches),
                        x.shape[0] // microbatches, axis=0,
                    ),
                    carry_batch,
                )

            def body(carry, i):
                loss_acc, grads_acc = carry
                l, g = grad_fn(params, micro(i, batch))
                grads_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), grads_acc, g
                )
                return (loss_acc + l, grads_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zeros), jnp.arange(microbatches)
            )
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)

        new_params, new_opt, gnorm = adamw.update(
            opt_cfg, grads, opt_state, params
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "step": new_opt.step}
        return new_params, new_opt, metrics

    return train_step
