"""MURS core: memory-usage models, sampler, Algorithm-1 scheduler, pool.

The paper's contribution (a memory-usage-rate based scheduler for
service-mode data processing systems) as a composable library:

  * :mod:`usage_models` — the four growth models + online rate estimation
  * :mod:`sampler` — the seasonal per-task metric sampler
  * :mod:`memory_manager` — shared pool (JVM-heap / HBM) accounting
  * :mod:`repro.sched` — Algorithm 1 (yellow/red, suspend/resume, spill
    guard); the old ``repro.core.scheduler`` shim has been removed —
    import from :mod:`repro.sched` (aliases below stay for core's API)
  * :mod:`tasks`, :mod:`service`, :mod:`spark_sim` — the faithful
    reproduction environment for the paper's own evaluation
"""

from repro.sched.murs import MursConfig
from repro.sched.murs import MursPolicy as MursScheduler
from repro.sched.protocol import SchedulingDecision

from .memory_manager import MemoryPool, OutOfMemoryError
from .sampler import Sampler, TaskStats
from .usage_models import (
    RateEstimator,
    UsageModel,
    classify_trace,
    fit_power_law,
    live_bytes_at,
)

__all__ = [
    "MemoryPool",
    "OutOfMemoryError",
    "Sampler",
    "TaskStats",
    "MursConfig",
    "MursScheduler",
    "SchedulingDecision",
    "RateEstimator",
    "UsageModel",
    "classify_trace",
    "fit_power_law",
    "live_bytes_at",
]
