"""Memory-usage models of function APIs / workloads (MURS §III).

A task's *live* (long-lifetime) memory grows with the amount of input it has
processed according to one of four coarse models:

    constant     — no K distinction, results streamed out (``map``, ``filter``)
    sub-linear   — distinguishes K, aggregates V, K appears randomly
                   (``reduceByKey``); TPU analogue: prefix-shared / MLA-latent KV
    linear       — distinguishes K, no aggregation (``groupByKey``, ``sortByKey``
                   shuffle buffers); TPU analogue: per-token KV-cache append
    super-linear — caches results that grow faster than input (histogram of all
                   divisors); TPU analogue: beam / tree speculative decode

The *memory usage rate* is the local slope Δlive/Δprocessed — the uniform,
online-measurable criterion MURS schedules on (paper §III-B).
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Sequence

__all__ = [
    "UsageModel",
    "live_bytes_at",
    "fit_power_law",
    "classify_exponent",
    "classify_trace",
    "RateEstimator",
]


class UsageModel(enum.Enum):
    """The four coarse-grained models of Fig. 2 in the paper."""

    CONSTANT = "constant"
    SUB_LINEAR = "sub_linear"
    LINEAR = "linear"
    SUPER_LINEAR = "super_linear"

    @property
    def order(self) -> int:
        """Scheduling preference order (paper: constant→sub→linear→super)."""
        return _MODEL_ORDER[self]


_MODEL_ORDER = {
    UsageModel.CONSTANT: 0,
    UsageModel.SUB_LINEAR: 1,
    UsageModel.LINEAR: 2,
    UsageModel.SUPER_LINEAR: 3,
}

#: Exponent of ``live = a * processed**b`` used when *generating* traces.
MODEL_EXPONENT = {
    UsageModel.CONSTANT: 0.0,
    UsageModel.SUB_LINEAR: 0.5,
    UsageModel.LINEAR: 1.0,
    UsageModel.SUPER_LINEAR: 1.5,
}


def live_bytes_at(model: UsageModel, processed: float, rate: float) -> float:
    """Live bytes after ``processed`` input bytes for a generating ``model``.

    ``rate`` is the nominal slope at full input for the linear model; for the
    other models it scales the curve so that all models are comparable at the
    same nominal rate (slope-matched at processed == 1.0 unit for linear).
    """
    if processed <= 0.0:
        return 0.0
    b = MODEL_EXPONENT[model]
    if b == 0.0:
        return rate  # a fixed working set, independent of input volume
    return rate * processed**b


def fit_power_law(
    processed: Sequence[float], live: Sequence[float]
) -> tuple[float, float]:
    """Least-squares fit of ``live ≈ a * processed**b`` in log-log space.

    Returns ``(a, b)``.  Points with non-positive coordinates are dropped;
    with fewer than two usable points the fit degenerates to ``(last, 0)``.
    """
    xs, ys = [], []
    for p, l in zip(processed, live):
        if p > 0.0 and l > 0.0:
            xs.append(math.log(p))
            ys.append(math.log(l))
    n = len(xs)
    if n < 2:
        return (live[-1] if live else 0.0, 0.0)
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx <= 1e-12:
        return (math.exp(my), 0.0)
    b = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / sxx
    a = math.exp(my - b * mx)
    return (a, b)


def classify_exponent(b: float) -> UsageModel:
    """Map a fitted growth exponent to one of the four models."""
    if b < 0.2:
        return UsageModel.CONSTANT
    if b < 0.8:
        return UsageModel.SUB_LINEAR
    if b <= 1.2:
        return UsageModel.LINEAR
    return UsageModel.SUPER_LINEAR


def classify_trace(
    processed: Sequence[float], live: Sequence[float]
) -> UsageModel:
    """Classify a sampled (processed, live) trace into a usage model.

    Constant traces are detected directly (near-zero relative growth) because
    a power-law fit is ill-conditioned when live barely moves.
    """
    if len(live) >= 2:
        lo, hi = min(live), max(live)
        if hi <= 0.0 or (hi - lo) <= 0.05 * max(hi, 1e-9):
            return UsageModel.CONSTANT
    _, b = fit_power_law(processed, live)
    return classify_exponent(b)


@dataclass
class RateEstimator:
    """Online memory-usage-rate estimator over a sliding sample window.

    The paper computes the rate as the quotient of two increments,
    ``Δsize_used_memory / Δsize_processed_records`` (§V), and keeps a buffer
    of computed values whose *trend* determines the model.
    """

    window: int = 32
    _processed: list[float] = field(default_factory=list)
    _live: list[float] = field(default_factory=list)

    def update(self, processed_bytes: float, live_bytes: float) -> None:
        self._processed.append(float(processed_bytes))
        self._live.append(float(live_bytes))
        if len(self._processed) > self.window:
            del self._processed[0]
            del self._live[0]

    @property
    def samples(self) -> int:
        return len(self._processed)

    @property
    def rate(self) -> float:
        """Current Δlive/Δprocessed slope (most recent increment pair)."""
        if len(self._processed) < 2:
            return 0.0
        dp = self._processed[-1] - self._processed[0]
        dl = self._live[-1] - self._live[0]
        if dp <= 0.0:
            return 0.0
        return max(dl / dp, 0.0)

    @property
    def model(self) -> UsageModel:
        if len(self._processed) < 3:
            return UsageModel.CONSTANT
        return classify_trace(self._processed, self._live)
