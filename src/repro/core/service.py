"""Multi-tenant service executor scheduling through the policy layer.

Discrete-time executor model of one Spark executor JVM (the paper runs four
identical workers; we simulate one executor on its 1/4 data share — jobs are
embarrassingly parallel across executors so aggregate ratios are preserved).

The executor owns:
  * ``cores`` hardware threads running tasks,
  * a :class:`MemoryPool` (the JVM heap) with young/old accounting,
  * a GC cost model (minor + full, stop-the-world),
  * a spill model (fair-share violation under a nearly-full heap),
  * a :class:`repro.sched.SchedulingPolicy` — :class:`FairPolicy` (the
    Spark baseline), :class:`MursPolicy` (Algorithm 1), or any other
    implementation of the protocol.

Jobs are DAGs of stages; a stage's tasks become runnable when the previous
stage of that job completes.  Core handout each tick is the policy's
``assign`` hook (FAIR/MURS: round-robin across jobs, as Spark's fair
scheduler pool does across tenants; PriorityPolicy: weighted stride).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.sched import FairPolicy, MursConfig, MursPolicy, SchedulingPolicy
from repro.sched.protocol import SchedulingDecision

from .memory_manager import MemoryPool
from .sampler import Sampler
from .tasks import TaskSpec, TaskState

__all__ = ["GcModel", "JobSpec", "JobMetrics", "ServiceMetrics", "ServiceExecutor"]

DEAD = "__dead__"  # pool owner holding dead-but-unreclaimed old-gen bytes


@dataclass(frozen=True)
class GcModel:
    """JVM garbage-collection cost model (stop-the-world)."""

    young_fraction: float = 0.2  # young generation share of the heap
    minor_pause_base: float = 0.01  # seconds
    #: survivor copy is pointer-chasing + card marking — slow per byte
    copy_bandwidth: float = 0.3e9  # bytes/s survivor copy rate (minor)
    #: every minor GC also scans old-gen card tables / remembered sets —
    #: the pause component that makes long-living data tax *all* tasks
    #: ("long-living objects incur significant memory and CPU overheads")
    old_scan_bandwidth: float = 3e9  # bytes of old-gen live scanned per s
    full_pause_base: float = 0.2  # seconds
    mark_bandwidth: float = 2e9  # bytes/s live mark+compact rate (full)
    #: full GC triggers when (live+dead) exceeds this fraction of the heap.
    #: The headroom between fulls is therefore DYNAMIC: trigger×cap − floor,
    #: where floor is the surviving live set — a scheduler that shrinks the
    #: floor (fewer concurrent buffers) gets superlinearly fewer full GCs,
    #: and one that lets the floor cross the trigger enters permanent thrash.
    full_trigger: float = 0.65
    #: back-off between fulls while thrashing (floor ≥ trigger even after
    #: collection — the concurrent-mode-failure regime)
    full_cooldown: float = 3.0
    #: minimum young-gen working space; OOM if it cannot be maintained
    young_min_fraction: float = 0.08


def pressure_slowdown(used_fraction: float) -> float:
    """Mutator throughput multiplier as a function of heap occupancy.

    The paper's central observation (§II): as free memory shrinks, *the task
    computation suffers* — every allocation becomes slower (TLAB refill
    failures, allocation stalls, fragmentation, collector back-pressure).
    This is the schedule-DEPENDENT cost that a memory-pressure-aware
    scheduler can actually remove: FAIR lets occupancy sit near the top and
    pays it on every record of every task; MURS holds occupancy below the
    knee.  Piecewise-linear knee curve:

        u ≤ 0.55        → 1.0   (no pressure)
        0.55 < u ≤ 0.80 → 1.0 → 0.55
        0.80 < u ≤ 0.95 → 0.55 → 0.25
        u > 0.95        → 0.20  (allocation-stall regime)
    """
    if used_fraction <= 0.55:
        return 1.0
    if used_fraction <= 0.80:
        return 1.0 + (used_fraction - 0.55) * (0.55 - 1.0) / 0.25
    if used_fraction <= 0.95:
        return 0.55 + (used_fraction - 0.80) * (0.25 - 0.55) / 0.15
    return 0.20


@dataclass(frozen=True)
class SpillModel:
    """Spark-1.6-style execution-memory spill behaviour."""

    #: unified execution+storage memory fraction (spark.memory.fraction)
    exec_fraction: float = 0.6
    #: fraction of a buffer that can actually be written out (the rest is
    #: in-flight objects — hot-key collections mid-materialization)
    spillable_fraction: float = 0.7


@dataclass(frozen=True)
class JobSpec:
    job_id: str
    stages: List[List[TaskSpec]]  # stages in order; tasks per stage
    submit_time: float = 0.0


@dataclass
class JobMetrics:
    job_id: str
    submit_time: float = 0.0
    finish_time: float = -1.0
    gc_time: float = 0.0
    spills: int = 0
    spilled_bytes: float = 0.0
    oom: bool = False
    tasks_total: int = 0

    @property
    def exec_time(self) -> float:
        return self.finish_time - self.submit_time if self.finish_time >= 0 else -1.0


@dataclass
class ServiceMetrics:
    jobs: Dict[str, JobMetrics] = field(default_factory=dict)
    minor_gcs: int = 0
    full_gcs: int = 0
    total_gc_time: float = 0.0
    oom: bool = False
    min_active_tasks: int = 1 << 30
    peak_task_live: Dict[str, float] = field(default_factory=dict)
    peak_pool_used_fraction: float = 0.0
    suspensions: int = 0
    sim_time: float = 0.0


class ServiceExecutor:
    """Tick-driven executor scheduling exclusively through ``policy``.

    ``policy`` takes any :class:`SchedulingPolicy`; the legacy ``murs``
    kwarg (a :class:`MursConfig`, or None for the FAIR baseline) is kept
    as a constructor convenience and resolves to :class:`MursPolicy` /
    :class:`FairPolicy`.
    """

    def __init__(
        self,
        *,
        cores: int,
        heap_bytes: float,
        proc_rate: float = 8e6,  # bytes/s of input per core (incl. shuffle,
        # serialization, disk — Spark-realistic; tasks run minutes, so the
        # seasonal sampler catches heavy tasks early in their life)
        disk_bandwidth: float = 150e6,  # spill write rate
        gc: Optional[GcModel] = None,
        spill: Optional[SpillModel] = None,
        murs: Optional[MursConfig] = None,
        policy: Optional[SchedulingPolicy] = None,
        dt: float = 0.05,
        max_time: float = 36000.0,
        oom_is_fatal: bool = True,
    ) -> None:
        if policy is not None and murs is not None:
            raise ValueError("pass either policy= or murs=, not both")
        self.cores = cores
        self.pool = MemoryPool(capacity=heap_bytes)
        self.proc_rate = proc_rate
        self.disk_bandwidth = disk_bandwidth
        self.gc = gc or GcModel()
        self.spill = spill or SpillModel()
        self.policy: SchedulingPolicy = policy or (
            MursPolicy(murs) if murs is not None else FairPolicy()
        )
        self.sampler = Sampler()
        self.dt = dt
        self.max_time = max_time
        self.oom_is_fatal = oom_is_fatal

        self.time = 0.0
        self._next_full_gc_allowed = 0.0
        self._live_at_last_full = 0.0
        self._jobs: Dict[str, JobSpec] = {}
        self._job_stage: Dict[str, int] = {}
        self._pending: Dict[str, List[TaskSpec]] = {}  # runnable, not started
        self._running: Dict[str, TaskState] = {}
        self._suspended: Dict[str, TaskState] = {}
        self._stage_remaining: Dict[str, int] = {}
        self._last_minor_live = 0.0
        self._next_sample = 0.0
        self.metrics = ServiceMetrics()

    # ------------------------------------------------------------ submission
    def submit(self, job: JobSpec) -> None:
        self._jobs[job.job_id] = job
        self._job_stage[job.job_id] = 0
        self.metrics.jobs[job.job_id] = JobMetrics(
            job_id=job.job_id,
            submit_time=job.submit_time,
            tasks_total=sum(len(s) for s in job.stages),
        )

    # ---------------------------------------------------------------- runner
    def run(self) -> ServiceMetrics:
        while self.time < self.max_time:
            if self._all_done():
                break
            self._tick()
        self.metrics.sim_time = self.time
        if self.metrics.min_active_tasks == 1 << 30:
            self.metrics.min_active_tasks = 0
        return self.metrics

    def _all_done(self) -> bool:
        if self.metrics.oom and self.oom_is_fatal:
            return True
        for jid, job in self._jobs.items():
            if self.time < job.submit_time:
                return False
            if self.metrics.jobs[jid].finish_time < 0:
                return False
        return True

    # ------------------------------------------------------------------ tick
    def _tick(self) -> None:
        dt = self.dt
        self._activate_stages()
        self._launch_tasks()

        running = [
            t
            for t in self._running.values()
            if not t.suspended and t.spill_block_until <= self.time
        ]
        self.metrics.min_active_tasks = min(
            self.metrics.min_active_tasks, len(running) or self.metrics.min_active_tasks
        )

        # --- advance tasks (throughput degrades with heap occupancy) -----
        speed = pressure_slowdown(self.pool.used_fraction)
        for task in running:
            garbage = task.advance(self.proc_rate * speed * dt)
            self.pool.add_transient(task.spec.task_id, garbage)
            self.pool.set_live(task.spec.task_id, task.live)
            peak = self.metrics.peak_task_live.get(task.spec.task_id, 0.0)
            if task.live > peak:
                self.metrics.peak_task_live[task.spec.task_id] = task.live
        self.metrics.peak_pool_used_fraction = max(
            self.metrics.peak_pool_used_fraction, self.pool.used_fraction
        )

        # --- garbage collection (before spill/OOM: allocation failure is
        # only real after collection has had its chance) -------------------
        self._maybe_gc()

        # --- spill / OOM -------------------------------------------------
        self._maybe_spill_or_oom()

        # --- task completion ---------------------------------------------
        self._complete_tasks()

        # --- seasonal policy pass ----------------------------------------
        if self.time >= self._next_sample:
            self._policy_pass()
            self._next_sample = self.time + self.policy.period

        self.time += dt

    # ------------------------------------------------------------ stage flow
    def _activate_stages(self) -> None:
        for jid, job in self._jobs.items():
            if self.time < job.submit_time:
                continue
            stage = self._job_stage[jid]
            if stage >= len(job.stages):
                continue
            key = f"{jid}/s{stage}"
            if key not in self._stage_remaining:
                tasks = job.stages[stage]
                self._stage_remaining[key] = len(tasks)
                self._pending.setdefault(jid, []).extend(tasks)

    def _launch_tasks(self) -> None:
        """Fill free cores in the order the policy's ``assign`` hook picks.

        A suspended task's thread sleeps inside InterruptibleIterator and
        costs no CPU: its *core* is released to other tasks (paper §I: "the
        resources are released from running heavy tasks") while its buffer
        stays resident.  Fresh launches therefore backfill suspended tasks'
        slots — typically with the light jobs' tasks, which is exactly how
        "the light tasks can then complete quickly".
        """
        free = self.cores - sum(
            1 for t in self._running.values() if not t.suspended
        )
        # A job with suspended tasks is a known heavy-pressure source: a
        # proactive policy does not launch more of its tasks until its
        # queue drains — the released cores go to the light jobs' tasks.
        gated = {
            self._running[tid].spec.job_id
            for tid in self.policy.suspended_queue
            if tid in self._running
        }
        pending = {
            j: len(p) for j, p in self._pending.items() if p and j not in gated
        }
        for jid in self.policy.assign(free, pending):
            spec = self._pending[jid].pop(0)
            self._running[spec.task_id] = TaskState(spec=spec)

    # ------------------------------------------------------------- spill/OOM
    def _maybe_spill_or_oom(self) -> None:
        """Spark-1.6 semantics (paper §IV): "the maximum memory space
        allowed for each task must be less than M/N" — a task whose buffer
        exceeds the per-task cap exec_pool/N spills the excess.  Reducing N
        (what MURS's suspension does) raises everyone's cap — this is the
        spill-avoidance channel of Table III."""
        sp = self.spill
        exec_pool = sp.exec_fraction * self.pool.capacity
        states = [t for t in self._running.values() if not t.done]
        n = max(sum(1 for t in states if not t.suspended), 1)
        share = exec_pool / n
        for t in states:
            if t.suspended or t.live <= share:
                continue
            written = t.spill(sp.spillable_fraction)
            self.pool.set_live(t.spec.task_id, t.live)
            t.spill_block_until = self.time + written / self.disk_bandwidth
            jm = self.metrics.jobs[t.spec.job_id]
            jm.spills += 1
            jm.spilled_bytes += written
        # OOM: after GC had its chance and everything spillable spilled,
        # the pool must still leave a minimal young-gen working space.
        young_min = self.gc.young_min_fraction * self.pool.capacity
        if self.pool.used_bytes + young_min >= self.pool.capacity:
            self._force_full_gc()
            if self.pool.used_bytes + young_min >= self.pool.capacity:
                self.metrics.oom = True
                for jm in self.metrics.jobs.values():
                    if jm.finish_time < 0:
                        jm.oom = True

    # ------------------------------------------------------------------- GC
    def _force_full_gc(self) -> None:
        pause = (
            self.gc.full_pause_base + self.pool.live_bytes / self.gc.mark_bandwidth
        )
        self.pool.release_owner(DEAD)
        self.pool.minor_gc()
        self._last_minor_live = self.pool.live_bytes
        self._live_at_last_full = self.pool.live_bytes
        self.metrics.full_gcs += 1
        self._bill_gc(pause)

    def _maybe_gc(self) -> None:
        g = self.gc
        young_cap = g.young_fraction * self.pool.capacity
        pause = 0.0
        if self.pool.transient_bytes >= young_cap:
            survivors = max(self.pool.live_bytes - self._last_minor_live, 0.0)
            pause += (
                g.minor_pause_base
                + survivors / g.copy_bandwidth
                + self.pool.live_bytes / g.old_scan_bandwidth
            )
            self.pool.minor_gc()
            self._last_minor_live = self.pool.live_bytes
            self.metrics.minor_gcs += 1
        if (
            self.pool.live_fraction >= g.full_trigger
            and self.time >= self._next_full_gc_allowed
        ):
            live_before = self.pool.live_bytes
            pause += g.full_pause_base + live_before / g.mark_bandwidth
            self.pool.release_owner(DEAD)  # reclaim dead old-gen objects
            self.pool.minor_gc()
            self._last_minor_live = self.pool.live_bytes
            self._live_at_last_full = self.pool.live_bytes
            self.metrics.full_gcs += 1
            if self.pool.live_fraction >= g.full_trigger:
                # Even a full collection left the floor above the trigger:
                # permanent-thrash regime (the live set is genuinely large).
                # Pace it — real collectors degrade, they don't spin.
                self._next_full_gc_allowed = self.time + pause + g.full_cooldown
            for tid in self.policy.on_full_gc(self.pool):
                self._resume(tid)
        if pause > 0.0:
            self._bill_gc(pause)

    def _bill_gc(self, pause: float) -> None:
        """Stop-the-world: bill the pause to every in-flight job and to
        wall-clock (all task progress already excluded the pause)."""
        self.metrics.total_gc_time += pause
        active_jobs = {t.spec.job_id for t in self._running.values()} | {
            j for j, p in self._pending.items() if p
        }
        for jid in active_jobs:
            if self.metrics.jobs[jid].finish_time < 0:
                self.metrics.jobs[jid].gc_time += pause
        self.time += pause

    # ------------------------------------------------------------ completion
    def _complete_tasks(self) -> None:
        finished = [t for t in self._running.values() if t.done]
        for t in finished:
            spec = t.spec
            del self._running[spec.task_id]
            # Old-gen buffers of a finished task are dead but unreclaimed
            # until the next full GC (the "revise after full GC" effect).
            self.pool.add_live(DEAD, self.pool.live.pop(spec.task_id, 0.0))
            self.pool.transient.pop(spec.task_id, None)
            if spec.cache_on_complete > 0.0:
                self.pool.add_live(f"cache/{spec.job_id}", spec.cache_on_complete)
            self.sampler.forget(spec.task_id)
            key = f"{spec.job_id}/s{spec.stage}"
            self._stage_remaining[key] -= 1
            if self._stage_remaining[key] == 0:
                self._job_stage[spec.job_id] += 1
                if self._job_stage[spec.job_id] >= len(
                    self._jobs[spec.job_id].stages
                ):
                    jm = self.metrics.jobs[spec.job_id]
                    jm.finish_time = self.time
                    # job-lifetime caches die with the job (dead until full GC)
                    freed = self.pool.live.pop(f"cache/{spec.job_id}", 0.0)
                    self.pool.add_live(DEAD, freed)
            tid = self.policy.on_task_complete(spec.task_id)
            if tid is not None:
                self._resume(tid)

    # ---------------------------------------------------------------- policy
    def _policy_pass(self) -> None:
        running_states = [
            t for t in self._running.values() if not t.suspended
        ]
        suspended_states = [t for t in self._running.values() if t.suspended]
        for t in running_states:
            self.sampler.observe(
                t.spec.task_id,
                processed_bytes=t.processed,
                total_bytes=t.spec.input_bytes,
                live_bytes=t.live,
                group=t.spec.job_id,
            )
        stats = self.sampler.stats([t.spec.task_id for t in running_states])
        frozen = self.sampler.stats([t.spec.task_id for t in suspended_states])
        decision: SchedulingDecision = self.policy.propose(
            self.pool, stats, now=self.time, suspended=frozen
        )
        for tid in decision.suspend:
            state = self._running.get(tid)
            if state is not None and not state.done:
                state.suspended = True
                self._suspended[tid] = state
                self.metrics.suspensions += 1
        for tid in decision.resume:
            self._resume(tid)

    def _resume(self, task_id: str) -> None:
        state = self._suspended.pop(task_id, None)
        if state is not None:
            state.suspended = False
