"""The MURS Sampler (paper §V).

Runs "seasonally" (periodically); for every running task it records the
metrics the scheduler consumes:

    * bytes of input processed so far / total input bytes  → completion %
    * live (long-lifetime) bytes currently attributed to the task
    * the memory-usage-rate estimate Δlive/Δprocessed and its model trend

The sampler is shared verbatim between the Spark-fidelity simulator
(`spark_sim.py`) and the JAX serving engine (`repro.serve.engine`): both feed
it (processed, live) observations; neither needs JVM tracing because the
accounting layers know exactly which bytes are live.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable

from .usage_models import RateEstimator, UsageModel

__all__ = ["TaskStats", "Sampler"]


@dataclass
class TaskStats:
    """Snapshot of one running task, as consumed by Algorithm 1."""

    task_id: str
    consumption: float  # live bytes currently attributed to the task
    rate: float  # Δlive / Δprocessed (memory usage rate)
    progress: float  # fraction of input processed, in [0, 1]
    remaining_bytes: float = 0.0  # input bytes still to process
    model: UsageModel = UsageModel.CONSTANT
    #: scheduling group the task belongs to (job id in the simulator,
    #: tenant in the serving engine) — consumed by tenant-aware policies
    group: str = ""

    @property
    def memory_necessary(self) -> float:
        """Projected additional live bytes to finish.

        Paper §III-B: "we use the current memory usage model to calculate
        the memory usage of the task" — the model-aware projection is
        rate × remaining input.  The pseudocode's c × (1 − done%) variant
        underestimates early in a task's life; we take the max of the two
        (conservative, still cheap to compute online).
        """
        return max(
            self.rate * self.remaining_bytes,
            self.consumption * (1.0 - self.progress),
        )

    @property
    def projected_total(self) -> float:
        """Projected total consumption at completion: c / done%."""
        if self.progress <= 1e-9:
            return float("inf")
        return self.consumption / self.progress


@dataclass
class Sampler:
    """Per-task metric store with online rate estimation."""

    window: int = 32
    _estimators: Dict[str, RateEstimator] = field(default_factory=dict)
    _progress: Dict[str, float] = field(default_factory=dict)
    _consumption: Dict[str, float] = field(default_factory=dict)
    _remaining: Dict[str, float] = field(default_factory=dict)
    _group: Dict[str, str] = field(default_factory=dict)

    def observe(
        self,
        task_id: str,
        *,
        processed_bytes: float,
        total_bytes: float,
        live_bytes: float,
        group: str = "",
    ) -> None:
        est = self._estimators.get(task_id)
        if est is None:
            est = self._estimators[task_id] = RateEstimator(window=self.window)
        est.update(processed_bytes, live_bytes)
        self._consumption[task_id] = live_bytes
        if total_bytes > 0:
            self._progress[task_id] = min(processed_bytes / total_bytes, 1.0)
        else:
            self._progress[task_id] = 1.0
        self._remaining[task_id] = max(total_bytes - processed_bytes, 0.0)
        if group:
            self._group[task_id] = group

    def forget(self, task_id: str) -> None:
        self._estimators.pop(task_id, None)
        self._progress.pop(task_id, None)
        self._consumption.pop(task_id, None)
        self._remaining.pop(task_id, None)
        self._group.pop(task_id, None)

    def stats(self, task_ids: Iterable[str]) -> list[TaskStats]:
        out = []
        for tid in task_ids:
            est = self._estimators.get(tid)
            out.append(
                TaskStats(
                    task_id=tid,
                    consumption=self._consumption.get(tid, 0.0),
                    rate=est.rate if est else 0.0,
                    progress=self._progress.get(tid, 0.0),
                    remaining_bytes=self._remaining.get(tid, 0.0),
                    model=est.model if est else UsageModel.CONSTANT,
                    group=self._group.get(tid, ""),
                )
            )
        return out
