"""DEPRECATED re-export shim — the scheduler moved to :mod:`repro.sched`.

The MURS decision procedure (paper §IV, Algorithm 1) lives in
:mod:`repro.sched.murs` as :class:`MursPolicy`, one implementation of the
pluggable :class:`repro.sched.SchedulingPolicy` protocol that both the
Spark-fidelity simulator and the JAX serving engine consume.  This module
keeps the historical import path alive for one release; ``MursScheduler``
is an alias of ``MursPolicy``.  Import from :mod:`repro.sched` instead.
"""

import warnings

from repro.sched.murs import MursConfig, MursPolicy
from repro.sched.protocol import SchedulingDecision

warnings.warn(
    "repro.core.scheduler is deprecated; import MursConfig/MursPolicy/"
    "SchedulingDecision from repro.sched instead",
    DeprecationWarning,
    stacklevel=2,
)

MursScheduler = MursPolicy

__all__ = ["MursConfig", "MursPolicy", "MursScheduler", "SchedulingDecision"]
