"""MURS — the Memory-Usage-Rate based Scheduler (paper §IV, Algorithm 1).

Decision procedure, invoked periodically ("seasonally") with fresh Sampler
stats and the pool state:

    usage < yellow                     → no action (and: resume ALL suspended
                                         tasks once usage drops below yellow
                                         after a full GC)
    yellow ≤ usage < red, SQ empty     → ComputeSuspendTasks: keep the
                                         lowest-rate tasks whose projected
                                         remaining need Σ c·(1−done%) fits the
                                         free pool, suspend the rest (the
                                         heavy tasks) into a FIFO queue
    yellow ≤ usage < red, SQ non-empty → no action (pressure already handled)
    usage ≥ red                        → emergency: ComputeSuspendTasks against
                                         the shrunken free pool (queue gate
                                         ignored) plus ComputeSpill — suspend
                                         every task whose actual (c > M/N) or
                                         projected (c/done% > M/N) consumption
                                         exceeds its fair share, cutting the
                                         degree of parallelism before
                                         spill / OOM

On every task completion one suspended task is resumed (FIFO — avoids
starvation, paper §VI-D); dropping below yellow resumes all.

The published pseudocode has two OCR-garbled lines (its line 21 pushes the
*kept* min-rate task into SQ; its branch order tests red before yellow);
we follow the unambiguous prose of §IV: the *returned* heavy tasks are the
ones suspended and queued, and ComputeSuspendTasks runs in the yellow band
while ComputeSpill guards the red band.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from .memory_manager import MemoryPool
from .sampler import TaskStats

__all__ = ["MursConfig", "SchedulingDecision", "MursScheduler"]


@dataclass(frozen=True)
class MursConfig:
    """Thresholds and knobs of MURS (defaults from the paper: 0.4 / 0.8)."""

    yellow: float = 0.4
    red: float = 0.8
    #: sampler/scheduler period in (sim or wall) seconds
    period: float = 1.0
    #: never suspend below this many running tasks (keep the service live)
    min_running: int = 1
    #: the collector's full-GC initiating occupancy.  Heap above this line
    #: is not usable without incurring full collections, so the scheduler's
    #: working notion of "free memory" is the headroom below it:
    #: free = trigger×capacity − live.  Set to None to use the raw
    #: JM.freeMemory reading of the paper's pseudocode (heap − used).
    collector_trigger: Optional[float] = 0.65
    #: a freshly resumed task cannot be re-suspended for this many seconds —
    #: prevents the suspend/resume oscillation around the yellow threshold
    resume_immunity: float = 5.0
    #: execution-memory share of the pool that the memory manager actually
    #: grants to tasks — the fair share M/N of ComputeSpill is M_exec/N, the
    #: same limit the environment spills at (anything larger never fires).
    #: Held slightly below the environment's grant (0.6) as a safety margin
    #: so kept tasks finish without ever hitting the per-task cap.
    exec_fraction: float = 0.55

    def __post_init__(self) -> None:
        if not (0.0 < self.yellow <= self.red <= 1.0):
            raise ValueError(
                f"need 0 < yellow <= red <= 1, got {self.yellow}, {self.red}"
            )


@dataclass
class SchedulingDecision:
    """Output of one scheduler invocation."""

    suspend: List[str] = field(default_factory=list)
    resume: List[str] = field(default_factory=list)
    reason: str = "ok"

    @property
    def is_noop(self) -> bool:
        return not self.suspend and not self.resume


class MursScheduler:
    """Algorithm 1 with FIFO suspension queue and resume rules."""

    def __init__(self, config: Optional[MursConfig] = None) -> None:
        self.config = config or MursConfig()
        self._suspended: List[str] = []  # FIFO: index 0 = first suspended
        self._resumed_at: dict[str, float] = {}
        self._now: float = 0.0

    # ------------------------------------------------------------ properties
    @property
    def suspended_queue(self) -> Sequence[str]:
        return tuple(self._suspended)

    @property
    def has_suspended(self) -> bool:
        return bool(self._suspended)

    def _immune(self, task_id: str) -> bool:
        t0 = self._resumed_at.get(task_id)
        return t0 is not None and (self._now - t0) < self.config.resume_immunity

    # ------------------------------------------------------------- main loop
    def propose(
        self,
        pool: MemoryPool,
        running: Sequence[TaskStats],
        now: float = 0.0,
        suspended: Sequence[TaskStats] = (),
    ) -> SchedulingDecision:
        """One "seasonal" scheduling pass (paper Algorithm 1).

        Yellow band: classify by rate and suspend the heavy tail (once —
        gated on an empty suspension queue, paper line 7).  Red band: the
        emergency path — ComputeSuspendTasks against the (now tiny) free
        pool *plus* the ComputeSpill fair-share guard, regardless of the
        queue gate, because red means spill/OOM is imminent.
        """
        cfg = self.config
        self._now = now
        usage = pool.live_fraction

        if usage < cfg.yellow:
            # Pressure receded: resume everything still suspended.
            if self._suspended:
                resumed = list(self._suspended)
                self._suspended.clear()
                for tid in resumed:
                    self._resumed_at[tid] = now
                return SchedulingDecision(resume=resumed, reason="below-yellow")
            return SchedulingDecision(reason="light")

        if usage >= cfg.red:
            d1 = self._compute_suspend_tasks(pool, running)
            still = [t for t in running if t.task_id not in set(d1.suspend)]
            d2 = self._compute_spill(pool, still, suspended)
            return SchedulingDecision(
                suspend=d1.suspend + d2.suspend,
                reason="red-emergency" if (d1.suspend or d2.suspend) else "red-fits",
            )

        # Spill-avoidance: if the execution pool is close to exhaustion the
        # memory manager is about to deny allocations (spill), regardless of
        # total-heap occupancy — run the ComputeSpill guard now.
        exec_pool = cfg.exec_fraction * pool.capacity
        frozen = sum(t.consumption for t in suspended)
        projected = sum(t.consumption + t.rate * t.remaining_bytes for t in running)
        if frozen + projected >= 0.9 * exec_pool:
            d = self._compute_spill(pool, running, suspended)
            if d.suspend:
                return d

        if self._suspended:
            # Yellow band but pressure already being handled.
            return SchedulingDecision(reason="already-suspended")

        return self._compute_suspend_tasks(pool, running)

    # --------------------------------------------------- ComputeSuspendTasks
    def _compute_suspend_tasks(
        self, pool: MemoryPool, running: Sequence[TaskStats]
    ) -> SchedulingDecision:
        """Keep lowest-rate tasks that fit free memory; suspend the rest."""
        cfg = self.config
        if cfg.collector_trigger is not None:
            free = max(
                cfg.collector_trigger * pool.capacity - pool.live_bytes, 0.0
            )
            free = min(free, pool.free_bytes)
        else:
            free = pool.free_bytes
        fair_share = self._fair_share(pool, running)

        # Order by projected FUTURE growth (rate × remaining input): keeping
        # low-future-growth tasks lets them finish cheaply, while suspending
        # high-future-growth tasks freezes only their (typically still small)
        # current buffer and saves all of their remaining growth.
        by_growth = sorted(
            running, key=lambda t: (t.rate * t.remaining_bytes, t.rate, t.task_id)
        )
        kept: List[TaskStats] = []
        suspend: List[TaskStats] = []
        for t in by_growth:
            if len(kept) < cfg.min_running or self._immune(t.task_id):
                kept.append(t)
                free -= t.memory_necessary
                continue
            # Inline spill guard (paper line 17): a task that would exceed its
            # fair share cannot be saved by suspending others — reduce the
            # degree of parallelism by suspending it instead.
            if self._violates_fair_share(t, fair_share):
                suspend.append(t)
                continue
            need = t.memory_necessary
            if free - need > 0.0:
                free -= need
                kept.append(t)
            else:
                suspend.append(t)

        # Suspend heaviest-first ordering for the FIFO queue: tasks were
        # examined in ascending rate, so `suspend` is already ascending;
        # queue them ascending so that the FIFO resume brings back the
        # lightest suspended task first.
        ids = [t.task_id for t in suspend]
        self._suspended.extend(ids)
        return SchedulingDecision(
            suspend=ids,
            reason="yellow-suspend" if ids else "yellow-fits",
        )

    # ---------------------------------------------------------- ComputeSpill
    def _compute_spill(
        self,
        pool: MemoryPool,
        running: Sequence[TaskStats],
        suspended: Sequence[TaskStats] = (),
    ) -> SchedulingDecision:
        """Spill-avoidance: reduce parallelism until the projected total
        consumption of the kept tasks — plus the frozen buffers of already
        suspended tasks, which stay resident — fits the execution pool, so
        the memory manager never has to deny an allocation (paper: "ensures
        that the running tasks can complete with the remaining memory
        space")."""
        cfg = self.config
        budget = cfg.exec_fraction * pool.capacity
        budget -= sum(t.consumption for t in suspended)
        by_growth = sorted(
            running, key=lambda t: (t.rate * t.remaining_bytes, t.rate, t.task_id)
        )
        suspend: List[str] = []
        kept = 0
        for t in by_growth:
            projected = t.consumption + t.rate * t.remaining_bytes
            if kept < cfg.min_running or self._immune(t.task_id):
                kept += 1
                budget -= projected
                continue
            if budget - projected > 0.0:
                budget -= projected
                kept += 1
            elif t.task_id not in self._suspended:
                suspend.append(t.task_id)
                budget -= t.consumption  # its buffer stays frozen in the pool
        self._suspended.extend(suspend)
        return SchedulingDecision(
            suspend=suspend, reason="spill-avoidance" if suspend else "spill-fits"
        )

    def _fair_share(
        self, pool: MemoryPool, running: Sequence[TaskStats]
    ) -> float:
        n = max(len(running), 1)
        return self.config.exec_fraction * pool.capacity / n

    @staticmethod
    def _violates_fair_share(t: TaskStats, fair_share: float) -> bool:
        if t.consumption > fair_share:
            return True
        return t.progress > 1e-9 and t.projected_total > fair_share

    # ------------------------------------------------------------ resume API
    def on_task_complete(self) -> Optional[str]:
        """A running task finished: resume the first suspended task (FIFO)."""
        if self._suspended:
            tid = self._suspended.pop(0)
            self._resumed_at[tid] = self._now
            return tid
        return None

    def on_full_gc(self, pool: MemoryPool) -> List[str]:
        """After a full GC, resume all if usage dropped below yellow."""
        if pool.live_fraction < self.config.yellow and self._suspended:
            resumed = list(self._suspended)
            self._suspended.clear()
            for tid in resumed:
                self._resumed_at[tid] = self._now
            return resumed
        return []

    def drop(self, task_id: str) -> None:
        """Remove a task from the queue (e.g. its job was cancelled)."""
        self._suspended = [t for t in self._suspended if t != task_id]
