"""Re-export shim — the scheduler moved to :mod:`repro.sched`.

The MURS decision procedure (paper §IV, Algorithm 1) now lives in
:mod:`repro.sched.murs` as :class:`MursPolicy`, one implementation of the
pluggable :class:`repro.sched.SchedulingPolicy` protocol that both the
Spark-fidelity simulator and the JAX serving engine consume.  This module
keeps the historical import path alive; ``MursScheduler`` is an alias of
``MursPolicy``.
"""

from repro.sched.murs import MursConfig, MursPolicy
from repro.sched.protocol import SchedulingDecision

MursScheduler = MursPolicy

__all__ = ["MursConfig", "MursPolicy", "MursScheduler", "SchedulingDecision"]
