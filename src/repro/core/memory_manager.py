"""Shared memory-pool accounting (the JVM-heap / HBM-pool analogue).

The pool tracks two byte classes per owner, mirroring JVM generations:

    transient  — young-generation objects; reclaimed wholesale by a minor GC
                 (on TPU: per-step activations freed at step end)
    live       — old-generation / long-living objects: shuffle buffers, cached
                 RDD blocks (on TPU: KV caches, cached activations)

The MURS pressure indicator is the fraction of *live* bytes in the pool,
measured right after a minor GC (paper §IV: "the percentage of the heap usage
after a minor GC represents the living data objects in the heap").
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

__all__ = ["MemoryPool", "OutOfMemoryError"]


class OutOfMemoryError(RuntimeError):
    """Raised when a non-reclaimable allocation exceeds pool capacity."""


@dataclass
class MemoryPool:
    """Byte-accurate shared pool with live/transient accounting per owner."""

    capacity: float
    live: Dict[str, float] = field(default_factory=dict)
    transient: Dict[str, float] = field(default_factory=dict)

    # ------------------------------------------------------------------ sums
    @property
    def live_bytes(self) -> float:
        return sum(self.live.values())

    @property
    def transient_bytes(self) -> float:
        return sum(self.transient.values())

    @property
    def used_bytes(self) -> float:
        return self.live_bytes + self.transient_bytes

    @property
    def free_bytes(self) -> float:
        return max(self.capacity - self.used_bytes, 0.0)

    @property
    def used_fraction(self) -> float:
        """An EMPTY zero-capacity pool reads 0.0 (not permanently full):
        constant-state deployments legitimately run with no pool at all."""
        if self.capacity > 0:
            return self.used_bytes / self.capacity
        return 0.0 if not self.used_bytes else 1.0

    @property
    def live_fraction(self) -> float:
        """The MURS pressure indicator: long-living bytes / capacity."""
        if self.capacity > 0:
            return self.live_bytes / self.capacity
        return 0.0 if not self.live_bytes else 1.0

    # ------------------------------------------------------------- mutation
    def add_live(self, owner: str, nbytes: float) -> None:
        self.live[owner] = self.live.get(owner, 0.0) + nbytes
        if self.live[owner] < 0.0:
            self.live[owner] = 0.0

    def set_live(self, owner: str, nbytes: float) -> None:
        self.live[owner] = max(float(nbytes), 0.0)

    def add_transient(self, owner: str, nbytes: float) -> None:
        self.transient[owner] = self.transient.get(owner, 0.0) + nbytes
        if self.transient[owner] < 0.0:
            self.transient[owner] = 0.0

    def release_owner(self, owner: str) -> float:
        """Free everything held by ``owner`` (task completed/evicted)."""
        freed = self.live.pop(owner, 0.0) + self.transient.pop(owner, 0.0)
        return freed

    def minor_gc(self) -> float:
        """Reclaim all transient bytes; returns surviving (live) bytes."""
        self.transient.clear()
        return self.live_bytes

    def owner_bytes(self, owner: str) -> float:
        return self.live.get(owner, 0.0) + self.transient.get(owner, 0.0)
