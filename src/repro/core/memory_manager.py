"""Shared memory-pool accounting (the JVM-heap / HBM-pool analogue).

The pool tracks two byte classes per owner, mirroring JVM generations:

    transient  — young-generation objects; reclaimed wholesale by a minor GC
                 (on TPU: per-step activations freed at step end)
    live       — old-generation / long-living objects: shuffle buffers, cached
                 RDD blocks (on TPU: KV caches, cached activations)

The MURS pressure indicator is the fraction of *live* bytes in the pool,
measured right after a minor GC (paper §IV: "the percentage of the heap usage
after a minor GC represents the living data objects in the heap").

``live_bytes`` / ``used_fraction`` sit on every hot path of the serving
engine (admission headroom checks, overcommit resolution, per-tick peak
tracking — many reads per tick), so the owner maps are
:class:`_OwnerLedger` dicts that maintain a running total through every
mutation path, turning each read into O(1) instead of O(owners).  The
ledger IS a dict — callers that reach past the MemoryPool API and mutate
``pool.live`` directly (``pop``/``clear``/item assignment) stay correct.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

__all__ = ["MemoryPool", "OutOfMemoryError"]


class OutOfMemoryError(RuntimeError):
    """Raised when a non-reclaimable allocation exceeds pool capacity."""


class _OwnerLedger(Dict[str, float]):
    """``Dict[str, float]`` with an O(1) running :attr:`total`.

    Every mutating dict method is overridden to keep ``total`` exact;
    emptying the ledger resets it to literal 0.0 so float error cannot
    accumulate across fill/drain cycles.
    """

    def __init__(self, *args, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.total = float(sum(self.values()))

    def _settle(self) -> None:
        if not self:
            self.total = 0.0

    def __setitem__(self, key: str, value: float) -> None:
        self.total += value - super().get(key, 0.0)
        super().__setitem__(key, value)

    def __delitem__(self, key: str) -> None:
        self.total -= super().__getitem__(key)
        super().__delitem__(key)
        self._settle()

    def pop(self, key, *default):
        if key in self:
            self.total -= super().__getitem__(key)
        out = super().pop(key, *default)
        self._settle()
        return out

    def popitem(self):
        key, value = super().popitem()
        self.total -= value
        self._settle()
        return key, value

    def clear(self) -> None:
        super().clear()
        self.total = 0.0

    def update(self, *args, **kwargs) -> None:
        for key, value in dict(*args, **kwargs).items():
            self[key] = value

    def setdefault(self, key, default=0.0):
        if key not in self:
            self[key] = default
        return super().__getitem__(key)

    def copy(self) -> "_OwnerLedger":
        return _OwnerLedger(self)


@dataclass
class MemoryPool:
    """Byte-accurate shared pool with live/transient accounting per owner."""

    capacity: float
    live: Dict[str, float] = field(default_factory=_OwnerLedger)
    transient: Dict[str, float] = field(default_factory=_OwnerLedger)

    def __post_init__(self) -> None:
        # a caller-supplied plain dict still gets O(1) totals
        if not isinstance(self.live, _OwnerLedger):
            self.live = _OwnerLedger(self.live)
        if not isinstance(self.transient, _OwnerLedger):
            self.transient = _OwnerLedger(self.transient)

    # ------------------------------------------------------------------ sums
    @property
    def live_bytes(self) -> float:
        live = self.live
        if isinstance(live, _OwnerLedger):
            return live.total
        return sum(live.values())  # someone replaced the dict wholesale

    @property
    def transient_bytes(self) -> float:
        transient = self.transient
        if isinstance(transient, _OwnerLedger):
            return transient.total
        return sum(transient.values())

    @property
    def used_bytes(self) -> float:
        return self.live_bytes + self.transient_bytes

    @property
    def free_bytes(self) -> float:
        return max(self.capacity - self.used_bytes, 0.0)

    @property
    def used_fraction(self) -> float:
        """An EMPTY zero-capacity pool reads 0.0 (not permanently full):
        constant-state deployments legitimately run with no pool at all."""
        if self.capacity > 0:
            return self.used_bytes / self.capacity
        return 0.0 if not self.used_bytes else 1.0

    @property
    def live_fraction(self) -> float:
        """The MURS pressure indicator: long-living bytes / capacity."""
        if self.capacity > 0:
            return self.live_bytes / self.capacity
        return 0.0 if not self.live_bytes else 1.0

    # ------------------------------------------------------------- mutation
    def add_live(self, owner: str, nbytes: float) -> None:
        self.live[owner] = max(self.live.get(owner, 0.0) + nbytes, 0.0)

    def set_live(self, owner: str, nbytes: float) -> None:
        self.live[owner] = max(float(nbytes), 0.0)

    def add_transient(self, owner: str, nbytes: float) -> None:
        self.transient[owner] = max(
            self.transient.get(owner, 0.0) + nbytes, 0.0
        )

    def release_owner(self, owner: str) -> float:
        """Free everything held by ``owner`` (task completed/evicted)."""
        freed = self.live.pop(owner, 0.0) + self.transient.pop(owner, 0.0)
        return freed

    def minor_gc(self) -> float:
        """Reclaim all transient bytes; returns surviving (live) bytes."""
        self.transient.clear()
        return self.live_bytes

    def owner_bytes(self, owner: str) -> float:
        return self.live.get(owner, 0.0) + self.transient.get(owner, 0.0)
