"""Paper-fidelity workloads and experiment harness (paper §VI).

Reproduces the evaluation setup of MURS §VI on the discrete-event executor:

  * cluster: 4 workers × (2 × 8-core Xeon-2670), 64 GB; we simulate one
    executor JVM on its 1/4 input share (workers are homogeneous and jobs are
    embarrassingly parallel across executors, so ratios are preserved);
  * applications (Table II):
      Grep  — 1 stage,  ``filter``                        (constant), no cache
      WC    — 2 stages, ``flatMap & reduceByKey``         (sub-linear write)
      Sort  — 3 stages, ``distinct & sortByKey``          (linear read)
      PR    — N stages, ``groupByKey & map & reduceByKey``(linear) + caching
  * datasets: WC 50 GB / Sort 30 GB (HiBench RandomWriter, 1B unique keys);
    Grep / PR webbase-2001 30 GB;
  * task counts match Table III: WC 1000, PR 1500 (per 5-iteration run).

All byte figures below are per-executor (i.e. dataset/4).
"""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional

from repro.sched import MursConfig
from .service import GcModel, JobSpec, ServiceExecutor, ServiceMetrics
from .tasks import ApiProfile, Phase, make_stage_tasks  # noqa: F401
from .usage_models import UsageModel

__all__ = [
    "GB",
    "APIS",
    "make_grep",
    "make_wc",
    "make_sort",
    "make_pr",
    "run_service",
    "run_batch",
]

GB = 1e9

# ---------------------------------------------------------------- API table
# Rates are buffer-to-input ratios at phase completion (see tasks._slope) and
# include the managed-runtime object-bloat factor (~3× raw bytes — the paper
# motivates exactly this bloat via [3]); garbage_per_byte models the
# young-generation churn of each operator.
APIS: Dict[str, ApiProfile] = {
    # constant: streams records through; tiny fixed working set
    "filter": ApiProfile("filter", UsageModel.CONSTANT, rate=8e6, garbage_per_byte=1.2),
    "map": ApiProfile("map", UsageModel.CONSTANT, rate=8e6, garbage_per_byte=1.5),
    # flatMap produces massive temporaries (paper §VI-B: WC's heap is
    # occupied by flatMap garbage during the write phase)
    "flatMap": ApiProfile("flatMap", UsageModel.CONSTANT, rate=16e6, garbage_per_byte=4.0),
    # sub-linear: aggregating shuffle (reduceByKey); 1B unique keys on the
    # HiBench datasets → substantial but sub-linear aggregation buffer
    "reduceByKey": ApiProfile(
        "reduceByKey", UsageModel.SUB_LINEAR, rate=0.9, garbage_per_byte=2.0
    ),
    "combine": ApiProfile(
        "combine", UsageModel.SUB_LINEAR, rate=0.6, garbage_per_byte=1.5
    ),
    # linear: non-aggregating shuffles hold the whole (bloated) partition
    "sortByKey": ApiProfile(
        "sortByKey", UsageModel.LINEAR, rate=3.0, garbage_per_byte=2.5
    ),
    "distinct": ApiProfile(
        "distinct", UsageModel.LINEAR, rate=2.0, garbage_per_byte=2.0
    ),
    "groupByKey": ApiProfile(
        "groupByKey", UsageModel.LINEAR, rate=3.0, garbage_per_byte=3.0
    ),
}


# ------------------------------------------------------------- applications
def make_grep(job_id: str = "grep", *, input_gb: float = 30.0, submit: float = 0.0) -> JobSpec:
    share = input_gb * GB / 4.0
    tasks = make_stage_tasks(
        job_id,
        0,
        n_tasks=60,
        stage_input_bytes=share,
        phases=[Phase("process", APIS["filter"], 1.0)],
    )
    return JobSpec(job_id, [tasks], submit_time=submit)


def make_wc(job_id: str = "wc", *, input_gb: float = 50.0, submit: float = 0.0) -> JobSpec:
    share = input_gb * GB / 4.0
    # Paper Table III: WC = 1000 tasks total → 125/stage/executor.
    # Stage 0 (map side): flatMap then the reduceByKey map-side combine in
    # the task *write* phase — the paper notes WC's pressure appears in the
    # write phase of the first stage amid flatMap temporaries.
    s0 = make_stage_tasks(
        job_id,
        0,
        n_tasks=125,
        stage_input_bytes=share,
        phases=[
            Phase("process", APIS["flatMap"], 0.5),
            Phase("write", APIS["reduceByKey"], 0.5),
        ],
        skew=0.5,
        # hot keys gather (§III redefinition): aggregation degenerates to
        # linear in ~10% of partitions — the source of WC's rare 710 MB spill
        hot_fraction=0.10,
        hot_api=APIS["groupByKey"],
    )
    # Stage 1 (reduce side): aggregated data is much smaller
    s1 = make_stage_tasks(
        job_id,
        1,
        n_tasks=125,
        stage_input_bytes=share * 0.3,
        phases=[
            Phase("read", APIS["combine"], 0.6),
            Phase("process", APIS["map"], 0.4),
        ],
        skew=0.5,
    )
    return JobSpec(job_id, [s0, s1], submit_time=submit)


def make_sort(job_id: str = "sort", *, input_gb: float = 30.0, submit: float = 0.0) -> JobSpec:
    share = input_gb * GB / 4.0
    s0 = make_stage_tasks(
        job_id, 0, n_tasks=60, stage_input_bytes=share,
        phases=[
            Phase("process", APIS["map"], 0.4),
            Phase("write", APIS["distinct"], 0.6),
        ],
        skew=0.3,
    )
    s1 = make_stage_tasks(
        job_id, 1, n_tasks=60, stage_input_bytes=share * 0.9,
        phases=[
            Phase("read", APIS["distinct"], 0.5),
            Phase("write", APIS["sortByKey"], 0.5),
        ],
        skew=0.3,
    )
    # Final sort stage: the linear read-phase buffer the paper highlights
    s2 = make_stage_tasks(
        job_id, 2, n_tasks=60, stage_input_bytes=share * 0.9,
        phases=[
            Phase("read", APIS["sortByKey"], 0.8),
            Phase("process", APIS["map"], 0.2),
        ],
        skew=0.3,
    )
    return JobSpec(job_id, [s0, s1, s2], submit_time=submit)


def make_pr(
    job_id: str = "pr",
    *,
    input_gb: float = 30.0,
    iterations: int = 5,
    submit: float = 0.0,
    cache_factor: float = 0.7,
) -> JobSpec:
    """PageRank: groupByKey links stage (cached), then N rank iterations.

    The link structure is cached in memory after the first stage and lives
    as long as the job (paper §VI-C) — this is the job-lifetime pressure
    source that pushes Spark into OME at ≤17 GB heaps.  Paper Table III:
    PR = 1500 tasks total over 6 stages → ~62/stage/executor.
    """
    share = input_gb * GB / 4.0
    n_tasks_per_stage = 1500 // (iterations + 1) // 4
    stages: List[List] = []
    # Stage 0: build + cache adjacency lists (groupByKey, linear) —
    # cache_on_complete materializes the job-lifetime cached RDD.
    stages.append(
        make_stage_tasks(
            job_id, 0, n_tasks=n_tasks_per_stage, stage_input_bytes=share,
            phases=[
                Phase("read", APIS["groupByKey"], 0.7),
                Phase("process", APIS["map"], 0.3),
            ],
            cache_total_bytes=share * cache_factor,
            skew=0.5,
        )
    )
    for it in range(1, iterations + 1):
        stages.append(
            make_stage_tasks(
                job_id, it, n_tasks=n_tasks_per_stage,
                stage_input_bytes=share * 0.6,
                phases=[
                    Phase("read", APIS["groupByKey"], 0.5),
                    Phase("process", APIS["map"], 0.2),
                    Phase("write", APIS["reduceByKey"], 0.3),
                ],
                # per-iteration rank RDD replaces the previous one; model the
                # steady-state increment as a small additional cache
                cache_total_bytes=share * 0.05,
                skew=0.5,
            )
        )
    return JobSpec(job_id, stages, submit_time=submit)


# --------------------------------------------------------------- experiment
def run_service(
    jobs: List[JobSpec],
    *,
    heap_gb: float,
    murs: Optional[MursConfig] = None,
    policy=None,
    cores: int = 16,
    dt: float = 0.05,
    gc: Optional[GcModel] = None,
    oom_is_fatal: bool = True,
) -> ServiceMetrics:
    """Run jobs concurrently in one shared context (service mode).

    ``policy`` takes any :class:`repro.sched.SchedulingPolicy`; ``murs``
    (a config, or None for FAIR) is the legacy convenience spelling.
    """
    ex = ServiceExecutor(
        cores=cores,
        heap_bytes=heap_gb * GB,
        murs=murs,
        policy=policy,
        dt=dt,
        gc=gc or GcModel(),
        oom_is_fatal=oom_is_fatal,
    )
    for j in jobs:
        ex.submit(j)
    return ex.run()


def run_batch(
    jobs: List[JobSpec],
    *,
    heap_gb: float,
    cores: int = 16,
    dt: float = 0.05,
    gc: Optional[GcModel] = None,
) -> Dict[str, ServiceMetrics]:
    """Run jobs one-after-another, each in a fresh executor (batch mode)."""
    out: Dict[str, ServiceMetrics] = {}
    for j in jobs:
        ex = ServiceExecutor(
            cores=cores, heap_bytes=heap_gb * GB, murs=None, dt=dt,
            gc=gc or GcModel(),
        )
        ex.submit(replace(j, submit_time=0.0))
        out[j.job_id] = ex.run()
    return out
