"""Task abstraction: read / process / write phases built from function APIs.

A task is implemented by at least one function API (paper §III-B).  The read
and write phases hold at most one *shuffle* API whose buffer is long-living;
process-phase APIs are constant-model (streaming) unless they cache, in which
case the model is redefined.  The live-memory growth of a task at any instant
is governed by its *current* phase's model — which is exactly what the
Sampler observes and the scheduler acts on.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from .usage_models import UsageModel, live_bytes_at

__all__ = ["ApiProfile", "Phase", "TaskSpec", "TaskState"]


@dataclass(frozen=True)
class ApiProfile:
    """Memory behaviour of one function API (e.g. ``groupByKey``)."""

    name: str
    model: UsageModel
    #: live-byte slope: bytes of long-living buffer per byte of input
    rate: float
    #: transient garbage produced per byte of input (young-gen pressure)
    garbage_per_byte: float = 1.0
    #: whether results are cached in memory (job-lifetime objects)
    caches: bool = False


@dataclass(frozen=True)
class Phase:
    """One phase of a task; ``span`` is the fraction of input it covers."""

    kind: str  # "read" | "process" | "write"
    api: ApiProfile
    span: float  # fraction of the task's input processed in this phase


@dataclass(frozen=True)
class TaskSpec:
    """Immutable description of a schedulable task."""

    task_id: str
    job_id: str
    stage: int
    input_bytes: float
    phases: List[Phase]
    #: bytes cached into job-lifetime memory when this task completes
    cache_on_complete: float = 0.0
    #: data-skew multiplier on buffer growth (hot keys, paper §VI-E)
    rate_multiplier: float = 1.0

    def __post_init__(self) -> None:
        total = sum(p.span for p in self.phases)
        if abs(total - 1.0) > 1e-6:
            raise ValueError(f"phase spans must sum to 1, got {total}")


@dataclass
class TaskState:
    """Mutable runtime state of a task inside the service executor."""

    spec: TaskSpec
    processed: float = 0.0  # input bytes consumed so far
    live: float = 0.0  # current long-living buffer bytes
    suspended: bool = False
    done: bool = False
    spills: int = 0
    spilled_bytes: float = 0.0
    spill_block_until: float = -1.0  # sim-time until which task is writing
    #: live bytes at the instant the current phase started (buffers from a
    #: finished read phase are handed to the next phase / released)
    _phase_base: float = 0.0
    _phase_idx: int = 0
    _phase_processed: float = 0.0

    @property
    def progress(self) -> float:
        if self.spec.input_bytes <= 0:
            return 1.0
        return min(self.processed / self.spec.input_bytes, 1.0)

    @property
    def current_phase(self) -> Optional[Phase]:
        if self._phase_idx < len(self.spec.phases):
            return self.spec.phases[self._phase_idx]
        return None

    def advance(self, nbytes: float) -> float:
        """Process ``nbytes`` more input; returns transient garbage produced.

        Live-buffer growth follows the current phase's usage model applied to
        bytes processed *within the phase* (models are independent with a
        strict order, paper §III-B).
        """
        garbage = 0.0
        remaining = nbytes
        while remaining > 1e-12 and not self.done:
            phase = self.current_phase
            if phase is None:
                self.done = True
                break
            phase_total = phase.span * self.spec.input_bytes
            take = min(remaining, max(phase_total - self._phase_processed, 0.0))
            self._phase_processed += take
            self.processed += take
            remaining -= take
            garbage += take * phase.api.garbage_per_byte
            self.live = self._phase_base + self.spec.rate_multiplier * live_bytes_at(
                phase.api.model,
                self._phase_processed,
                _slope(phase, phase_total),
            )
            if self._phase_processed >= phase_total * (1.0 - 1e-12):
                # Phase boundary: the shuffle buffer of a read phase is
                # consumed by the next phase; write-phase buffers persist
                # until task completion (then become dead-until-full-GC).
                self._phase_idx += 1
                self._phase_processed = 0.0
                self._phase_base = self.live if phase.kind != "read" else 0.0
                if phase.kind == "read":
                    self.live = self._phase_base
            if self.processed >= self.spec.input_bytes * (1.0 - 1e-12) or (
                self.current_phase is None
            ):
                self.done = True
        return garbage

    def spill(self, spillable_fraction: float = 0.6) -> float:
        """Spill the spillable part of the buffer to disk; returns bytes.

        The unspillable remainder models in-flight objects (a hot key's
        collection being materialized cannot be cut mid-record — the error
        source the paper discusses in §VI-E).
        """
        written = self.live * spillable_fraction
        self.spilled_bytes += written
        self.spills += 1
        self.live -= written
        self._phase_base = min(self._phase_base, self.live)
        # growth restarts from the retained remainder within the phase
        self._phase_processed = 0.0
        return written


def _slope(phase: Phase, phase_total: float) -> float:
    """Anchor the model curve so ``live(end) = rate × phase_input``.

    ``ApiProfile.rate`` is thereby interpreted uniformly across models as the
    buffer-to-input ratio at phase completion: a ``groupByKey`` that holds the
    whole partition has rate 1.0 whatever the curve shape; only the *path*
    (and hence the sampled memory usage rate / slope seen by MURS) differs
    between sub-linear, linear and super-linear.
    """
    from .usage_models import MODEL_EXPONENT

    api = phase.api
    if api.model is UsageModel.CONSTANT:
        return api.rate  # fixed working set in bytes (absolute)
    b = MODEL_EXPONENT[api.model]
    if phase_total <= 0.0:
        return 0.0
    return api.rate * phase_total / (phase_total**b)


def make_stage_tasks(
    job_id: str,
    stage: int,
    *,
    n_tasks: int,
    stage_input_bytes: float,
    phases: List[Phase],
    cache_total_bytes: float = 0.0,
    skew: float = 0.0,
    hot_fraction: float = 0.0,
    hot_api: Optional[ApiProfile] = None,
) -> List[TaskSpec]:
    """Split a stage's input evenly into ``n_tasks`` task specs.

    ``skew`` ∈ [0, 1] adds a deterministic heavy-tailed multiplier on buffer
    growth per task (hot keys): multiplier = (1-skew) + 4·skew·h³ with h a
    per-task hash in [0, 1) — a few tasks grow up to ~4×, most grow less.

    ``hot_fraction`` > 0 applies the paper's §III model *redefinition*: in a
    fraction of tasks the key distribution is not random (hot keys gather),
    so a sub-linear aggregating API degenerates — those tasks get their
    non-constant phases replaced by ``hot_api`` (typically a linear profile).
    """
    import hashlib

    per_task = stage_input_bytes / max(n_tasks, 1)
    cache_per_task = cache_total_bytes / max(n_tasks, 1)
    out = []
    for i in range(n_tasks):
        tid = f"{job_id}/s{stage}/t{i}"
        h = int(hashlib.md5(tid.encode()).hexdigest()[:8], 16) / 0xFFFFFFFF
        mult = (1.0 - skew) + 4.0 * skew * h**3 if skew > 0.0 else 1.0
        task_phases = phases
        if hot_api is not None and hot_fraction > 0.0 and h > 1.0 - hot_fraction:
            task_phases = [
                Phase(p.kind, hot_api, p.span)
                if p.api.model is not UsageModel.CONSTANT
                else p
                for p in phases
            ]
        out.append(
            TaskSpec(
                task_id=tid,
                job_id=job_id,
                stage=stage,
                input_bytes=per_task,
                phases=task_phases,
                cache_on_complete=cache_per_task,
                rate_multiplier=mult,
            )
        )
    return out
