"""MURS — the Memory-Usage-Rate based Scheduler (paper §IV, Algorithm 1).

Decision procedure, invoked periodically ("seasonally") with fresh Sampler
stats and the pool state:

    usage < yellow                     → no action (and: resume ALL suspended
                                         tasks once usage drops below yellow
                                         after a full GC)
    yellow ≤ usage < red, SQ empty     → ComputeSuspendTasks: keep the
                                         lowest-rate tasks whose projected
                                         remaining need Σ c·(1−done%) fits the
                                         free pool, suspend the rest (the
                                         heavy tasks) into a FIFO queue
    yellow ≤ usage < red, SQ non-empty → no action (pressure already handled)
    usage ≥ red                        → emergency: ComputeSuspendTasks against
                                         the shrunken free pool (queue gate
                                         ignored) plus ComputeSpill — suspend
                                         every task whose actual (c > M/N) or
                                         projected (c/done% > M/N) consumption
                                         exceeds its fair share, cutting the
                                         degree of parallelism before
                                         spill / OOM

On every task completion one suspended task is resumed (FIFO — avoids
starvation, paper §VI-D); dropping below yellow resumes all.

The published pseudocode has two OCR-garbled lines (its line 21 pushes the
*kept* min-rate task into SQ; its branch order tests red before yellow);
we follow the unambiguous prose of §IV: the *returned* heavy tasks are the
ones suspended and queued, and ComputeSuspendTasks runs in the yellow band
while ComputeSpill guards the red band.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

from .protocol import BasePolicy, SchedulingDecision

if TYPE_CHECKING:
    from repro.core.memory_manager import MemoryPool
    from repro.core.sampler import TaskStats

__all__ = ["MursConfig", "MursPolicy"]

#: architecture memory classes whose byte demand does NOT grow with
#: context length (``configs.MEMORY_CLASSES`` subset): a mamba2 tenant's
#: state is the same size at token 1 and token 10k, so its usage RATE is
#: structurally ~zero no matter what the online EMA momentarily reads
FLAT_CLASSES = ("constant_state", "zero_kv")


@dataclass(frozen=True)
class MursConfig:
    """Thresholds and knobs of MURS (defaults from the paper: 0.4 / 0.8)."""

    yellow: float = 0.4
    red: float = 0.8
    #: sampler/scheduler period in (sim or wall) seconds
    period: float = 1.0
    #: never suspend below this many running tasks (keep the service live)
    min_running: int = 1
    #: the collector's full-GC initiating occupancy.  Heap above this line
    #: is not usable without incurring full collections, so the scheduler's
    #: working notion of "free memory" is the headroom below it:
    #: free = trigger×capacity − live.  Set to None to use the raw
    #: JM.freeMemory reading of the paper's pseudocode (heap − used).
    collector_trigger: Optional[float] = 0.65
    #: a freshly resumed task cannot be re-suspended for this many seconds —
    #: prevents the suspend/resume oscillation around the yellow threshold
    resume_immunity: float = 5.0
    #: execution-memory share of the pool that the memory manager actually
    #: grants to tasks — the fair share M/N of ComputeSpill is M_exec/N, the
    #: same limit the environment spills at (anything larger never fires).
    #: Held slightly below the environment's grant (0.6) as a safety margin
    #: so kept tasks finish without ever hitting the per-task cap.
    exec_fraction: float = 0.55
    #: the inline per-task fair-share check (paper line 17) models Spark's
    #: M/N execution-memory grant: a task projected past its grant WILL
    #: spill, so it is suspended pre-emptively.  Pools without per-task
    #: grants (an HBM KV pool) should turn this off — page-quantized
    #: consumption makes c/done% overshoot and the guard then suspends
    #: every request at once.
    fair_share_guard: bool = True

    def __post_init__(self) -> None:
        if not (0.0 < self.yellow <= self.red <= 1.0):
            raise ValueError(
                f"need 0 < yellow <= red <= 1, got {self.yellow}, {self.red}"
            )

    @classmethod
    def for_serving(cls, **overrides) -> "MursConfig":
        """Thresholds retuned for a serving HBM pool.

        The JVM-specific machinery is disabled: there is no full-GC
        occupancy line (``collector_trigger``), no per-task execution-
        memory grant (``fair_share_guard``), and the scheduler may plan
        against nearly the whole pool (``exec_fraction`` ≈ 1) because
        nothing else shares it.
        """
        base = dict(
            exec_fraction=0.95, collector_trigger=None, fair_share_guard=False
        )
        base.update(overrides)
        return cls(**base)


class MursPolicy(BasePolicy):
    """Algorithm 1 with FIFO suspension queue and resume rules.

    Placement (``assign``) stays round-robin — MURS changes which tasks
    RUN under pressure, not how free cores rotate across tenants.
    """

    name = "murs"
    proactive = True

    def __init__(self, config: Optional[MursConfig] = None) -> None:
        super().__init__()
        self.config = config or MursConfig()
        self.period = self.config.period
        # never admit new work into a red pool — it would be suspended on
        # the very next pass (and gate its whole tenant); queue it instead
        self.admission_headroom = self.config.red
        self._resumed_at: Dict[str, float] = {}
        self._now: float = 0.0
        #: per-group (tenant/job) memory-usage-rate EMA — the sampler's §III
        #: rate aggregated per tenant, feeding the cache_pressure hint.
        #: Entries for groups not observed within ``_group_rate_horizon``
        #: seasonal periods are pruned: a long-lived service with churning
        #: tenant ids must not grow this dict without bound, and a departed
        #: burst tenant's stale maximum must not compress every live
        #: tenant's pressure score toward uniform.
        self._group_rate: Dict[str, float] = {}
        self._group_seen: Dict[str, float] = {}
        #: per-group DECLARED architecture memory class (note_group_class)
        #: — the static prior the online EMA is read through: a group of
        #: FLAT_CLASSES never counts as high-rate, whatever its EMA says
        self._group_class: Dict[str, str] = {}
        self._group_rate_horizon: float = 50.0 * max(
            self.period, self.config.resume_immunity
        )

    def _immune(self, task_id: str) -> bool:
        t0 = self._resumed_at.get(task_id)
        return t0 is not None and (self._now - t0) < self.config.resume_immunity

    # ------------------------------------------------------------- main loop
    def propose(
        self,
        pool: "MemoryPool",
        running: Sequence["TaskStats"],
        now: float = 0.0,
        suspended: Sequence["TaskStats"] = (),
    ) -> SchedulingDecision:
        """One "seasonal" scheduling pass (paper Algorithm 1).

        Yellow band: classify by rate and suspend the heavy tail (once —
        gated on an empty suspension queue, paper line 7).  Red band: the
        emergency path — ComputeSuspendTasks against the (now tiny) free
        pool *plus* the ComputeSpill fair-share guard, regardless of the
        queue gate, because red means spill/OOM is imminent.
        """
        cfg = self.config
        self._now = now
        # Expired immunity stamps are dead weight in a long-lived service —
        # prune them here so the dict is bounded by the active task set.
        expired = [
            t
            for t, t0 in self._resumed_at.items()
            if (now - t0) >= cfg.resume_immunity
        ]
        for t in expired:
            del self._resumed_at[t]
        for t in running:
            if t.group:
                self.note_group_rate(t.group, t.rate, now)
        usage = pool.live_fraction

        if usage < cfg.yellow:
            # Pressure receded: resume everything still suspended.
            if self._suspended:
                resumed = list(self._suspended)
                self._suspended.clear()
                for tid in resumed:
                    self._resumed_at[tid] = now
                return SchedulingDecision(resume=resumed, reason="below-yellow")
            return SchedulingDecision(reason="light")

        if usage >= cfg.red:
            d1 = self._compute_suspend_tasks(pool, running)
            still = [t for t in running if t.task_id not in set(d1.suspend)]
            d2 = self._compute_spill(pool, still, suspended)
            return SchedulingDecision(
                suspend=d1.suspend + d2.suspend,
                reason="red-emergency" if (d1.suspend or d2.suspend) else "red-fits",
            )

        # Spill-avoidance: if the execution pool is close to exhaustion the
        # memory manager is about to deny allocations (spill), regardless of
        # total-heap occupancy — run the ComputeSpill guard now.
        exec_pool = cfg.exec_fraction * pool.capacity
        frozen = sum(t.consumption for t in suspended)
        projected = sum(t.consumption + t.rate * t.remaining_bytes for t in running)
        if frozen + projected >= 0.9 * exec_pool:
            d = self._compute_spill(pool, running, suspended)
            if d.suspend:
                return d

        if self._suspended:
            # Yellow band but pressure already being handled.
            return SchedulingDecision(reason="already-suspended")

        return self._compute_suspend_tasks(pool, running)

    # --------------------------------------------------- ComputeSuspendTasks
    def _compute_suspend_tasks(
        self, pool: "MemoryPool", running: Sequence["TaskStats"]
    ) -> SchedulingDecision:
        """Keep lowest-rate tasks that fit free memory; suspend the rest."""
        cfg = self.config
        if cfg.collector_trigger is not None:
            free = max(
                cfg.collector_trigger * pool.capacity - pool.live_bytes, 0.0
            )
            free = min(free, pool.free_bytes)
        else:
            free = pool.free_bytes
        fair_share = self._fair_share(pool, running)

        # Order by projected FUTURE growth (rate × remaining input): keeping
        # low-future-growth tasks lets them finish cheaply, while suspending
        # high-future-growth tasks freezes only their (typically still small)
        # current buffer and saves all of their remaining growth.  Ties —
        # in particular the zero-information passes before the sampler has
        # rate estimates — break on the §III-B projected remaining need, so
        # a nearly-done task is never suspended ahead of a fresh heavy one.
        by_growth = sorted(
            running,
            key=lambda t: (
                t.rate * t.remaining_bytes,
                t.rate,
                t.memory_necessary,
                t.task_id,
            ),
        )
        kept: List["TaskStats"] = []
        suspend: List["TaskStats"] = []
        for t in by_growth:
            if len(kept) < cfg.min_running or self._immune(t.task_id):
                kept.append(t)
                free -= t.memory_necessary
                continue
            # Inline spill guard (paper line 17): a task that would exceed its
            # fair share cannot be saved by suspending others — reduce the
            # degree of parallelism by suspending it instead.
            if cfg.fair_share_guard and self._violates_fair_share(t, fair_share):
                suspend.append(t)
                continue
            need = t.memory_necessary
            if free - need > 0.0:
                free -= need
                kept.append(t)
            else:
                suspend.append(t)

        # Suspend heaviest-first ordering for the FIFO queue: tasks were
        # examined in ascending rate, so `suspend` is already ascending;
        # queue them ascending so that the FIFO resume brings back the
        # lightest suspended task first.
        ids = [t.task_id for t in suspend]
        self._suspended.extend(ids)
        return SchedulingDecision(
            suspend=ids,
            reason="yellow-suspend" if ids else "yellow-fits",
        )

    # ---------------------------------------------------------- ComputeSpill
    def _compute_spill(
        self,
        pool: "MemoryPool",
        running: Sequence["TaskStats"],
        suspended: Sequence["TaskStats"] = (),
    ) -> SchedulingDecision:
        """Spill-avoidance: reduce parallelism until the projected total
        consumption of the kept tasks — plus the frozen buffers of already
        suspended tasks, which stay resident — fits the execution pool, so
        the memory manager never has to deny an allocation (paper: "ensures
        that the running tasks can complete with the remaining memory
        space")."""
        cfg = self.config
        budget = cfg.exec_fraction * pool.capacity
        budget -= sum(t.consumption for t in suspended)
        by_growth = sorted(
            running,
            key=lambda t: (
                t.rate * t.remaining_bytes,
                t.rate,
                t.memory_necessary,
                t.task_id,
            ),
        )
        suspend: List[str] = []
        kept = 0
        for t in by_growth:
            projected = t.consumption + t.rate * t.remaining_bytes
            if kept < cfg.min_running or self._immune(t.task_id):
                kept += 1
                budget -= projected
                continue
            if budget - projected > 0.0:
                budget -= projected
                kept += 1
            elif t.task_id not in self._suspended:
                suspend.append(t.task_id)
                budget -= t.consumption  # its buffer stays frozen in the pool
        self._suspended.extend(suspend)
        return SchedulingDecision(
            suspend=suspend, reason="spill-avoidance" if suspend else "spill-fits"
        )

    def _fair_share(
        self, pool: "MemoryPool", running: Sequence["TaskStats"]
    ) -> float:
        n = max(len(running), 1)
        return self.config.exec_fraction * pool.capacity / n

    @staticmethod
    def _violates_fair_share(t: "TaskStats", fair_share: float) -> bool:
        if t.consumption > fair_share:
            return True
        return t.progress > 1e-9 and t.projected_total > fair_share

    # -------------------------------------------------------- group rate EMA
    def note_group_rate(self, group: str, rate: float, now: float = 0.0) -> None:
        """One usage-rate observation for ``group`` (EMA, horizon-pruned).
        Fed by :meth:`propose` for a replica-local policy, and by a
        ``ServingCluster`` forwarding replica-level EMAs into its router
        — the router never runs ``propose`` itself."""
        prev = self._group_rate.get(group)
        self._group_rate[group] = (
            rate if prev is None else 0.8 * prev + 0.2 * rate
        )
        self._group_seen[group] = now
        for g in [
            g
            for g, seen in self._group_seen.items()
            if (now - seen) > self._group_rate_horizon
        ]:
            del self._group_seen[g]
            del self._group_rate[g]

    def group_rates(self) -> Dict[str, float]:
        return dict(self._group_rate)

    # ------------------------------------------------------ memory classes
    def note_group_class(self, group: str, memory_class: str) -> None:
        """Record the declared architecture class of ``group``'s model —
        the §III function classes generalized to architectures: the
        class is knowable BEFORE any request runs, so every rate-driven
        hook below can clamp a structurally-flat tenant to low-rate even
        while its EMA is still warming up (or momentarily polluted by
        its fixed-state registration burst)."""
        self._group_class[group] = memory_class

    def group_classes(self) -> Dict[str, str]:
        return dict(self._group_class)

    def _flat_group(self, group: str) -> bool:
        """True when the group's declared class cannot grow the pool."""
        return self._group_class.get(group) in FLAT_CLASSES

    def _shed_key(self, group: str, row) -> tuple:
        """Shed the highest-usage-rate group FIRST (paper §III at the
        front door): its admitted traffic grows the pool fastest, so
        rejecting it protects the most SLO traffic per rejected request.
        The EMA is authoritative; before it warms up (cold start, or a
        router that never saw the group) the front door's projected
        in-flight demand stands in — demand-ordered shedding is the
        zero-information approximation of rate-ordered shedding.  Ties
        fall back to group arrival order (FIFO), matching the base."""
        # a structurally flat tenant (mamba / zero-KV) cannot grow the
        # pool: shedding it buys nothing per §III, so it sheds LAST
        if self._flat_group(group):
            rate = 0.0
        else:
            rate = self._group_rate.get(group, row.get("rate", 0.0))
        return (
            -rate,
            -row.get("demand_bytes", 0.0),
            row.get("arrival_seq", 0.0),
        )

    # ------------------------------------------------------ cluster placement
    def placement_score(self, group: str, replica_stats) -> float:
        """Pressure- and rate-aware routing (paper §III applied ACROSS
        replicas): the score is the negated replica load, where "load"
        is read through the group's usage-rate class.

        A HIGH-rate tenant's requests grow the pool fastest, so for them
        load is the replica's byte DEMAND (its next thousand tokens need
        page headroom — placing it on a nearly-full replica buys
        suspensions and spills).  A LOW/constant-rate tenant barely
        touches the pool; its latency is gated by batch slots, so for it
        load is the replica's SLOT occupancy.  The per-group usage-rate
        EMA (the same one behind ``cache_pressure``) blends the two —
        unseen groups sit in the middle.  Equal-load replicas tie and
        fall back to the router's round-robin cursor.
        """
        rate_norm = 1.0 - self._inverse_rate_score(group)  # high rate → 1
        # committed-peak demand when the replica reports it: materialized
        # bytes alone lag a just-placed heavy request by its whole decode
        demand = max(
            float(replica_stats.get("demand_fraction", 0.0)),
            float(replica_stats.get("projected_fraction", 0.0)),
        )
        slots = float(replica_stats.get("slot_load", 0.0))
        return -(rate_norm * demand + (1.0 - rate_norm) * slots)

    # ------------------------------------------------------- elastic scaling
    def scale_pressure(self, replica_stats) -> float:
        """Fleet demand read through the usage-rate lens (paper §III-B
        applied to the whole fleet): the mean, across replicas, of the
        committed-peak byte fraction — ``max(demand, projected)``, the
        same surface ``placement_score`` steers heavy tenants by.  Queued
        work that no replica has admitted yet still needs future pages,
        so a replica with a backlog reports pressure ≥ its slot load
        even while its pool is momentarily empty.  FAIR scales on slot
        occupancy; MURS scales on where the bytes are going.

        A replica that DECLARES a flat memory class (constant-state /
        zero-KV model) contributes its slot occupancy alone: its byte
        fractions are bounded by construction — its bytes never grow
        with context, so scaling it is a throughput decision, not a
        memory-pressure one.
        """
        if not replica_stats:
            return 0.0
        total = 0.0
        for s in replica_stats:
            slots = min(float(s.get("slot_load", 0.0)), 2.0) / 2.0
            if str(s.get("memory_class", "")) in FLAT_CLASSES:
                total += slots
                continue
            bytes_frac = max(
                float(s.get("demand_fraction", 0.0)),
                float(s.get("projected_fraction", 0.0)),
            )
            total += max(bytes_frac, slots)
        return min(total / len(replica_stats), 1.0)

    # ----------------------------------------------------------- cache hint
    def _inverse_rate_score(self, group: str) -> float:
        """1 − rate/top over the per-group usage-rate EMA, in [0, 1]:
        LOW-rate tenants score HIGH.  Unseen groups sit in the middle
        (0.5) so the hint never starves LRU / size tie-breaks.  A group
        DECLARED flat (constant-state / zero-KV architecture) pins to
        1.0: its demand cannot grow, so it is definitively low-rate —
        its placement reads slot occupancy, its frozen state demotes
        first, and its (empty) prefix cache never shields anything."""
        if self._flat_group(group):
            return 1.0
        rate = self._group_rate.get(group)
        if rate is None or not self._group_rate:
            return 0.5
        top = max(self._group_rate.values())
        if top <= 0.0:
            return 0.5
        return 1.0 - min(rate / top, 1.0)

    def _frozen_score(self, group: str) -> float:
        """How eagerly ``group``'s FROZEN KV demotes to the host tier,
        in [0, 1] — the usage-rate classes of §III applied to tier
        placement.  A low-rate tenant's suspended pages sit frozen the
        longest (its requests resume into slow growth), so parking them
        in host memory costs the least and frees HBM for the heavy
        tenants' growth — demoting proactively, page by page, is what
        keeps the reactive spill path (and the disk tier behind it) from
        ever firing.  Every tenant scores > 0 under MURS: frozen KV is
        by definition demotable, the hint only orders who goes first.
        """
        return max(self._inverse_rate_score(group), 0.1)

    @staticmethod
    def _scratch_score(group: str) -> float:
        """SCRATCH is free to regenerate by definition: every group's
        scratch pages are equally first out the door."""
        return 1.0

    def pressure(self, view=None):
        """MURS's :class:`~repro.serve.ledger.PressurePlan` — §III as
        class orders and usage-rate scores.

        The class orders keep the stock shape (reclaim SCRATCH, then
        COLD_CACHED, then FROZEN; proactively demote FROZEN before
        COLD_CACHED), so cold cache always evicts before frozen state is
        touched *by construction*.  The scores are the usage-rate lens:
        ``COLD_CACHED`` evicts LOW-rate tenants' prefixes first (cheap to
        regrow, shield little future allocation — a high-rate tenant's
        cached prefix spares the pool the most growth and is kept
        longest), ``FROZEN`` demotes low-rate tenants' suspended KV
        first, and the shed key rejects the highest-rate group's
        arrivals first."""
        from repro.serve.ledger import PageClass, PressurePlan

        return PressurePlan(
            scores={
                PageClass.SCRATCH: self._scratch_score,
                PageClass.COLD_CACHED: self._inverse_rate_score,
                PageClass.FROZEN: self._frozen_score,
            },
            shed_key=self._shed_key,
        )

    # ------------------------------------------------------------ resume API
    def on_task_complete(self, task_id: Optional[str] = None) -> Optional[str]:
        """A running task finished: resume the first suspended task (FIFO).

        The finished task's immunity stamp is purged — without this the
        ``_resumed_at`` dict grows without bound in a long-lived service
        (every task that was ever resumed stays in it forever).
        """
        if task_id is not None:
            self._resumed_at.pop(task_id, None)
        if self._suspended:
            tid = self._suspended.pop(0)
            self._resumed_at[tid] = self._now
            return tid
        return None

    def on_full_gc(self, pool: "MemoryPool") -> List[str]:
        """After a full GC, resume all if usage dropped below yellow."""
        if pool.live_fraction < self.config.yellow and self._suspended:
            resumed = list(self._suspended)
            self._suspended.clear()
            for tid in resumed:
                self._resumed_at[tid] = self._now
            return resumed
        return []

    def drop(self, task_id: str) -> None:
        """Remove a task from every policy structure (job cancelled)."""
        super().drop(task_id)
        self._resumed_at.pop(task_id, None)
