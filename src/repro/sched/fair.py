"""FAIR — the stock baseline policy (Spark fair scheduler pool / naive
serving admission).

Round-robin core handout across tenants (inherited from
:class:`BasePolicy`), no pressure response: ``propose`` never suspends
and ``admission_headroom`` is 1.0, so the runtimes apply stock semantics
— admit until the pool is full, then resolve overcommit reactively
(spill / offload-to-host, or OOM-style hard failure when no spill path
exists).  The ``pressure()`` plan stays at the BasePolicy stock: every
per-class score is 0.0 for every tenant, so prefix-cache eviction order
is pure LRU and frozen KV is never demoted proactively — reactive-only
tiering is exactly what "stock" means.  Likewise ``placement_score``
stays at the base 0.0 for every replica, so cross-replica routing under
FAIR is the router's round-robin tie-break: pressure-oblivious request
spraying, the multi-server stock baseline.  The plan's shed key is
likewise the inherited FIFO-over-groups order: under admission overload
the earliest-arrived tenant sheds first, with no regard for who is
actually filling the pool — the failure mode the usage-rate order is
measured against.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Sequence

from .protocol import BasePolicy, SchedulingDecision

if TYPE_CHECKING:
    from repro.core.memory_manager import MemoryPool
    from repro.core.sampler import TaskStats

__all__ = ["FairPolicy"]


class FairPolicy(BasePolicy):
    """Pressure-oblivious round-robin: the paper's comparison baseline."""

    name = "fair"
    proactive = False

    def __init__(self, period: float = 1.0) -> None:
        super().__init__()
        self.period = period

    def propose(
        self,
        pool: "MemoryPool",
        running: Sequence["TaskStats"],
        now: float = 0.0,
        suspended: Sequence["TaskStats"] = (),
    ) -> SchedulingDecision:
        return SchedulingDecision(reason="fair")
