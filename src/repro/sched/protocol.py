"""The :class:`SchedulingPolicy` protocol and shared policy machinery.

A policy owns four runtime hooks (the minimal surface both runtimes call):

    propose(pool, running, now, suspended) → SchedulingDecision
        the "seasonal" pass: given fresh sampler stats and the pool state,
        decide which tasks to suspend / resume this period.
    on_task_complete(task_id) → resumed task id or None
        a running task finished; the policy may resume one suspended task
        (MURS: FIFO, starvation-free) and must forget per-task state it
        holds for the finished task.
    on_full_gc(pool) → resumed task ids
        the collector just ran; resume if pressure receded.
    drop(task_id)
        the task's job was cancelled — purge it from every policy structure.

plus two placement hooks:

    assign(free, pending) → group ids to launch from, one per free core
        how free execution slots are offered to tenants/jobs.  FAIR's
        round-robin cursor lives HERE now, not inlined in the executor.

    shed_order(groups, stats) → groups, first-shed first
        admission-time load shedding under overload (the serving front
        door's hook): MURS sheds the highest-usage-rate group first
        (paper §III — its traffic costs the pool the most future
        allocation), PriorityPolicy sheds by inverse weight, and the
        base/fair order is FIFO over group arrival.  Implemented as a
        thin wrapper over ``pressure().shed_key`` — subclasses customize
        the plan, not this method.

    placement_score(group, replica_stats) → preference for placing the
        group's next request on the replica described by ``replica_stats``
        (a ``ServingCluster`` routing decision — the same usage-rate
        classes of paper §III applied ACROSS replicas).  Higher = better;
        the router breaks exact ties round-robin, so the base default of
        0.0 for every replica IS round-robin (FAIR).  MURS scores by
        negated demand, scaled up for high-usage-rate groups (a heavy
        tenant is steered harder toward the emptiest replica — its
        placement mistake costs the most future allocation);
        PriorityPolicy scales the same aversion by tenant weight.

    scale_pressure(replica_stats) → fleet-level demand in [0, 1], the
        signal the cluster's elastic autoscaler thresholds (DESIGN.md
        §11).  The base/fair reading is mean slot occupancy; MURS reads
        the projected usage-rate surface instead — the fleet is "full"
        when its admitted requests will grow into the pool, not merely
        when its batch rows are busy.

and ONE memory-pressure surface:

    pressure(view: LedgerView) → PressurePlan
        the policy's complete answer to "memory is tight — what goes
        first?", replacing the three historical hooks (``cache_pressure``,
        ``demotion_pressure``, ``shed_order``) with one plan built from
        the class-stamped ledger view: per-:class:`PageClass` reclaim and
        proactive-demotion orders plus per-class group-scoring callables
        and a front-door shed key.  The stock plan evicts ``SCRATCH``,
        then ``COLD_CACHED``, and only then demotes ``FROZEN`` — so MURS
        evicts cold cache before touching frozen state *by construction*.
        The base scores are 0.0 for every group (pure LRU eviction,
        never-proactive demotion — the stock baseline only pays reactive
        spills); MURS scores by inverse usage rate (a LOW-rate tenant's
        prefixes regrow cheaply and its frozen pages are cheapest to park
        in host memory — the paper's ~90% spill reduction is exactly this
        demote-early-by-class behaviour).  ``cache_pressure(group)`` /
        ``demotion_pressure(group)`` survive as thin wrappers reading the
        plan's ``COLD_CACHED`` / ``FROZEN`` scores.

Runtimes interrogate declarative attributes instead of branching on the
policy's type: ``proactive`` (True → the policy prevents overcommit via
admission control + suspension; False → stock reactive semantics),
``admission_headroom`` (the pool fraction the policy will fill before
gating new admissions — 1.0 for the stock baseline, the red line for
MURS), and ``period`` (seconds between seasonal passes).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import (
    TYPE_CHECKING,
    List,
    Mapping,
    Optional,
    Protocol,
    Sequence,
    runtime_checkable,
)

if TYPE_CHECKING:  # annotation-only: keeps repro.sched import-cycle free
    from repro.core.memory_manager import MemoryPool
    from repro.core.sampler import TaskStats
    from repro.serve.ledger import LedgerView, PressurePlan

__all__ = ["SchedulingDecision", "SchedulingPolicy", "BasePolicy"]


@dataclass
class SchedulingDecision:
    """Output of one policy invocation."""

    suspend: List[str] = field(default_factory=list)
    resume: List[str] = field(default_factory=list)
    reason: str = "ok"

    @property
    def is_noop(self) -> bool:
        return not self.suspend and not self.resume


@runtime_checkable
class SchedulingPolicy(Protocol):
    """Structural type every scheduling policy satisfies."""

    name: str
    proactive: bool
    period: float
    admission_headroom: float

    def propose(
        self,
        pool: "MemoryPool",
        running: Sequence["TaskStats"],
        now: float = 0.0,
        suspended: Sequence["TaskStats"] = (),
    ) -> SchedulingDecision: ...

    def on_task_complete(self, task_id: Optional[str] = None) -> Optional[str]: ...

    def on_full_gc(self, pool: "MemoryPool") -> List[str]: ...

    def drop(self, task_id: str) -> None: ...

    def assign(self, free: int, pending: Mapping[str, int]) -> List[str]: ...

    def shed_order(
        self,
        groups: Sequence[str],
        stats: Mapping[str, Mapping[str, float]],
    ) -> List[str]: ...

    def placement_score(
        self, group: str, replica_stats: Mapping[str, float]
    ) -> float: ...

    def scale_pressure(
        self, replica_stats: Sequence[Mapping[str, float]]
    ) -> float: ...

    def note_group_rate(
        self, group: str, rate: float, now: float = 0.0
    ) -> None: ...

    def group_rates(self) -> Mapping[str, float]: ...

    def note_group_class(self, group: str, memory_class: str) -> None: ...

    def group_classes(self) -> Mapping[str, str]: ...

    def pressure(
        self, view: Optional["LedgerView"] = None
    ) -> "PressurePlan": ...

    def cache_pressure(self, group: str) -> float: ...

    def demotion_pressure(self, group: str) -> float: ...

    @property
    def suspended_queue(self) -> Sequence[str]: ...

    @property
    def has_suspended(self) -> bool: ...


class BasePolicy:
    """Default implementations: FIFO suspension queue + round-robin assign.

    The round-robin ``assign`` reproduces Spark's fair-pool core handout
    (and the cursor semantics the simulator previously inlined): the cursor
    persists across calls; draining a group does not advance it, so the
    next group slides into the cursor's slot.
    """

    name = "base"
    proactive = False
    period: float = 1.0
    #: admit new work while pool usage stays below this fraction of
    #: capacity (1.0 = stock: fill to the brim, handle pressure reactively)
    admission_headroom: float = 1.0

    def __init__(self) -> None:
        self._suspended: List[str] = []  # FIFO: index 0 = first suspended
        self._cursor = 0

    # ------------------------------------------------------------ properties
    @property
    def suspended_queue(self) -> Sequence[str]:
        return tuple(self._suspended)

    @property
    def has_suspended(self) -> bool:
        return bool(self._suspended)

    # ----------------------------------------------------------------- hooks
    def propose(
        self,
        pool: "MemoryPool",
        running: Sequence["TaskStats"],
        now: float = 0.0,
        suspended: Sequence["TaskStats"] = (),
    ) -> SchedulingDecision:
        return SchedulingDecision(reason=self.name)

    def on_task_complete(self, task_id: Optional[str] = None) -> Optional[str]:
        if self._suspended:
            return self._suspended.pop(0)
        return None

    def on_full_gc(self, pool: "MemoryPool") -> List[str]:
        return []

    def drop(self, task_id: str) -> None:
        self._suspended = [t for t in self._suspended if t != task_id]

    # ------------------------------------------------------ pressure surface
    @staticmethod
    def _zero_score(group: str) -> float:
        """Stock per-group score: 0.0 for everyone — cold-cache eviction
        falls back to pure LRU and frozen KV never demotes proactively."""
        return 0.0

    @staticmethod
    def _fifo_shed_key(group: str, row: Mapping[str, float]) -> tuple:
        """Stock shed key: earliest-arrived group sheds first (FIFO) —
        rate-oblivious, the baseline the usage-rate order is measured
        against."""
        return (row.get("arrival_seq", 0.0),)

    def pressure(self, view=None) -> "PressurePlan":
        """The one memory-pressure surface: a :class:`PressurePlan` built
        from the class-stamped ledger ``view`` (may be ``None`` when the
        caller has no ledger, e.g. at wiring time).

        The stock plan keeps the default class orders (evict ``SCRATCH``,
        then ``COLD_CACHED``, then demote ``FROZEN``) with zero scores
        everywhere: pure-LRU cache eviction, never-proactive demotion,
        FIFO front-door shedding.  Subclasses override THIS method —
        ``cache_pressure`` / ``demotion_pressure`` / ``shed_order`` below
        are wrappers reading the plan and must not be overridden."""
        from repro.serve.ledger import PageClass, PressurePlan

        return PressurePlan(
            scores={
                PageClass.COLD_CACHED: self._zero_score,
                PageClass.FROZEN: self._zero_score,
            },
            shed_key=self._fifo_shed_key,
        )

    # ------------------------------------------------------------ cache hint
    def cache_pressure(self, group: str) -> float:
        """Evictability of ``group``'s cold cached pages — the plan's
        ``COLD_CACHED`` score (stock: 0.0 for everyone → pure LRU)."""
        from repro.serve.ledger import PageClass

        return self.pressure().score(PageClass.COLD_CACHED, group)

    # --------------------------------------------------------- demotion hint
    def demotion_pressure(self, group: str) -> float:
        """How eagerly ``group``'s frozen KV should demote to the host
        tier ahead of need — the plan's ``FROZEN`` score (stock: 0.0 for
        everyone → only ever the reactive spill path)."""
        from repro.serve.ledger import PageClass

        return self.pressure().score(PageClass.FROZEN, group)

    # ------------------------------------------------------------- placement
    def placement_score(
        self, group: str, replica_stats: Mapping[str, float]
    ) -> float:
        """Cross-replica placement preference: 0.0 for every replica →
        the router's round-robin tie-break decides (the stock baseline
        spreads requests across replicas with no pressure awareness)."""
        return 0.0

    def scale_pressure(
        self, replica_stats: Sequence[Mapping[str, float]]
    ) -> float:
        """Fleet-level demand signal for the cluster's elastic autoscaler,
        in [0, 1]: the fraction of the fleet's capacity the policy
        considers committed.  The scaling controller spawns a replica
        when this stays above its up-threshold and drains one when it
        stays below its down-threshold (see
        ``repro.serve.cluster.ScalingConfig``).

        The base/fair reading is SLOT occupancy — mean ``slot_load``
        across replicas — because a rate-oblivious policy only sees how
        many batch rows are busy or queued for.  MURS overrides this with
        the usage-rate surface (projected byte demand): a fleet whose
        slots are idle but whose admitted requests will grow into the
        pool is already overcommitted in the only currency that matters
        under §III (future allocation), so MURS scales on usage-rate
        while FAIR scales on slot-load.
        """
        if not replica_stats:
            return 0.0
        loads = [min(float(s.get("slot_load", 0.0)), 2.0) for s in replica_stats]
        return min(sum(loads) / len(loads), 1.0)

    def note_group_rate(
        self, group: str, rate: float, now: float = 0.0
    ) -> None:
        """Feed one group-level usage-rate observation into the policy.
        A cluster router never runs ``propose`` (it has no pool), so this
        is how the per-replica rate signal reaches its placement scores;
        the base policy keeps no rate state and ignores it."""

    def group_rates(self) -> Mapping[str, float]:
        """The policy's current per-group usage-rate estimates (empty for
        rate-oblivious policies) — what a cluster forwards from replica
        policies into its router."""
        return {}

    def note_group_class(self, group: str, memory_class: str) -> None:
        """Declare the ARCHITECTURE memory class of ``group``'s model
        (one of ``configs.MEMORY_CLASSES``) — the static generalization
        of the paper's per-API-function classes.  A mamba tenant's byte
        demand is constant no matter how long its requests run; a
        long-context transformer tenant's grows linearly.  The base
        policy is class-oblivious and ignores it."""

    def group_classes(self) -> Mapping[str, str]:
        """Per-group declared memory classes (empty for class-oblivious
        policies) — mirrors :meth:`group_rates` for the cluster's
        forwarding path."""
        return {}

    def shed_order(
        self,
        groups: Sequence[str],
        stats: Mapping[str, Mapping[str, float]],
    ) -> List[str]:
        """Admission-overload shed order: FIRST element is shed first.

        Called by the serving front door when projected demand crosses its
        pressure threshold — new arrivals from the leading groups are
        rejected (503) until the overshoot is covered.  ``stats`` maps each
        group to ``{"rate", "demand_bytes", "arrival_seq"}`` (usage-rate
        estimate, in-flight projected bytes, first-seen order).

        A wrapper over the plan's ``shed_key``: the base/fair key is FIFO
        over group arrival, MURS sheds the highest-usage-rate group first,
        PriorityPolicy by inverse weight.  Override :meth:`pressure`, not
        this.
        """
        key = self.pressure().shed_key
        return sorted(groups, key=lambda g: key(g, stats.get(g, {})))

    def assign(self, free: int, pending: Mapping[str, int]) -> List[str]:
        """Round-robin over groups with pending work; one pick per core."""
        groups = [g for g, n in pending.items() if n > 0]
        remaining = {g: pending[g] for g in groups}
        picks: List[str] = []
        while free > 0 and groups:
            self._cursor %= len(groups)
            g = groups[self._cursor]
            picks.append(g)
            remaining[g] -= 1
            free -= 1
            if remaining[g] <= 0:
                groups.remove(g)
            else:
                self._cursor += 1
        return picks
