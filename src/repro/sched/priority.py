"""Tenant-weighted priority policy — proof the policy layer is pluggable.

Two levers, both weight-driven:

  * placement: ``assign`` is stride scheduling — each group (tenant/job)
    holds a pass value advanced by 1/weight per granted core, so a weight-2
    tenant receives twice the cores of a weight-1 tenant over time, yet
    low-weight tenants never starve (their pass eventually becomes minimal).
  * pressure: above ``shed_threshold`` live occupancy the policy sheds
    FUTURE GROWTH weight-ordered — it keeps the highest-weight tenants'
    tasks whose projected growth fits the headroom still free below pool
    capacity and suspends the rest (lowest weight, then highest growth,
    first).  Resume is FIFO
    on completion (inherited) and wholesale once usage drops below
    ``resume_below``.

Weights come from the constructor; tasks are mapped to groups via the
``group`` field the Sampler stamps on :class:`TaskStats` (job id in the
simulator, tenant in the serving engine).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Mapping, Optional, Sequence

from .protocol import BasePolicy, SchedulingDecision

if TYPE_CHECKING:
    from repro.core.memory_manager import MemoryPool
    from repro.core.sampler import TaskStats

__all__ = ["PriorityConfig", "PriorityPolicy"]


@dataclass(frozen=True)
class PriorityConfig:
    """Weights and thresholds for :class:`PriorityPolicy`."""

    weights: Mapping[str, float] = field(default_factory=dict)
    default_weight: float = 1.0
    #: live occupancy at which weight-ordered shedding starts
    shed_threshold: float = 0.6
    #: live occupancy below which all suspended tasks resume
    resume_below: float = 0.4
    min_running: int = 1
    period: float = 1.0

    def __post_init__(self) -> None:
        if not (0.0 < self.resume_below <= self.shed_threshold <= 1.0):
            raise ValueError(
                "need 0 < resume_below <= shed_threshold <= 1, got "
                f"{self.resume_below}, {self.shed_threshold}"
            )
        for g, w in self.weights.items():
            if w <= 0.0:
                raise ValueError(f"weight for {g!r} must be positive, got {w}")


class PriorityPolicy(BasePolicy):
    """Weighted stride placement + weight-ordered pressure shedding."""

    name = "priority"
    proactive = True

    def __init__(self, config: Optional[PriorityConfig] = None) -> None:
        super().__init__()
        self.config = config or PriorityConfig()
        self.period = self.config.period
        self.admission_headroom = self.config.shed_threshold
        self._pass: Dict[str, float] = {}  # stride-scheduling pass values

    def weight_of(self, group: str) -> float:
        return self.config.weights.get(group, self.config.default_weight)

    # ------------------------------------------------------------- placement
    def assign(self, free: int, pending: Mapping[str, int]) -> List[str]:
        remaining = {g: n for g, n in pending.items() if n > 0}
        if not remaining:
            return []
        # a newly seen group starts at the current minimum pass so it is
        # neither starved nor allowed to monopolize cores
        floor = min(
            (self._pass[g] for g in remaining if g in self._pass), default=0.0
        )
        for g in remaining:
            self._pass.setdefault(g, floor)
        picks: List[str] = []
        while free > 0 and remaining:
            g = min(remaining, key=lambda x: (self._pass[x], x))
            picks.append(g)
            self._pass[g] += 1.0 / self.weight_of(g)
            remaining[g] -= 1
            if remaining[g] <= 0:
                del remaining[g]
            free -= 1
        return picks

    def _shed_key(self, group: str, row) -> tuple:
        """Shed lowest-weight groups first (by 1/weight): under admission
        overload a paid/priority tenant's arrivals are the last to 503.
        Ties fall back to group arrival order (FIFO)."""
        return (self.weight_of(group), row.get("arrival_seq", 0.0))

    # ------------------------------------------------------ cluster placement
    def placement_score(self, group: str, replica_stats) -> float:
        """Weight-proportional routing: every tenant avoids loaded
        replicas, but a high-weight tenant's aversion is divided down —
        its scores sit closer to zero, so on a contended routing pass
        (the cluster places best-score-first) it claims the emptiest
        replica ahead of low-weight traffic.  Replica load blends byte
        demand and slot occupancy evenly (no rate signal here)."""
        demand = max(
            float(replica_stats.get("demand_fraction", 0.0)),
            float(replica_stats.get("projected_fraction", 0.0)),
        )
        slots = float(replica_stats.get("slot_load", 0.0))
        return -0.5 * (demand + slots) / self.weight_of(group)

    # ------------------------------------------------------ pressure surface
    def _weight_score(self, group: str) -> float:
        """Weight-ordered reclaim: a low-weight tenant's pages go first
        (1/(1+w) keeps the score in (0, 1) and monotone in weight)."""
        return 1.0 / (1.0 + self.weight_of(group))

    def pressure(self, view=None):
        """Weight-ordered :class:`~repro.serve.ledger.PressurePlan`: cold
        cached prefixes evict and frozen KV demotes low-weight-first (the
        same 1/(1+w) score ranks who pays for pressure in both classes),
        and the front door sheds by inverse weight."""
        from repro.serve.ledger import PageClass, PressurePlan

        return PressurePlan(
            scores={
                PageClass.COLD_CACHED: self._weight_score,
                PageClass.FROZEN: self._weight_score,
            },
            shed_key=self._shed_key,
        )

    # -------------------------------------------------------------- pressure
    def propose(
        self,
        pool: "MemoryPool",
        running: Sequence["TaskStats"],
        now: float = 0.0,
        suspended: Sequence["TaskStats"] = (),
    ) -> SchedulingDecision:
        cfg = self.config
        usage = pool.live_fraction
        if usage < cfg.resume_below:
            if self._suspended:
                resumed = list(self._suspended)
                self._suspended.clear()
                return SchedulingDecision(resume=resumed, reason="below-resume")
            return SchedulingDecision(reason="light")
        if usage < cfg.shed_threshold or self._suspended:
            # below the shed line, or pressure already being handled
            return SchedulingDecision(reason="steady")

        # Shed future growth weight-first: keep high-weight tenants' tasks
        # while their projected growth fits the remaining headroom below
        # CAPACITY (suspension freezes a task's buffer but stops its
        # growth — the shed line only decides when shedding starts, the
        # growth budget is everything still free in the pool).
        headroom = max(pool.capacity - pool.live_bytes, 0.0)
        keep_order = sorted(
            running,
            key=lambda t: (
                -self.weight_of(t.group),
                t.rate * t.remaining_bytes,
                t.task_id,
            ),
        )
        kept = 0
        suspend: List["TaskStats"] = []
        for t in keep_order:
            growth = t.rate * t.remaining_bytes
            if kept < cfg.min_running or growth <= headroom:
                kept += 1
                headroom -= growth
            else:
                suspend.append(t)
        # FIFO resume should bring back the highest-weight victims first
        suspend.sort(
            key=lambda t: (-self.weight_of(t.group), t.rate * t.remaining_bytes)
        )
        ids = [t.task_id for t in suspend]
        self._suspended.extend(ids)
        return SchedulingDecision(
            suspend=ids, reason="weight-shed" if ids else "fits"
        )
