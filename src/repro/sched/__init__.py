"""Pluggable scheduling-policy layer (MURS §IV, generalized).

The paper's claim is that ONE memory-usage-rate scheduler can govern all
co-resident tasks of a service.  This package makes the scheduler a first-
class, swappable policy so that the Spark-fidelity simulator
(:mod:`repro.core.service`) and the JAX serving engine
(:mod:`repro.serve.engine`) consume the exact same decision layer —
MURS-vs-FAIR comparisons are policy swaps, never divergent code paths.

Policies:
  * :class:`FairPolicy`     — Spark's fair scheduler pool: round-robin core
                              assignment, no pressure response (the stock
                              baseline; spills / OOMs reactively).
  * :class:`MursPolicy`     — Algorithm 1: yellow/red bands, rate-ranked
                              suspension, FIFO resume, spill guard.
  * :class:`PriorityPolicy` — tenant-weighted stride scheduling with
                              weight-ordered shedding under pressure
                              (demonstrates the layer is actually pluggable).
"""

from .fair import FairPolicy
from .murs import MursConfig, MursPolicy
from .priority import PriorityConfig, PriorityPolicy
from .protocol import BasePolicy, SchedulingDecision, SchedulingPolicy

__all__ = [
    "BasePolicy",
    "FairPolicy",
    "MursConfig",
    "MursPolicy",
    "PriorityConfig",
    "PriorityPolicy",
    "SchedulingDecision",
    "SchedulingPolicy",
]
