"""Sharded synthetic-token data pipeline with background prefetch.

Production shape: every host builds only its local shard of the global batch
(deterministic per (seed, step, host)), wraps it into a globally-sharded
jax.Array, and a background thread keeps ``prefetch`` batches ahead of the
training loop.  On a single-process CPU run the same code path produces the
full batch.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    prefetch: int = 2
    pad_fraction: float = 0.0  # fraction of tail positions padded (label −1)


def _host_batch(
    cfg: ArchConfig,
    shape: ShapeConfig,
    data_cfg: DataConfig,
    step: int,
    *,
    batch: Optional[int] = None,
    seq: Optional[int] = None,
) -> Dict[str, np.ndarray]:
    """Deterministic synthetic batch for this host (numpy, host-resident)."""
    b = batch if batch is not None else shape.global_batch
    s = seq if seq is not None else shape.seq_len
    rng = np.random.default_rng(
        (data_cfg.seed * 1_000_003 + step) * 97 + jax.process_index()
    )
    tokens = rng.integers(0, cfg.vocab, size=(b, s), dtype=np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = -1
    if data_cfg.pad_fraction > 0.0:
        pad = int(s * data_cfg.pad_fraction)
        if pad:
            labels[:, -pad:] = -1
    out = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "vision_stub":
        out["patch_embeds"] = rng.standard_normal(
            (b, cfg.vision_tokens, cfg.d_model), dtype=np.float32
        )
    if cfg.enc_layers:
        t_enc = s // cfg.enc_seq_divisor
        out["frame_embeds"] = rng.standard_normal(
            (b, t_enc, cfg.d_model), dtype=np.float32
        )
    return out


class DataPipeline:
    """Iterator of device-ready batches with background prefetch."""

    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        data_cfg: Optional[DataConfig] = None,
        *,
        sharding=None,
        batch: Optional[int] = None,
        seq: Optional[int] = None,
    ) -> None:
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg or DataConfig()
        self.sharding = sharding
        self.batch = batch
        self.seq = seq
        self._q: "queue.Queue" = queue.Queue(maxsize=self.data_cfg.prefetch)
        self._step = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _produce_one(self, step: int):
        host = _host_batch(
            self.cfg, self.shape, self.data_cfg, step,
            batch=self.batch, seq=self.seq,
        )
        put = {}
        for k, v in host.items():
            arr = jnp.asarray(v)
            if self.sharding is not None:
                arr = jax.device_put(arr, self.sharding)
            put[k] = arr
        return put

    def _producer(self) -> None:
        step = 0
        while not self._stop.is_set():
            try:
                self._q.put(self._produce_one(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
