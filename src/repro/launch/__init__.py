"""Launchers: training, serving, and the multi-pod compile dry-run.

``repro.launch.dryrun`` is import-order sensitive (it must set XLA flags
before jax initializes) and is therefore not imported here.
"""
