"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable abstract
values for the given (architecture × input-shape) cell, with no device
allocation:

    train_*    → {tokens, labels}  (+ modality-stub embeddings)
    prefill_*  → {tokens}          (+ stubs)
    decode_* / long_* → {tokens [B,1], caches(seq_len), pos}
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import init_cache
from repro.models.transformer import init_model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _stub_inputs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if cfg.frontend == "vision_stub":
        out["patch_embeds"] = _sds((batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers:
        out["frame_embeds"] = _sds(
            (batch, seq // cfg.enc_seq_divisor, cfg.d_model), jnp.bfloat16
        )
    return out


def train_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.enc_layers:
        # enc-dec: encoder sees the frames; decoder trains on text tokens
        dec_len = min(s // 8, 512)
        out = {
            "tokens": _sds((b, dec_len), jnp.int32),
            "labels": _sds((b, dec_len), jnp.int32),
        }
        out.update(_stub_inputs(cfg, b, s))
        return out
    out = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    out.update(_stub_inputs(cfg, b, s))
    return out


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.enc_layers:
        out = {"tokens": _sds((b, min(s // 8, 448)), jnp.int32)}
        out.update(_stub_inputs(cfg, b, s))
        return out
    out = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.frontend == "vision_stub":
        out.update(_stub_inputs(cfg, b, s))
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Decode: one new token against a seq_len KV cache."""
    b, s = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: init_cache(cfg, b, s))
    if cfg.enc_layers:
        hd = cfg.head_dim
        t_enc = s // cfg.enc_seq_divisor
        caches = dict(caches)
        caches["cross_kv"] = (
            _sds((b, cfg.n_kv_heads, t_enc, hd), jnp.bfloat16),
            _sds((b, cfg.n_kv_heads, t_enc, hd), jnp.bfloat16),
        )
    return {
        "tokens": _sds((b, 1), jnp.int32),
        "caches": caches,
        "pos": _sds((), jnp.int32),
    }


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)


def model_state_specs(cfg: ArchConfig, *, with_opt: bool = True):
    """Abstract (params, opt_state) via eval_shape — no allocation."""
    params = jax.eval_shape(
        lambda: init_model(cfg, jax.random.PRNGKey(0))
    )
    if not with_opt:
        return params
    from repro.optim import adamw

    opt = jax.eval_shape(lambda: adamw.init(params))
    return params, opt
