import os
import sys

if "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512"
    ).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The lines above MUST run before any other import (jax locks the device
count at first init) — they give this process 512 placeholder CPU devices so
``jax.make_mesh`` can build the production meshes.  When this module is
merely *imported* into a process that already initialized jax (tests, the
import sweep), the flag would be a silent no-op for this process but leak
into child environments — so it is only set when jax is not loaded yet:

    single-pod: (16, 16)      ("data", "model")        = 256 chips
    multi-pod:  (2, 16, 16)   ("pod", "data", "model") = 512 chips

Per cell the driver:
  1. builds ShapeDtypeStruct stand-ins (no allocation) for params/opt/batch,
  2. resolves arch/shape-aware sharding rules (repro.dist.presets),
  3. ``jax.jit(step, in_shardings=…).lower(...).compile()`` — success proves
     the distribution config is coherent,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     (parsed from the post-SPMD HLO) to JSON for the roofline analysis.

Usage:
    python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import json
import re
import time
import jax

from repro.configs import ARCHS, SHAPES
from repro.dist.presets import arch_overrides, batch_shardings
from repro.dist.sharding import make_rules, param_shardings, use_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, model_state_specs
from repro.models import decode_step, prefill
from repro.optim import adamw
from repro.train.train_step import make_train_step

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*"
    r"\(?\s*([a-z0-9]+)\[([0-9,]*)\]"
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (per-device)
    post-SPMD HLO."""
    out = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        op, dtype, dims = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        entry = out.setdefault(op, {"count": 0, "bytes": 0})
        entry["count"] += 1
        entry["bytes"] += n * _DTYPE_BYTES[dtype]
    return out


def _cost_dict(compiled) -> dict:
    try:
        cost = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}


def _memory_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if m is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(m, k):
            out[k] = int(getattr(m, k))
    return out


#: §Perf variants — each is a hypothesis in the hillclimb log (EXPERIMENTS.md)
VARIANTS = {
    "baseline": {},
    # qwen-train: never materialize [B,S,V] f32 logits
    "chunked_loss": {"loss_chunk": 512},
    # qwen-train: save matmul outputs in remat (cuts the 4/3 recompute tax)
    "dots": {"remat": "dots"},
    "chunked+dots": {"loss_chunk": 512, "remat": "dots"},
    # qwen-train: 8-way microbatch accumulation — per-micro backward runs
    # inside the accumulation scan body, so activation residency divides by 8
    "micro8": {"microbatches": 8, "loss_chunk": 512},
    "micro16": {"microbatches": 16, "loss_chunk": 512},
    "micro32": {"microbatches": 32, "loss_chunk": 512},
    # zamba2-train: ZeRO-1 — params replicated (no per-layer fsdp gathers),
    # optimizer state still sharded over data
    "zero1": {"zero1": True},
    # decode cells: serve-mode sharding — weights TP-resident (no fsdp
    # all-gathers per step), KV cache sequence-sharded over the model axis,
    # MoE expert-internal dim over data (token-sized collectives only)
    "serve_v2": {"serve_v2": True},
    # vocab-sharded embedding tables force gather full-remats (fwd) and
    # scatter collectives (bwd) — replicating the table trades ≤1 GiB HBM
    # for the entire gather/scatter collective chain
    "serve_v3": {"serve_v2": True, "repembed": True},
    "zero1+repembed": {"zero1": True, "repembed": True},
    "repembed": {"repembed": True},
}


def build_cell(arch: str, shape_name: str, *, multi_pod: bool, variant: str = "baseline"):
    """Returns (lowered, meta) for one (arch × shape × mesh) cell."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    if shape_name not in cfg.applicable_shapes:
        return None, {"skipped": True, "reason": "shape not applicable"}
    v = VARIANTS[variant]

    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = arch_overrides(cfg, mesh, shape)
    if v.get("serve_v2"):
        overrides["fsdp"] = None
        overrides["kv_seq"] = "model"
        if cfg.moe is not None and overrides.get("expert", "x") is not None:
            # safe together with the GLOBAL decode dispatch (no batch axis
            # in the expert GEMM): experts shard over model × data →
            # deepseek's 226 B expert params = 1.8 GiB/device
            overrides["expert_mlp"] = "data"
    if v.get("repembed"):
        overrides["vocab"] = None
    rules = make_rules(mesh, overrides=overrides)
    specs = input_specs(cfg, shape)
    b_shardings = batch_shardings(cfg, rules, specs)

    with use_rules(rules):
        if shape.kind == "train":
            params_s, opt_s = model_state_specs(cfg)
            if v.get("zero1"):
                nofsdp = make_rules(
                    mesh, overrides=overrides | {"fsdp": None}
                )
                p_shard = param_shardings(params_s, nofsdp)
                m_shard = param_shardings(params_s, rules)
            else:
                p_shard = param_shardings(params_s, rules)
                m_shard = p_shard
            o_shard = adamw.AdamWState(
                step=rules.sharding(()),
                m=m_shard,
                v=m_shard,
            )
            step_fn = make_train_step(
                cfg,
                adamw.AdamWConfig(),
                microbatches=v.get("microbatches", 1),
                remat=v.get("remat", True),
                loss_chunk=v.get("loss_chunk"),
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shardings),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_s, opt_s, specs)
        elif shape.kind == "prefill":
            params_s = model_state_specs(cfg, with_opt=False)
            p_shard = param_shardings(params_s, rules)

            def prefill_fn(params, batch):
                tokens = batch["tokens"]
                extra = {k: v for k, v in batch.items() if k != "tokens"}
                return prefill(
                    cfg, params, tokens, extra=extra or None,
                    max_seq=shape.seq_len, remat=True,
                )

            jitted = jax.jit(prefill_fn, in_shardings=(p_shard, b_shardings))
            lowered = jitted.lower(params_s, specs)
        else:  # decode
            params_s = model_state_specs(cfg, with_opt=False)
            p_shard = param_shardings(params_s, rules)

            def decode_fn(params, tokens, caches, pos):
                return decode_step(cfg, params, tokens, caches, pos)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(
                    p_shard,
                    b_shardings["tokens"],
                    b_shardings["caches"],
                    b_shardings["pos"],
                ),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                params_s, specs["tokens"], specs["caches"], specs["pos"]
            )
    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "variant": variant,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return lowered, meta


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    out_dir: str,
    variant: str = "baseline",
):
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if variant != "baseline":
        tag += f"__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            prev = json.load(f)
        if "error" not in prev:
            print(f"[skip] {tag} (cached)")
            return prev
    t0 = time.time()
    try:
        lowered, meta = build_cell(
            arch, shape_name, multi_pod=multi_pod, variant=variant
        )
        if lowered is None:
            record = meta | {"arch": arch, "shape": shape_name}
            print(f"[n/a ] {tag}: {meta['reason']}")
        else:
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            record = meta | {
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "cost": _cost_dict(compiled),
                "memory": _memory_dict(compiled),
                "collectives": collective_bytes(compiled.as_text()),
            }
            print(
                f"[ ok ] {tag}: lower {t_lower:.1f}s compile {t_compile:.1f}s "
                f"flops/dev={record['cost'].get('flops', 0):.3e}"
            )
    except Exception as e:  # record failures — they are bugs to fix
        record = {
            "arch": arch,
            "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "error": f"{type(e).__name__}: {e}"[:2000],
        }
        print(f"[FAIL] {tag}: {record['error'][:200]}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    ok = fail = skipped = 0
    for a, s, mp in cells:
        rec = run_cell(
            a, s, multi_pod=mp, out_dir=args.out, variant=args.variant
        )
        if rec.get("skipped"):
            skipped += 1
        elif "error" in rec:
            fail += 1
        else:
            ok += 1
    print(f"\ndry-run: {ok} ok, {fail} failed, {skipped} n/a")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
