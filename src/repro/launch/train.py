"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant Trainer on the selected architecture.  On this CPU
container the full configs are dry-run-only; by default the launcher uses
the reduced (smoke) config so the command is actually runnable anywhere —
pass ``--full`` on real hardware.
"""

import argparse

from repro.configs import ARCHS, SHAPES, get_arch
from repro.optim.adamw import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--full", action="store_true",
                    help="use the full config (requires real accelerators)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.smoke()
    shape = SHAPES[args.shape]
    batch = args.batch if args.batch else (None if args.full else 4)
    seq = args.seq if args.seq else (None if args.full else 64)
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps")
    trainer = Trainer(
        cfg, shape,
        TrainerConfig(
            steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            microbatches=args.microbatches,
            grad_compression=args.grad_compression,
            opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps),
        ),
        batch=batch,
        seq=seq,
    )
    out = trainer.run()
    if trainer.compressed_wire_bytes is not None:
        print(f"grad compression: {trainer.compressed_wire_bytes / 1e6:.2f} MB/exchange "
              f"(f32 would be {4 * cfg.param_count() / 1e6:.2f} MB)")
    print(f"finished at step {out['final_step']}  loss={out['final_loss']}")
    for m in out["log"][-3:]:
        print(f"  step {m['step']}  loss {m['loss']:.4f}  "
              f"{m['step_time_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
