"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the multi-tenant engine (MURS admission by default; ``--fair`` for
the stock baseline) and runs a synthetic two-tenant workload.
"""

import argparse

import jax

from repro.configs import ARCHS, get_arch
from repro.sched import FairPolicy, MursConfig, MursPolicy
from repro.models import init_model
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import kv_bytes_per_token


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=sorted(ARCHS))
    ap.add_argument("--fair", action="store_true", help="disable MURS")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--pool-tokens", type=int, default=80,
                    help="KV pool capacity in token-equivalents")
    ap.add_argument("--requests", type=int, default=7)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    capacity = max(kv_bytes_per_token(cfg), 1.0) * args.pool_tokens
    engine = ServingEngine(
        cfg, params,
        EngineConfig(
            n_slots=args.slots,
            max_seq=args.max_seq,
            hbm_capacity_bytes=capacity,
            policy=(FairPolicy() if args.fair
                    else MursPolicy(MursConfig.for_serving(period=1.0))),
        ),
    )
    n_a = args.requests // 2 + args.requests % 2
    for i in range(n_a):
        engine.submit(Request(f"A{i}", "A", list(range(10, 18)), 40))
    for i in range(args.requests - n_a):
        engine.submit(Request(f"B{i}", "B", list(range(30, 34)), 6))
    rep = engine.run(max_ticks=1000)
    mode = "FAIR" if args.fair else "MURS"
    print(f"[{mode}] completed {rep.completed}/{args.requests}  "
          f"failed {rep.failed}  "
          f"suspensions {rep.extras['suspensions']}  "
          f"tokens {rep.tokens_generated}  "
          f"peak pool {rep.extras['peak_used_fraction']:.2f}")


if __name__ == "__main__":
    main()
