"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  Production target: TPU v5e pods —
16×16 = 256 chips per pod ("data", "model"); the multi-pod mesh adds a
leading "pod" axis (2×16×16 = 512 chips).  Hardware constants for the
roofline live in repro.roofline.analysis.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh():
    """A 1×1 mesh over the local device — smoke tests / CPU runs."""
    return jax.make_mesh((1, 1), ("data", "model"))
