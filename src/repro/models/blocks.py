"""Transformer / SSM blocks: GQA attention, MLA, MoE, Mamba-2 SSD.

Each block provides ``init_<blk>(key, cfg) → params`` (vmap-able for
scan-over-layers stacking) and apply functions for the three execution
modes: train/prefill (full sequence, optionally emitting a KV cache) and
decode (single token against a cache).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import shard
from .layers import (
    apply_rope,
    chunked_attention,
    decode_attention,
    dense_init,
    rms_norm,
)

Array = jax.Array


# ===================================================================== GQA
def init_attention(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _qkv(p: dict, cfg: ArchConfig, x: Array) -> Tuple[Array, Array, Array]:
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    q = shard(q, ("batch", "heads", "seq", None))
    k = shard(k, ("batch", "kv_heads", "seq", None))
    v = shard(v, ("batch", "kv_heads", "seq", None))
    return q, k, v


def attention_forward(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    q_offset: int = 0,
    return_cache: bool = False,
):
    """Full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    positions = q_offset + jnp.arange(s)
    q = apply_rope(q, positions[None, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, None, :], cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if return_cache:
        return y, (k, v)
    return y


def attention_decode(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    cache: Tuple[Array, Array],
    pos: Array,
    *,
    window: Optional[int] = None,
):
    """Single-token decode; pos: scalar.

    Full attention: cache k/v [B, KV, S_max, hd], written at ``pos``.
    Sliding window: RING-BUFFER cache [B, KV, window, hd], written at
    ``pos % window`` (see layers.decode_attention_ring).
    """
    from .layers import decode_attention_ring

    b = x.shape[0]
    q, k_new, v_new = _qkv(p, cfg, x)  # seq dim == 1
    q = apply_rope(q, pos[None, None, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[None, None, None], cfg.rope_theta)
    k_cache, v_cache = cache
    ring = window is not None and k_cache.shape[2] == window
    write_at = (pos % window) if ring else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new, write_at, axis=2
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new, write_at, axis=2
    )
    if ring:
        out = decode_attention_ring(q, k_cache, v_cache, pos, window)
    else:
        out = decode_attention(q, k_cache, v_cache, pos, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return y, (k_cache, v_cache)


# ===================================================================== MLA
def init_mla(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    assert m is not None
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dtype),
        "q_ln": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank, cfg.n_heads * qk_dim, dtype),
        "wkv_a": dense_init(
            ks[2], cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim, dtype
        ),
        "kv_ln": jnp.zeros((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(
            ks[3],
            m.kv_lora_rank,
            cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim),
            dtype,
        ),
        "wo": dense_init(ks[4], cfg.n_heads * m.v_head_dim, cfg.d_model, dtype),
    }


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", q_lat, p["wq_b"]).reshape(
        b, s, cfg.n_heads, qk_dim
    ).transpose(0, 2, 1, 3)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(
        q[..., m.qk_nope_head_dim :], positions[None, None, :], cfg.rope_theta
    )
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    m = cfg.mla
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    latent = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope(
        kv_a[..., m.kv_lora_rank :][:, None], positions[None, None, :], cfg.rope_theta
    )  # [B, 1, S, rope_dim]
    return latent, k_rope


def mla_forward(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    *,
    q_offset: int = 0,
    return_cache: bool = False,
):
    """MLA train/prefill: expand latent to per-head K/V (compute-optimal at
    long Sq); the decode path uses the absorbed latent-space form instead."""
    m = cfg.mla
    b, s, _ = x.shape
    positions = q_offset + jnp.arange(s)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    latent, k_rope = _mla_latent(p, cfg, x, positions)
    kv = jnp.einsum("bsr,rh->bsh", latent, p["wkv_b"]).reshape(
        b, s, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim
    ).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim :]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, cfg.n_heads, s, m.qk_rope_head_dim))],
        axis=-1,
    )
    out = chunked_attention(q, k, v, causal=True, q_offset=q_offset)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if return_cache:
        # the MLA cache is the LATENT (+ rope key): 576 B/token vs
        # 2·128·128 = 32 KiB/token for full per-head K/V — the sub-linear
        # serve-memory motif (DESIGN.md §4)
        return y, (latent, k_rope[:, 0])
    return y


def mla_decode(p: dict, cfg: ArchConfig, x: Array, cache, pos: Array):
    """Absorbed-form MLA decode: attention runs in the latent space."""
    m = cfg.mla
    b = x.shape[0]
    latent_cache, rope_cache = cache  # [B, S, r], [B, S, rope_dim]
    positions = pos[None]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)  # [B, H, 1, *]
    latent_new, k_rope_new = _mla_latent(p, cfg, x, positions)
    latent_cache = jax.lax.dynamic_update_slice_in_dim(
        latent_cache, latent_new, pos, axis=1
    )
    rope_cache = jax.lax.dynamic_update_slice_in_dim(
        rope_cache, k_rope_new[:, 0], pos, axis=1
    )
    # absorb wkv_b's key half into the query:  q_lat = q_nope @ W_k^T
    wkv_b = p["wkv_b"].reshape(
        m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim
    )
    w_k = wkv_b[..., : m.qk_nope_head_dim]  # [r, H, nope]
    w_v = wkv_b[..., m.qk_nope_head_dim :]  # [r, H, v]
    q_lat = jnp.einsum("bhqn,rhn->bhqr", q_nope.astype(jnp.float32), w_k.astype(jnp.float32))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (
        jnp.einsum("bhqr,bsr->bhqs", q_lat, latent_cache.astype(jnp.float32))
        + jnp.einsum(
            "bhqe,bse->bhqs",
            q_rope.astype(jnp.float32),
            rope_cache.astype(jnp.float32),
        )
    ) * scale
    mask = jnp.arange(latent_cache.shape[1])[None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bhqr", probs, latent_cache.astype(jnp.float32))
    out = jnp.einsum("bhqr,rhv->bhqv", ctx, w_v.astype(jnp.float32))  # [B,H,1,v]
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1).astype(x.dtype)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return y, (latent_cache, rope_cache)


# ===================================================================== MoE
def init_moe(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    moe = cfg.moe
    assert moe is not None
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(cfg.d_model)
    p = {
        "router": (
            jax.random.normal(ks[0], (cfg.d_model, moe.num_experts), jnp.float32)
            * scale
        ).astype(jnp.float32),
        "gate": (
            jax.random.normal(
                ks[1], (moe.num_experts, cfg.d_model, moe.d_ff_expert), jnp.float32
            )
            * scale
        ).astype(dtype),
        "up": (
            jax.random.normal(
                ks[2], (moe.num_experts, cfg.d_model, moe.d_ff_expert), jnp.float32
            )
            * scale
        ).astype(dtype),
        "down": (
            jax.random.normal(
                ks[3], (moe.num_experts, moe.d_ff_expert, cfg.d_model), jnp.float32
            )
            * scale
        ).astype(dtype),
    }
    return p


def _capacity(cfg: ArchConfig, seq: int) -> int:
    moe = cfg.moe
    c = int(math.ceil(seq * moe.top_k / moe.num_experts * moe.capacity_factor))
    return max(8, min(c, seq))


def moe_dispatch_row(x_row: Array, gates_row: Array, top_k: int, capacity: int):
    """Sort-based dispatch for a single sequence (vmapped over batch).

    Returns (xe [E*C, d], slot_of [S*k], tok_of [S*k], gate_of [S*k],
    keep [S*k]).
    """
    s, e = gates_row.shape
    top_vals, top_idx = jax.lax.top_k(gates_row, top_k)  # [S, k]
    top_vals = jax.nn.softmax(top_vals, axis=-1)  # renormalize over chosen
    flat_expert = top_idx.reshape(-1)  # [S*k]
    flat_gate = top_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(s), top_k)
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    sorted_tok = flat_tok[order]
    sorted_gate = flat_gate[order]
    starts = jnp.searchsorted(sorted_expert, jnp.arange(e))  # [E]
    pos_in_expert = jnp.arange(s * top_k) - starts[sorted_expert]
    keep = pos_in_expert < capacity
    slot = jnp.where(keep, sorted_expert * capacity + pos_in_expert, 0)
    xe = jnp.zeros((e * capacity, x_row.shape[-1]), x_row.dtype)
    contrib = jnp.where(keep[:, None], x_row[sorted_tok], 0).astype(x_row.dtype)
    xe = xe.at[slot].add(contrib)
    return xe, slot, sorted_tok, sorted_gate, keep


def moe_forward(p: dict, cfg: ArchConfig, x: Array) -> Array:
    """Token-choice top-k MoE with per-sequence capacity (GShard-style
    token dropping) and sort-based grouped dispatch.

    Decode (s == 1) uses a GLOBAL cross-batch dispatch instead: the whole
    batch is one dispatch group, so the expert GEMM [E, C, d]×[E, d, f] has
    no batch axis — expert weights can shard over (model × data) without the
    weight all-gather that a batch-axis conflict forces (§Perf-1), and the
    per-step activations are token-sized.
    """
    moe = cfg.moe
    b, s, d = x.shape
    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"]
    )
    gates = jax.nn.softmax(router_logits, axis=-1)

    if s == 1:
        xb = x[:, 0]  # [B, d]
        gb = gates[:, 0]  # [B, E]
        capacity = max(
            4,
            int(
                math.ceil(
                    b * moe.top_k / moe.num_experts * moe.capacity_factor
                )
            ),
        )
        xe, slot, tok, gate_w, keep = moe_dispatch_row(
            xb, gb, moe.top_k, capacity
        )
        xe = xe.reshape(moe.num_experts, capacity, d)
        xe = shard(xe, ("expert", None, None))
        h_gate = jnp.einsum("ecd,edf->ecf", xe, p["gate"])
        h_up = jnp.einsum("ecd,edf->ecf", xe, p["up"])
        h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up
        h = shard(h, ("expert", None, "expert_mlp"))
        ye = jnp.einsum("ecf,efd->ecd", h, p["down"]).reshape(-1, d)
        vals = ye[slot].astype(jnp.float32) * (gate_w * keep)[:, None]
        y = jnp.zeros((b, d), jnp.float32).at[tok].add(vals)
        return y[:, None].astype(x.dtype)

    capacity = _capacity(cfg, s)

    xe, slot, tok, gate_w, keep = jax.vmap(
        lambda xr, gr: moe_dispatch_row(xr, gr, moe.top_k, capacity)
    )(x, gates)
    xe = xe.reshape(b, moe.num_experts, capacity, d)
    xe = shard(xe, ("batch", "expert", None, None))
    h_gate = jnp.einsum("becd,edf->becf", xe, p["gate"])
    h_up = jnp.einsum("becd,edf->becf", xe, p["up"])
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up
    h = shard(h, ("batch", "expert", None, "expert_mlp"))
    ye = jnp.einsum("becf,efd->becd", h, p["down"])
    ye = ye.reshape(b, moe.num_experts * capacity, d)

    def combine_row(ye_row, slot_row, tok_row, gate_row, keep_row):
        vals = ye_row[slot_row].astype(jnp.float32) * (
            gate_row * keep_row
        )[:, None]
        return jnp.zeros((s, d), jnp.float32).at[tok_row].add(vals)

    y = jax.vmap(combine_row)(ye, slot, tok, gate_w, keep)
    return y.astype(x.dtype)


# ================================================================== Mamba-2
def init_mamba(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    """Mamba-2 block with SPLIT projections.

    The reference implementation fuses z/x/B/C/dt into one in_proj and
    slices its output — under tensor parallelism those slices cut the
    sharded output dim at non-shard-aligned offsets and XLA pays a
    collective-permute chain for every piece (measured ≈7.5 GiB/step on
    zamba2-train; EXPERIMENTS.md §Perf-2).  Separate matrices give every
    part a cleanly sharded (or replicated) output dim.
    """
    ssm = cfg.ssm
    assert ssm is not None
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.n_heads(d)
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], d, di, dtype),
        "w_x": dense_init(ks[1], d, di, dtype),
        "w_B": dense_init(ks[2], d, ssm.d_state, dtype),
        "w_C": dense_init(ks[3], d, ssm.d_state, dtype),
        "w_dt": dense_init(ks[4], d, nh, dtype),
        "conv_x": (
            jax.random.normal(ks[5], (ssm.d_conv, di), jnp.float32) * 0.1
        ).astype(dtype),
        "conv_B": (
            jax.random.normal(ks[6], (ssm.d_conv, ssm.d_state), jnp.float32)
            * 0.1
        ).astype(dtype),
        "conv_C": (
            jax.random.normal(ks[7], (ssm.d_conv, ssm.d_state), jnp.float32)
            * 0.1
        ).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _causal_conv(x: Array, w: Array, s: int) -> Array:
    """Depthwise causal conv along seq; x [B,S,C], w [d_conv, C]."""
    d_conv = w.shape[0]
    x_pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    return sum(x_pad[:, i : i + s] * w[i][None, None, :] for i in range(d_conv))


def _ssd_scan(x, dt, A, B, C, chunk: int):
    """Chunked SSD (state-space duality) scan [arXiv:2405.21060 §6].

    x: [b, s, nh, hd]; dt: [b, s, nh]; A: [nh] (negative);
    B, C: [b, s, ds].  Returns y: [b, s, nh, hd].
    """
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    nc = (s + chunk - 1) // chunk
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(b, nc, chunk, nh, hd)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B.reshape(b, nc, chunk, ds)
    Cc = C.reshape(b, nc, chunk, ds)

    dA = dtc * A[None, None, None, :]  # [b, nc, q, nh] (negative)
    seg = jnp.cumsum(dA, axis=2)  # cumulative decay within chunk
    total = seg[:, :, -1, :]  # [b, nc, nh]

    # --- intra-chunk (quadratic within chunk, matches attention-form SSD)
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # [b,nc,qi,qj,nh]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask BEFORE exp: upper-triangle rel is positive-large → exp overflows
    # to inf and poisons gradients through the where (inf·0 = NaN in vjp)
    rel = jnp.where(causal, rel, -jnp.inf)
    L = jnp.exp(rel)
    scores = jnp.einsum("bcid,bcjd->bcij", Cc, Bc)  # [b,nc,qi,qj]
    M = scores[..., None] * L * dtc[:, :, None, :, :]  # [b,nc,qi,qj,nh]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc.astype(jnp.float32))

    # --- per-chunk outgoing state:  S_c = Σ_j exp(total−seg_j)·dt_j·B_j⊗x_j
    decay_out = jnp.exp(total[:, :, None, :] - seg)  # [b,nc,q,nh]
    wx = (decay_out * dtc)[..., None] * xc.astype(jnp.float32)  # [b,nc,q,nh,hd]
    S_c = jnp.einsum("bcqd,bcqhp->bchpd", Bc, wx)  # [b,nc,nh,hd,ds]

    # --- inter-chunk recurrence:  H_c = exp(total_c)·H_{c-1} + S_c
    def scan_fn(H, inputs):
        S_chunk, tot = inputs  # [b,nh,hd,ds], [b,nh]
        H_new = jnp.exp(tot)[:, :, None, None] * H + S_chunk
        return H_new, H  # emit the INCOMING state for this chunk

    H0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    _, H_in = jax.lax.scan(
        scan_fn,
        H0,
        (S_c.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    H_in = H_in.transpose(1, 0, 2, 3, 4)  # [b,nc,nh,hd,ds]

    # --- inter-chunk contribution:  y_i += exp(seg_i)·C_i·H_in
    decay_in = jnp.exp(seg)  # [b,nc,q,nh]
    y_inter = (
        jnp.einsum("bcqd,bchpd->bcqhp", Cc, H_in) * decay_in[..., None]
    )

    y = (y_intra + y_inter).reshape(b, nc * chunk, nh, hd)
    if pad:
        y = y[:, :s]
    return y


def mamba_forward(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    *,
    return_cache: bool = False,
):
    """Mamba-2 block (train / prefill).

    Cache = (conv_x_state, conv_B_state, conv_C_state, ssm_state)."""
    ssm = cfg.ssm
    b, s, d = x.shape
    di = ssm.d_inner(d)
    nh = ssm.n_heads(d)

    from .layers import bf16_grad

    # bf16_grad: the SSD internals run in f32, so without a boundary the
    # cotangents reaching these projections are f32 and every TP activation-
    # grad all-reduce doubles in size (§Perf-2 follow-up)
    z = bf16_grad(jnp.einsum("bsd,dk->bsk", x, p["w_z"]))
    x_in = bf16_grad(jnp.einsum("bsd,dk->bsk", x, p["w_x"]))
    B_in = bf16_grad(jnp.einsum("bsd,dk->bsk", x, p["w_B"]))
    C_in = bf16_grad(jnp.einsum("bsd,dk->bsk", x, p["w_C"]))
    dt_raw = bf16_grad(jnp.einsum("bsd,dk->bsk", x, p["w_dt"]))

    xc = jax.nn.silu(_causal_conv(x_in, p["conv_x"], s).astype(jnp.float32))
    Bc = jax.nn.silu(_causal_conv(B_in, p["conv_B"], s).astype(jnp.float32))
    Cc = jax.nn.silu(_causal_conv(C_in, p["conv_C"], s).astype(jnp.float32))

    xs = xc.reshape(b, s, nh, ssm.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y = _ssd_scan(xs, dt, A, Bc, Cc, ssm.chunk_size)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    if return_cache:
        tail = ssm.d_conv - 1
        cache = (
            x_in[:, -tail:, :],
            B_in[:, -tail:, :],
            C_in[:, -tail:, :],
            _final_state(xs, dt, A, Bc),
        )
        return out, cache
    return out


def _final_state(xs, dt, A, B):
    """Final SSM state  H = Σ_j exp(Σ_{l>j} dA_l)·dt_j·B_j⊗x_j  (f32)."""
    b, s, nh, hd = xs.shape
    dA = dt * A[None, None, :]
    seg = jnp.cumsum(dA, axis=1)
    total = seg[:, -1:, :]
    decay = jnp.exp(total - seg)  # [b,s,nh]
    wx = (decay * dt)[..., None] * xs.astype(jnp.float32)
    return jnp.einsum("bsd,bshp->bhpd", B, wx)  # [b,nh,hd,ds]


def mamba_decode(p: dict, cfg: ArchConfig, x: Array, cache, pos: Array):
    """Single-token Mamba-2 step: O(1) state update (constant memory)."""
    ssm = cfg.ssm
    b, _, d = x.shape
    di = ssm.d_inner(d)
    nh = ssm.n_heads(d)
    cx, cB, cC, ssm_state = cache  # conv tails [b, d_conv-1, *], state f32

    z = jnp.einsum("bsd,dk->bsk", x, p["w_z"])[:, 0]
    x_in = jnp.einsum("bsd,dk->bsk", x, p["w_x"])[:, 0]
    B_in = jnp.einsum("bsd,dk->bsk", x, p["w_B"])[:, 0]
    C_in = jnp.einsum("bsd,dk->bsk", x, p["w_C"])[:, 0]
    dt_raw = jnp.einsum("bsd,dk->bsk", x, p["w_dt"])[:, 0]

    def step_conv(tail, new, w):
        window = jnp.concatenate([tail, new[:, None]], axis=1)  # [b,d_conv,c]
        out = jnp.einsum("bkc,kc->bc", window, w)
        return jax.nn.silu(out.astype(jnp.float32)), window[:, 1:]

    xc, cx = step_conv(cx, x_in, p["conv_x"])
    Bc, cB = step_conv(cB, B_in, p["conv_B"])
    Cc, cC = step_conv(cC, C_in, p["conv_C"])
    xs = xc.reshape(b, nh, ssm.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [b,nh]
    A = -jnp.exp(p["A_log"])

    decay = jnp.exp(dt * A[None, :])  # [b,nh]
    ssm_state = decay[:, :, None, None] * ssm_state + jnp.einsum(
        "bd,bhp->bhpd", Bc, dt[..., None] * xs
    )
    y = jnp.einsum("bhpd,bd->bhp", ssm_state, Cc)  # [b,nh,hd]
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(b, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None]
    return out, (cx, cB, cC, ssm_state)
