"""Model zoo: 10 assigned architectures over a shared block-program core."""

from .transformer import (
    backbone_forward,
    decode_step,
    decode_step_paged,
    forward,
    init_cache,
    init_model,
    paged_decode_supported,
    prefill,
)

__all__ = [
    "backbone_forward",
    "decode_step",
    "decode_step_paged",
    "forward",
    "init_cache",
    "init_model",
    "paged_decode_supported",
    "prefill",
]
