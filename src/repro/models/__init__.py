"""Model zoo: 10 assigned architectures over a shared block-program core."""

from .transformer import (
    backbone_forward,
    decode_step,
    forward,
    init_cache,
    init_model,
    prefill,
)

__all__ = [
    "backbone_forward",
    "decode_step",
    "forward",
    "init_cache",
    "init_model",
    "prefill",
]
