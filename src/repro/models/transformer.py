"""Model assembly: block-program scan-over-layers, init, forward, decode.

A model's stack is ``block_pattern × pattern_repeats + suffix_blocks``.
The repeated unit is scanned with :func:`jax.lax.scan` over stacked unit
parameters, keeping the HLO O(1) in depth (an 80-layer qwen compiles like a
single unit); heterogeneous stacks (gemma3, zamba2) repeat a heterogeneous
*unit* whose pytree structure is uniform across repeats.  Suffix blocks are
unrolled.  zamba2's shared attention block is a single (non-stacked)
parameter set invoked at every ``shared_attn`` position through per-position
adapters.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import shard
from . import blocks
from .layers import (
    apply_mlp,
    apply_rope,
    bf16_grad,
    dense_init,
    embed_init,
    init_mlp,
    rms_norm,
)

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------- per-block
def _init_block(key, cfg: ArchConfig, btype: str, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if btype == "mamba":
        p["mamba"] = blocks.init_mamba(k1, cfg, dtype)
        return p
    if btype == "shared_attn":
        # adapters only; the shared body lives once at the model level
        p["in_adapter"] = dense_init(k1, cfg.d_model, cfg.d_model, dtype)
        p["out_adapter"] = dense_init(k2, cfg.d_model, cfg.d_model, dtype)
        return p
    # attention blocks ("attn" | "local_attn")
    if cfg.mla is not None:
        p["attn"] = blocks.init_mla(k1, cfg, dtype)
    else:
        p["attn"] = blocks.init_attention(k1, cfg, dtype)
    p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.moe is not None:
        p["moe"] = blocks.init_moe(k2, cfg, dtype)
        if cfg.moe.num_shared_experts:
            p["shared_mlp"] = init_mlp(
                k3,
                cfg.d_model,
                cfg.moe.num_shared_experts * cfg.moe.d_ff_shared,
                dtype,
            )
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_shared_body(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    """zamba2 shared transformer body (attention + MLP), one copy."""
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": blocks.init_attention(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _ffn(p: dict, cfg: ArchConfig, x: Array) -> Array:
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y = blocks.moe_forward(p["moe"], cfg, h)
        if "shared_mlp" in p:
            y = y + apply_mlp(p["shared_mlp"], h)
        return y
    return apply_mlp(p["mlp"], h)


def _apply_block_full(
    p: dict,
    cfg: ArchConfig,
    btype: str,
    x: Array,
    *,
    shared_body: Optional[dict],
    q_offset: int = 0,
    causal: bool = True,
    want_cache: bool,
):
    """Full-sequence (train / prefill) application of one block."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    cache = None
    if btype == "mamba":
        if want_cache:
            y, cache = blocks.mamba_forward(p["mamba"], cfg, h, return_cache=True)
        else:
            y = blocks.mamba_forward(p["mamba"], cfg, h)
        x = x + y
    elif btype == "shared_attn":
        assert shared_body is not None
        inner = jnp.einsum("bsd,de->bse", h, p["in_adapter"])
        g = rms_norm(inner, shared_body["ln1"], cfg.norm_eps)
        if want_cache:
            a, cache = blocks.attention_forward(
                shared_body["attn"], cfg, g, q_offset=q_offset, causal=causal,
                return_cache=True,
            )
        else:
            a = blocks.attention_forward(
                shared_body["attn"], cfg, g, q_offset=q_offset, causal=causal
            )
        inner = inner + a
        inner = inner + apply_mlp(
            shared_body["mlp"], rms_norm(inner, shared_body["ln2"], cfg.norm_eps)
        )
        x = x + jnp.einsum("bsd,de->bse", inner, p["out_adapter"])
    else:
        window = cfg.sliding_window if btype == "local_attn" else None
        if cfg.mla is not None:
            if want_cache:
                a, cache = blocks.mla_forward(
                    p["attn"], cfg, h, q_offset=q_offset, return_cache=True
                )
            else:
                a = blocks.mla_forward(p["attn"], cfg, h, q_offset=q_offset)
        else:
            if want_cache:
                a, cache = blocks.attention_forward(
                    p["attn"], cfg, h, window=window, causal=causal,
                    q_offset=q_offset, return_cache=True,
                )
            else:
                a = blocks.attention_forward(
                    p["attn"], cfg, h, window=window, causal=causal,
                    q_offset=q_offset,
                )
        x = x + a
        x = x + _ffn(p, cfg, x)
        x = shard(bf16_grad(x), ("batch", "seq", "embed"))
        return x, cache
    x = shard(bf16_grad(x), ("batch", "seq", "embed"))
    return x, cache


def _apply_block_decode(
    p: dict,
    cfg: ArchConfig,
    btype: str,
    x: Array,
    cache,
    pos: Array,
    *,
    shared_body: Optional[dict],
):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if btype == "mamba":
        y, cache = blocks.mamba_decode(p["mamba"], cfg, h, cache, pos)
        return x + y, cache
    if btype == "shared_attn":
        assert shared_body is not None
        inner = jnp.einsum("bsd,de->bse", h, p["in_adapter"])
        g = rms_norm(inner, shared_body["ln1"], cfg.norm_eps)
        a, cache = blocks.attention_decode(shared_body["attn"], cfg, g, cache, pos)
        inner = inner + a
        inner = inner + apply_mlp(
            shared_body["mlp"], rms_norm(inner, shared_body["ln2"], cfg.norm_eps)
        )
        return x + jnp.einsum("bsd,de->bse", inner, p["out_adapter"]), cache
    window = cfg.sliding_window if btype == "local_attn" else None
    if cfg.mla is not None:
        a, cache = blocks.mla_decode(p["attn"], cfg, h, cache, pos)
    else:
        a, cache = blocks.attention_decode(
            p["attn"], cfg, h, cache, pos, window=window
        )
    x = x + a
    x = x + _ffn(p, cfg, x)
    return x, cache


# --------------------------------------------------------------- cache init
def _block_cache_shape(cfg: ArchConfig, btype: str, batch: int, max_seq: int):
    """Abstract (shape, dtype) pytree for one block's cache."""
    hd = cfg.head_dim
    dt = jnp.bfloat16
    if btype == "mamba":
        ssm = cfg.ssm
        di = ssm.d_inner(cfg.d_model)
        tail = ssm.d_conv - 1
        return (
            jnp.zeros((batch, tail, di), dt),  # conv_x tail
            jnp.zeros((batch, tail, ssm.d_state), dt),  # conv_B tail
            jnp.zeros((batch, tail, ssm.d_state), dt),  # conv_C tail
            jnp.zeros(
                (batch, ssm.n_heads(cfg.d_model), ssm.head_dim, ssm.d_state),
                jnp.float32,
            ),
        )
    if cfg.mla is not None and btype in ("attn", "local_attn"):
        m = cfg.mla
        return (
            jnp.zeros((batch, max_seq, m.kv_lora_rank), dt),
            jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dt),
        )
    # sliding-window layers keep an O(window) RING buffer, not O(seq)
    # (1024× smaller for gemma3 locals at long_500k; see §Perf)
    seq = min(max_seq, cfg.sliding_window) if btype == "local_attn" else max_seq
    return (
        jnp.zeros((batch, cfg.n_kv_heads, seq, hd), dt),
        jnp.zeros((batch, cfg.n_kv_heads, seq, hd), dt),
    )


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> PyTree:
    """Decode caches for the whole stack: {unit: stacked, suffix: list}."""
    reps = cfg.resolved_pattern_repeats

    def unit_cache():
        return {
            f"b{i}": _block_cache_shape(cfg, bt, batch, max_seq)
            for i, bt in enumerate(cfg.block_pattern)
        }

    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), unit_cache()
    )
    suffix = [
        _block_cache_shape(cfg, bt, batch, max_seq) for bt in cfg.suffix_blocks
    ]
    return {"unit": stacked, "suffix": suffix}


# -------------------------------------------------------------------- model
def init_model(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> PyTree:
    reps = cfg.resolved_pattern_repeats
    k_embed, k_unit, k_suffix, k_shared, k_head, k_front = jax.random.split(key, 6)

    def init_unit(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        return {
            f"b{i}": _init_block(ks[i], cfg, bt, dtype)
            for i, bt in enumerate(cfg.block_pattern)
        }

    params: Dict[str, Any] = {
        "embed": {"tokens": embed_init(k_embed, cfg.vocab, cfg.d_model, dtype)},
        "layers": jax.vmap(init_unit)(jax.random.split(k_unit, reps)),
        "suffix": [
            _init_block(k, cfg, bt, dtype)
            for k, bt in zip(
                jax.random.split(k_suffix, max(len(cfg.suffix_blocks), 1)),
                cfg.suffix_blocks,
            )
        ],
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dtype)
    if "shared_attn" in cfg.block_pattern or "shared_attn" in cfg.suffix_blocks:
        params["shared_body"] = _init_shared_body(k_shared, cfg, dtype)
    if cfg.frontend == "vision_stub":
        params["vision_proj"] = dense_init(k_front, cfg.d_model, cfg.d_model, dtype)
    if cfg.enc_layers:
        params["encoder"] = _init_encoder(k_front, cfg, dtype)
        params["audio_proj"] = dense_init(k_front, cfg.d_model, cfg.d_model, dtype)
        # decoder cross-attention weights per decoder block
        kx = jax.random.split(k_front, reps)

        def init_cross(k):
            return {
                f"b{i}": {
                    "ln_x": jnp.zeros((cfg.d_model,), dtype),
                    "attn": blocks.init_attention(k, cfg, dtype),
                }
                for i in range(len(cfg.block_pattern))
            }

        params["cross"] = jax.vmap(init_cross)(kx)
    return params


def _init_encoder(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    def init_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": blocks.init_attention(k1, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
        }

    ks = jax.random.split(key, cfg.enc_layers)
    return {
        "layers": jax.vmap(init_layer)(ks),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
    }


# ----------------------------------------------------------------- forward
def _embed(cfg: ArchConfig, params, tokens: Array) -> Array:
    x = params["embed"]["tokens"][tokens]
    return shard(x, ("batch", "seq", "embed"))


def _unembed(cfg: ArchConfig, params, x: Array) -> Array:
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    w = (
        params["embed"]["tokens"].T
        if cfg.tie_embeddings
        else params["lm_head"]
    )
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32), w.astype(jnp.float32))
    return shard(logits, ("batch", "seq", "vocab"))


def backbone_forward(
    cfg: ArchConfig,
    params,
    x: Array,
    *,
    q_offset: int = 0,
    causal: bool = True,
    want_cache: bool = False,
    remat: bool = True,
    cross_ctx: Optional[Tuple[Array, Array]] = None,
):
    """Run the block program over embeddings ``x``.

    Returns (x, caches) where caches is None unless ``want_cache``.
    """
    shared_body = params.get("shared_body")
    pattern = cfg.block_pattern

    def unit_fn(h, unit_inputs):
        unit_p = unit_inputs["p"]
        caches_out = {}
        for i, bt in enumerate(pattern):
            h, c = _apply_block_full(
                unit_p[f"b{i}"], cfg, bt, h,
                shared_body=shared_body, q_offset=q_offset, causal=causal,
                want_cache=want_cache,
            )
            if cross_ctx is not None:
                h = _cross_attend(
                    unit_inputs["cross"][f"b{i}"], cfg, h, cross_ctx
                )
            if want_cache:
                caches_out[f"b{i}"] = c
        return h, (caches_out if want_cache else None)

    if remat == "dots":
        # save matmul outputs, recompute elementwise ops only — trades the
        # full-recompute tax (×4/3 step FLOPs) for modest extra residency
        body = jax.checkpoint(
            unit_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    elif remat:
        body = jax.checkpoint(unit_fn)
    else:
        body = unit_fn
    xs = {"p": params["layers"]}
    if cross_ctx is not None:
        xs["cross"] = params["cross"]
    x, unit_caches = jax.lax.scan(body, x, xs)

    suffix_caches = []
    for p_blk, bt in zip(params["suffix"], cfg.suffix_blocks):
        x, c = _apply_block_full(
            p_blk, cfg, bt, x,
            shared_body=shared_body, q_offset=q_offset, causal=causal,
            want_cache=want_cache,
        )
        suffix_caches.append(c)
    caches = (
        {"unit": unit_caches, "suffix": suffix_caches} if want_cache else None
    )
    return x, caches


def _cross_attend(pc: dict, cfg: ArchConfig, x: Array, ctx_kv) -> Array:
    """Cross-attention (whisper decoder): K/V precomputed from encoder."""
    k, v = ctx_kv
    h = rms_norm(x, pc["ln_x"], cfg.norm_eps)
    b, s, _ = h.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", h, pc["attn"]["wq"]).reshape(
        b, s, cfg.n_heads, hd
    ).transpose(0, 2, 1, 3)
    from .layers import chunked_attention

    out = chunked_attention(q, k, v, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return x + jnp.einsum("bsh,hd->bsd", out, pc["attn"]["wo"])


def forward_hidden(
    cfg: ArchConfig,
    params,
    tokens: Array,
    *,
    extra: Optional[Dict[str, Array]] = None,
    remat: bool = True,
) -> Array:
    """Train-mode forward up to the final norm (no unembedding)."""
    x = _embed(cfg, params, tokens)
    cross_ctx = None
    if cfg.frontend == "vision_stub":
        vis = jnp.einsum(
            "bnd,de->bne", extra["patch_embeds"], params["vision_proj"]
        ).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        x = shard(x, ("batch", "seq", "embed"))
    if cfg.enc_layers:
        enc_out = encoder_forward(cfg, params, extra["frame_embeds"], remat=remat)
        cross_ctx = _encode_cross_kv(cfg, params, enc_out)
    x, _ = backbone_forward(cfg, params, x, remat=remat, cross_ctx=cross_ctx)
    return rms_norm(x, params["final_ln"], cfg.norm_eps)


def forward(
    cfg: ArchConfig,
    params,
    tokens: Array,
    *,
    extra: Optional[Dict[str, Array]] = None,
    remat: bool = True,
) -> Array:
    """Train-mode forward → logits [B, S(+vision), vocab]."""
    x = forward_hidden(cfg, params, tokens, extra=extra, remat=remat)
    w = params["embed"]["tokens"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32), w.astype(jnp.float32))
    return shard(logits, ("batch", "seq", "vocab"))


def encoder_forward(cfg: ArchConfig, params, frames: Array, *, remat=True) -> Array:
    """Whisper encoder over (stub) frame embeddings [B, T, d]."""
    enc = params["encoder"]
    x = jnp.einsum("btd,de->bte", frames, params["audio_proj"]).astype(
        params["audio_proj"].dtype
    )
    x = shard(x, ("batch", "seq", "embed"))

    def layer_fn(h, p):
        a = blocks.attention_forward(
            p["attn"], cfg, rms_norm(h, p["ln1"], cfg.norm_eps), causal=False
        )
        h = h + a
        h = h + apply_mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))
        return h, None

    body = jax.checkpoint(layer_fn) if remat else layer_fn
    x, _ = jax.lax.scan(body, x, enc["layers"])
    return rms_norm(x, enc["final_ln"], cfg.norm_eps)


def _encode_cross_kv(cfg: ArchConfig, params, enc_out: Array):
    """Precompute cross-attention K/V from encoder output (first block's
    weights; K/V are shared across decoder layers in this implementation —
    an adaptation noted in DESIGN.md)."""
    pc = jax.tree_util.tree_map(lambda x: x[0], params["cross"])["b0"]
    b, t, _ = enc_out.shape
    hd = cfg.head_dim
    k = jnp.einsum("btd,dh->bth", enc_out, pc["attn"]["wk"]).reshape(
        b, t, cfg.n_kv_heads, hd
    ).transpose(0, 2, 1, 3)
    v = jnp.einsum("btd,dh->bth", enc_out, pc["attn"]["wv"]).reshape(
        b, t, cfg.n_kv_heads, hd
    ).transpose(0, 2, 1, 3)
    return k, v


# ------------------------------------------------------------------ decode
def prefill(
    cfg: ArchConfig,
    params,
    tokens: Array,
    *,
    extra: Optional[Dict[str, Array]] = None,
    max_seq: Optional[int] = None,
    remat: bool = True,
):
    """Prefill: forward + emit KV caches padded to ``max_seq``."""
    b, s = tokens.shape
    max_seq = max_seq or s
    x = _embed(cfg, params, tokens)
    cross_ctx = None
    if cfg.frontend == "vision_stub":
        vis = jnp.einsum(
            "bnd,de->bne", extra["patch_embeds"], params["vision_proj"]
        ).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.enc_layers:
        enc_out = encoder_forward(cfg, params, extra["frame_embeds"], remat=remat)
        cross_ctx = _encode_cross_kv(cfg, params, enc_out)
    x, caches = backbone_forward(
        cfg, params, x, want_cache=True, remat=remat, cross_ctx=cross_ctx
    )
    logits = _unembed(cfg, params, x[:, -1:])
    caches = _pad_caches(cfg, caches, max_seq)
    if cross_ctx is not None:
        caches["cross_kv"] = cross_ctx
    return logits, caches


def _pad_caches(cfg: ArchConfig, caches, max_seq: int):
    """Pad prefill K/V (seq axis) out to the decode cache size.

    Unit caches carry a leading scan (repeats) dim; suffix caches don't —
    the seq axis is uniformly ``ndim − 2`` for both K/V and MLA latents.
    """

    def pad_seq(x):
        axis = x.ndim - 2
        pad_n = max_seq - x.shape[axis]
        if pad_n <= 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad_n)
        return jnp.pad(x, widths)

    def to_ring(x):
        """Fold a full prefill K/V (seq axis) into the ring layout: slot j
        holds the last prefill position p < S with p % window == j."""
        w = cfg.sliding_window
        axis = x.ndim - 2
        s = x.shape[axis]
        if s <= w:
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, w - s)
            return jnp.pad(x, widths)  # slot j == position j (not wrapped)
        j = jnp.arange(w)
        idx = (s - 1) - ((s - 1 - j) % w)
        return jnp.take(x, idx, axis=axis)

    def pad_kv(c, bt):
        if c is None:
            return None
        if bt == "mamba":
            return c  # conv/ssm states have no seq axis
        if bt == "local_attn" and cfg.mla is None:
            return jax.tree_util.tree_map(to_ring, c)
        return jax.tree_util.tree_map(pad_seq, c)

    unit = {
        f"b{i}": pad_kv(caches["unit"][f"b{i}"], bt)
        for i, bt in enumerate(cfg.block_pattern)
    }
    suffix = [
        pad_kv(c, bt) for c, bt in zip(caches["suffix"], cfg.suffix_blocks)
    ]
    return {"unit": unit, "suffix": suffix}


def decode_step(
    cfg: ArchConfig,
    params,
    tokens: Array,  # [B, 1]
    caches,
    pos: Array,  # scalar int32 — current position
):
    """One decode step; returns (logits [B,1,V], updated caches)."""
    x = _embed(cfg, params, tokens)
    shared_body = params.get("shared_body")
    pattern = cfg.block_pattern
    cross_kv = caches.get("cross_kv")

    def unit_fn(h, inputs):
        unit_p, unit_c = inputs["p"], inputs["c"]
        new_c = {}
        for i, bt in enumerate(pattern):
            h, c = _apply_block_decode(
                unit_p[f"b{i}"], cfg, bt, h, unit_c[f"b{i}"], pos,
                shared_body=shared_body,
            )
            if cross_kv is not None:
                h = _cross_attend(inputs["cross"][f"b{i}"], cfg, h, cross_kv)
            new_c[f"b{i}"] = c
        return h, new_c

    xs = {"p": params["layers"], "c": caches["unit"]}
    if cross_kv is not None:
        xs["cross"] = params["cross"]
    x, new_unit = jax.lax.scan(unit_fn, x, xs)

    new_suffix = []
    for p_blk, c_blk, bt in zip(
        params["suffix"], caches["suffix"], cfg.suffix_blocks
    ):
        x, c = _apply_block_decode(
            p_blk, cfg, bt, x, c_blk, pos, shared_body=shared_body
        )
        new_suffix.append(c)

    logits = _unembed(cfg, params, x)
    new_caches = {"unit": new_unit, "suffix": new_suffix}
    if cross_kv is not None:
        new_caches["cross_kv"] = cross_kv
    return logits, new_caches


# ------------------------------------------------------- paged decode (pool)
def paged_decode_supported(cfg: ArchConfig) -> bool:
    """True when the whole stack is plain full attention — the layout the
    paged-decode Pallas kernel serves.  MLA latents, Mamba states,
    encoder-decoder cross-attention and sliding-window rings keep their
    own cache shapes and stay on the dense vmapped path."""
    stack = list(cfg.block_pattern) + list(cfg.suffix_blocks)
    return (
        cfg.mla is None
        and cfg.ssm is None
        and not cfg.enc_layers
        and cfg.frontend in (None, "none")
        and bool(stack)
        and all(bt == "attn" for bt in stack)
    )


def _quantize_pool_int8(pool: Array):
    """Per-page absmax int8 quantization of a ``[n, P, hd]`` pool view:
    returns (codes int8, scales f32 [n]) in the layout
    :func:`kernels.ops.paged_decode_attention_int8` consumes.  The scale
    floor keeps all-zero (never-written pad) pages from dividing by 0."""
    absmax = jnp.max(jnp.abs(pool), axis=(1, 2))
    scales = jnp.maximum(absmax / 127.0, 1e-8).astype(jnp.float32)
    codes = jnp.round(pool / scales[:, None, None]).astype(jnp.int8)
    return codes, scales


def decode_step_paged(
    cfg: ArchConfig,
    params,
    tokens: Array,  # [B, 1] — compacted active rows (B may be padded)
    caches,  # the engine's per-slot dense caches (slot axis = n_slots)
    poss: Array,  # [B] int32 per-row decode position
    row_slot: Array,  # [B] int32 slot of each row; n_slots for pad rows
    page_table: Array,  # [B, W] int32 pool page ids (width-trimmed)
    seq_lens: Array,  # [B] int32 tokens to attend (pos+1; 0 for pad rows)
    page_src_slot: Array,  # [n_pool] int32 owning slot of each pool page
    page_src_idx: Array,  # [n_pool] int32 logical page index in that slot
    *,
    page_tokens: int,
    n_pool: int,
    interpret: bool,
    int8: bool = False,
):
    """One decode step through :func:`kernels.ops.paged_decode_attention`.

    With ``int8=True`` the gathered pool views are absmax-quantized per
    page row and attention runs through
    :func:`kernels.ops.paged_decode_attention_int8` instead — the f32
    kernel stays available as the differential oracle (``int8=False``).

    The per-slot dense caches remain the storage of truth (COW, tier
    promotion and migration all operate on them); this step materializes
    the *pool view* the kernel wants by gathering each live pool page from
    its owning slot via the provenance arrays, then runs ONE kernel call
    per layer with the kv-head axis folded into the page axis:

        pool row of (kv head g, page pid) = g · n_pool + pid
        table row of (request b, q head h) = table[b] + (h // G) · n_pool

    so a [B, W] block table becomes [B·H, W] and the whole active batch is
    a single (B·H, W) grid.  Rows are expected sorted by length
    (descending) and W trimmed to the longest resident request — short
    decodes then never pay DMAs for the long tail.  New-token K/V are
    scatter-written into the slot caches *before* the gather (matching the
    dense path, which attends positions ``<= pos`` inclusive); pad rows
    carry ``row_slot == n_slots`` so their writes drop out-of-bounds.
    Returns (logits [B, 1, V], updated caches).
    """
    from repro.kernels import ops as kernel_ops

    x = _embed(cfg, params, tokens)
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    P = page_tokens

    # per-q-head rows of the folded table: identical for every layer
    hoff = (jnp.arange(H, dtype=jnp.int32) // G) * n_pool
    table_flat = (
        jnp.repeat(page_table.astype(jnp.int32), H, axis=0)
        + jnp.tile(hoff, B)[:, None]
    )
    lens_flat = jnp.repeat(seq_lens.astype(jnp.int32), H)
    positions = poss[:, None, None]  # [..., s] with s == 1

    def attn_block(p, x_in, cache):
        h = rms_norm(x_in, p["ln1"], cfg.norm_eps)
        ap = p["attn"]
        q, k_new, v_new = blocks._qkv(ap, cfg, h)  # [B, {H,KV}, 1, hd]
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
        kc, vc = cache  # [n_slots, KV, max_seq, hd]
        kc = kc.at[row_slot, :, poss].set(k_new[:, :, 0, :], mode="drop")
        vc = vc.at[row_slot, :, poss].set(v_new[:, :, 0, :], mode="drop")
        # pool view: pad seq to whole pages, gather page provenance
        n_slots, _, max_seq, _ = kc.shape
        lp = -(-max_seq // P)
        pad = lp * P - max_seq
        kcp = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else kc
        vcp = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else vc
        kcr = kcp.reshape(n_slots, KV, lp, P, hd)
        vcr = vcp.reshape(n_slots, KV, lp, P, hd)
        k_pool = kcr[page_src_slot, :, page_src_idx]  # [n_pool, KV, P, hd]
        v_pool = vcr[page_src_slot, :, page_src_idx]
        k_pool = k_pool.transpose(1, 0, 2, 3).reshape(KV * n_pool, P, hd)
        v_pool = v_pool.transpose(1, 0, 2, 3).reshape(KV * n_pool, P, hd)
        qf = q[:, :, 0, :].reshape(B * H, hd)
        if int8:
            k_codes, k_scales = _quantize_pool_int8(k_pool)
            v_codes, v_scales = _quantize_pool_int8(v_pool)
            out = kernel_ops.paged_decode_attention_int8(
                qf, k_codes, v_codes, k_scales, v_scales,
                table_flat, lens_flat, interpret=interpret,
            )
        else:
            out = kernel_ops.paged_decode_attention(
                qf, k_pool, v_pool, table_flat, lens_flat,
                interpret=interpret,
            )
        out = out.reshape(B, 1, H * hd)
        y = jnp.einsum("bsh,hd->bsd", out, ap["wo"])
        x_out = x_in + y
        x_out = x_out + _ffn(p, cfg, x_out)
        return x_out, (kc, vc)

    def unit_fn(h, inputs):
        unit_p, unit_c = inputs["p"], inputs["c"]
        new_c = {}
        for i in range(len(cfg.block_pattern)):
            h, c = attn_block(unit_p[f"b{i}"], h, unit_c[f"b{i}"])
            new_c[f"b{i}"] = c
        return h, new_c

    x, new_unit = jax.lax.scan(
        unit_fn, x, {"p": params["layers"], "c": caches["unit"]}
    )
    new_suffix = []
    for p_blk, c_blk in zip(params["suffix"], caches["suffix"]):
        x, c = attn_block(p_blk, x, c_blk)
        new_suffix.append(c)

    logits = _unembed(cfg, params, x)
    return logits, {"unit": new_unit, "suffix": new_suffix}
