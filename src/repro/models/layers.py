"""Model primitives: norms, RoPE, chunked attention, gated MLP.

Pure-JAX (jnp + lax) implementations designed for:
  * scan-over-layers stacking (init fns are vmap-able),
  * SPMD sharding via activation-constraint hooks (repro.dist.sharding),
  * O(S) attention memory through query-block chunking with online softmax
    (the jnp baseline of the Pallas flash kernel in repro.kernels).

Weights live in bf16 by default; all reductions (norm, softmax, logits) run
in f32.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard as _shard

Array = jax.Array

# ---------------------------------------------------------- grad boundaries
@jax.custom_vjp
def bf16_grad(x: Array) -> Array:
    """Identity forward; casts the COTANGENT to bf16 on the way back.

    Placed at residual-stream block boundaries: activation gradients between
    blocks stay bf16 (standard mixed precision), which halves every
    tensor-parallel activation-grad all-reduce — measured 512→256 MiB per
    reduction on zamba2-train (EXPERIMENTS.md §Perf-2 follow-up).  Weight
    gradients and optimizer math remain f32.
    """
    return x


def _bf16_grad_fwd(x):
    return x, None


def _bf16_grad_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


bf16_grad.defvjp(_bf16_grad_fwd, _bf16_grad_bwd)


# --------------------------------------------------------------------- init
def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.bfloat16) -> Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(
        dtype
    )


def embed_init(key, vocab: int, dim: int, dtype=jnp.bfloat16) -> Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# -------------------------------------------------------------------- norms
def rms_norm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
NEG_INF = -1e30


def _repeat_kv(k: Array, groups: int) -> Array:
    """[B, kv, S, hd] → [B, kv*groups, S, hd] (GQA head replication)."""
    if groups == 1:
        return k
    b, kv, s, hd = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, kv, groups, s, hd)).reshape(
        b, kv * groups, s, hd
    )


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    chunk: int = 512,
) -> Array:
    """Flash-style attention: scan over query blocks with full-K lazily
    masked logits — peak memory O(chunk × S) instead of O(S²).

    q: [B, H, Sq, hd]; k, v: [B, KV, Sk, hd] (KV heads repeated to H here).
    ``q_offset`` is the absolute position of q[..., 0, :] (prefill chunking /
    decode).  ``window`` enables sliding-window (local) masking.
    """
    b, h, sq, hd = q.shape
    kv_heads = k.shape[1]
    groups = h // kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    sk = k.shape[2]
    v_dim = v.shape[-1]  # may differ from hd (MLA)
    scale = 1.0 / math.sqrt(hd)

    chunk = min(chunk, sq)
    n_chunks = (sq + chunk - 1) // chunk
    pad = n_chunks * chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qs = q.reshape(b, h, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)

    k_pos = jnp.arange(sk)

    def body(carry, inputs):
        idx, q_blk = inputs  # q_blk: [B, H, chunk, hd]
        logits = (
            jnp.einsum(
                "bhqd,bhkd->bhqk", q_blk.astype(jnp.float32), k.astype(jnp.float32)
            )
            * scale
        )
        q_pos = q_offset + idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, sk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
        return carry, out.astype(v.dtype)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qs))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, n_chunks * chunk, v_dim)
    if pad:
        out = out[:, :, :sq]
    return out


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    cur_pos: Array,
    *,
    window: Optional[int] = None,
) -> Array:
    """Single-token attention over a KV cache.

    q: [B, H, 1, hd]; k_cache/v_cache: [B, KV, S, hd]; cur_pos: scalar int —
    number of valid cache entries (the new token attends to [0, cur_pos]).
    """
    b, h, _, hd = q.shape
    kv_heads = k_cache.shape[1]
    k = _repeat_kv(k_cache, h // kv_heads)
    v = _repeat_kv(v_cache, h // kv_heads)
    sk = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    logits = (
        jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    k_pos = jnp.arange(sk)
    mask = k_pos[None, :] <= cur_pos
    if window is not None:
        mask &= k_pos[None, :] > (cur_pos - window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ring(
    q: Array,
    k_ring: Array,
    v_ring: Array,
    cur_pos: Array,
    window: int,
) -> Array:
    """Sliding-window decode over a RING-BUFFER cache of size ``window``.

    Slot ``i`` holds the key whose absolute position is the largest
    ``p ≤ cur_pos`` with ``p % window == i``; keys carry RoPE applied at
    their absolute position, so attention is order-agnostic given the mask:
    a slot is valid iff its absolute position is ≥ 0 and ≥ cur_pos−window+1
    (the latter holds by construction once the ring has wrapped).
    The cache is O(window) instead of O(seq) — 1024× smaller for gemma3's
    local layers at long_500k.
    """
    b, h, _, hd = q.shape
    kv_heads = k_ring.shape[1]
    k = _repeat_kv(k_ring, h // kv_heads)
    v = _repeat_kv(v_ring, h // kv_heads)
    scale = 1.0 / math.sqrt(hd)
    logits = (
        jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    slots = jnp.arange(window)
    abs_pos = cur_pos - ((cur_pos - slots) % window)  # [window]
    mask = (abs_pos >= 0) & (abs_pos <= cur_pos)
    logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------- MLP
def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_mlp(params: dict, x: Array) -> Array:
    gate = jnp.einsum("bsd,df->bsf", x, params["gate"])
    up = jnp.einsum("bsd,df->bsf", x, params["up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = _shard(h, ("batch", "seq", "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, params["down"])
