"""Documentation consistency gate: links, § references, coverage.

CI's ``docs`` job runs this on every push.  Eight checks, all cheap and
all hard failures:

1. **Relative links resolve.**  Every ``[text](path)`` in the repo's
   markdown whose target is a relative path (optionally with a
   ``#fragment``) must point at an existing file or directory.
   External URLs and pure in-page anchors are skipped.

2. **§ references resolve.**  Markdown prose leans on ``DESIGN.md``
   section numbers ("see §7", "DESIGN.md §11").  Every ``§N`` cited in
   a markdown file must correspond to an actual ``## N.`` header in
   DESIGN.md — a renumbering that orphans citations fails here, not in
   a reviewer's head.  (``§II``-style Roman numerals cite the *paper*
   and are exempt; ranges like ``§§2–8`` check both endpoints.)

3. **Docstring coverage floor.**  Every public module, class, and
   public method/function under ``repro.serve`` and
   ``repro.checkpoint`` must carry a docstring — the two packages the
   operations guide documents.  Parsed with ``ast`` (no imports, no
   jax): underscore names, dunders except ``__init__``'s class, and
   nested function bodies are exempt.

4. **BENCH_serve.json keys are documented.**  Every leaf metric name in
   the committed ``BENCH_baseline.json`` (same shape the live record
   has) must appear in ``docs/OPERATIONS.md`` — a new benchmark key
   without operator documentation fails the gate that merges it.

5. **Every architecture config is classified in DESIGN.md §12.**  Each
   module under ``src/repro/configs/`` (``__init__.py`` aside) must be
   named in the §12 memory-class table/prose — adding an architecture
   without declaring where it sits in the class taxonomy fails here.

6. **model_zoo bench keys are documented.**  The heterogeneous-fleet
   leg must exist in the baseline and every leaf key under its
   ``model_zoo`` section must appear in ``docs/OPERATIONS.md`` — the
   leg's gate bits are correctness claims, so undocumented keys are a
   harder smell here than elsewhere (check 4 already covers the rest).

7. **Every PageClass member is placed in DESIGN.md §6.**  The
   lifetime-class enum in ``repro/serve/ledger.py`` is the code form
   of the §6 taxonomy; each member's value string must appear in that
   section — an enum member the docs don't classify fails here.

8. **memory bench keys are documented.**  The class-stamped ledger leg
   must exist in the baseline and every leaf key under its ``memory``
   section must appear in ``docs/OPERATIONS.md`` (the leg carries the
   ``ledger_matches_recount`` correctness bit).

Usage::

    python tools/check_docs.py [--root .]
"""

import argparse
import ast
import json
import os
import re
import sys

MARKDOWN_FILES = (
    "README.md",
    "DESIGN.md",
    "ROADMAP.md",
    "docs/OPERATIONS.md",
)

#: packages under the docstring-coverage floor (src/-relative)
COVERED_PACKAGES = ("src/repro/serve", "src/repro/checkpoint")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_REF_RE = re.compile(r"§§?(\d+)(?:[–-](\d+))?")
DESIGN_HEADER_RE = re.compile(r"^## (\d+)\.", re.MULTILINE)


def _strip_code_blocks(text: str) -> str:
    """Fenced code blocks may contain ``](`` sequences and § examples
    that are not prose citations."""
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def check_links(root: str) -> list:
    errors = []
    for md in MARKDOWN_FILES:
        path = os.path.join(root, md)
        if not os.path.exists(path):
            continue
        text = _strip_code_blocks(open(path, encoding="utf-8").read())
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel)
            )
            if not os.path.exists(resolved):
                errors.append(f"{md}: broken relative link -> {target}")
    return errors


def check_section_refs(root: str) -> list:
    design = open(
        os.path.join(root, "DESIGN.md"), encoding="utf-8"
    ).read()
    known = {int(n) for n in DESIGN_HEADER_RE.findall(design)}
    errors = []
    for md in MARKDOWN_FILES:
        path = os.path.join(root, md)
        if not os.path.exists(path):
            continue
        text = _strip_code_blocks(open(path, encoding="utf-8").read())
        for lineno, line in enumerate(text.splitlines(), 1):
            for m in SECTION_REF_RE.finditer(line):
                cited = {int(m.group(1))}
                if m.group(2):
                    cited.add(int(m.group(2)))
                for n in cited - known:
                    errors.append(
                        f"{md}:{lineno}: cites §{n} but DESIGN.md has "
                        f"no '## {n}.' header"
                    )
    return errors


def _missing_docstrings(path: str, modname: str) -> list:
    tree = ast.parse(open(path, encoding="utf-8").read(), filename=path)
    missing = []
    if ast.get_docstring(tree) is None:
        missing.append(f"{modname}: module docstring")

    def public(name: str) -> bool:
        return not name.startswith("_") or name == "__init__"

    for node in tree.body:
        if isinstance(node, ast.ClassDef) and public(node.name):
            if ast.get_docstring(node) is None:
                missing.append(f"{modname}.{node.name}: class docstring")
            for sub in node.body:
                if (
                    isinstance(
                        sub, (ast.FunctionDef, ast.AsyncFunctionDef)
                    )
                    and public(sub.name)
                    and sub.name != "__init__"
                    and ast.get_docstring(sub) is None
                    # a @property forwarding one attribute documents
                    # itself; still require docstrings on real logic
                    and len(sub.body) > 1
                ):
                    missing.append(
                        f"{modname}.{node.name}.{sub.name}: docstring"
                    )
        elif isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef)
        ) and public(node.name):
            if ast.get_docstring(node) is None:
                missing.append(f"{modname}.{node.name}: docstring")
    return missing


def check_docstrings(root: str) -> list:
    errors = []
    for pkg in COVERED_PACKAGES:
        base = os.path.join(root, pkg)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, os.path.join(root, "src"))
                modname = rel[:-3].replace(os.sep, ".")
                errors.extend(_missing_docstrings(path, modname))
    return errors


def _leaf_keys(obj, out):
    if isinstance(obj, dict):
        for k, v in obj.items():
            if isinstance(v, dict):
                _leaf_keys(v, out)
            else:
                out.add(str(k))
    return out


#: leaf keys that are run parameters / derived micro-detail, not
#: operator-facing metrics (kernel microbench cells are shape-keyed and
#: documented as a pattern, not per-cell)
DOC_EXEMPT = re.compile(
    r"^(arch|debug|seed|n_requests|n_arrivals|horizon_ticks|"
    r"service_mode|hbm_capacity_tokens|b\d+_p\d+|us_per_call|max_err|"
    r"interpret|mean_s|min_s|max_s|source|distinct|paged_decode_ticks|"
    # smoke-config arch names key the model_zoo fleet/per_model maps —
    # the pattern is documented, not each generated name
    r"[a-z0-9_.\-]+-smoke)$"
)


def check_bench_keys(root: str) -> list:
    bench_path = os.path.join(root, "BENCH_baseline.json")
    ops_path = os.path.join(root, "docs", "OPERATIONS.md")
    if not os.path.exists(bench_path):
        return [f"missing {bench_path} (commit the benchmark baseline)"]
    if not os.path.exists(ops_path):
        return ["missing docs/OPERATIONS.md"]
    record = json.load(open(bench_path, encoding="utf-8"))
    ops = open(ops_path, encoding="utf-8").read()
    errors = []
    for key in sorted(_leaf_keys(record, set())):
        if DOC_EXEMPT.match(key):
            continue
        if key not in ops:
            errors.append(
                f"BENCH_serve.json key '{key}' is not documented in "
                "docs/OPERATIONS.md"
            )
    return errors


def check_configs_in_design(root: str) -> list:
    """Every architecture config module must be placed in the DESIGN.md
    §12 memory-class taxonomy by filename."""
    design_path = os.path.join(root, "DESIGN.md")
    design = open(design_path, encoding="utf-8").read()
    m = re.search(r"^## 12\..*?(?=^## |\Z)", design, re.MULTILINE | re.DOTALL)
    if not m:
        return [
            "DESIGN.md has no '## 12.' section "
            "(architecture memory classes)"
        ]
    section = m.group(0)
    cfg_dir = os.path.join(root, "src", "repro", "configs")
    errors = []
    for fn in sorted(os.listdir(cfg_dir)):
        if not fn.endswith(".py") or fn == "__init__.py":
            continue
        if fn not in section:
            errors.append(
                f"configs/{fn} is not classified in DESIGN.md §12"
            )
    return errors


def check_page_classes(root: str) -> list:
    """Every :class:`PageClass` member must be named (by its value
    string) in the DESIGN.md §6 lifetime-class section — the enum is
    the code form of that taxonomy, and a member the docs don't place
    is an unclassified lifetime."""
    design_path = os.path.join(root, "DESIGN.md")
    ledger_path = os.path.join(
        root, "src", "repro", "serve", "ledger.py"
    )
    design = open(design_path, encoding="utf-8").read()
    m = re.search(r"^## 6\..*?(?=^## |\Z)", design, re.MULTILINE | re.DOTALL)
    if not m:
        return ["DESIGN.md has no '## 6.' section (lifetime classes)"]
    section = m.group(0)
    tree = ast.parse(open(ledger_path, encoding="utf-8").read())
    members = []
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == "PageClass":
            for sub in node.body:
                if (
                    isinstance(sub, ast.Assign)
                    and isinstance(sub.value, ast.Constant)
                    and isinstance(sub.value.value, str)
                ):
                    members.append(sub.value.value)
    if not members:
        return ["repro/serve/ledger.py defines no PageClass members"]
    errors = []
    for value in members:
        if value not in section:
            errors.append(
                f"PageClass member '{value}' is not named in the "
                "DESIGN.md §6 lifetime-class section"
            )
    return errors


def check_memory_keys(root: str) -> list:
    """The class-stamped ledger leg must exist in the baseline and every
    leaf key under ``memory`` must be documented in OPERATIONS.md — the
    leg carries the ``ledger_matches_recount`` correctness bit, so its
    keys are operator-facing by construction."""
    bench_path = os.path.join(root, "BENCH_baseline.json")
    ops_path = os.path.join(root, "docs", "OPERATIONS.md")
    if not os.path.exists(bench_path):
        return [f"missing {bench_path} (commit the benchmark baseline)"]
    if not os.path.exists(ops_path):
        return ["missing docs/OPERATIONS.md"]
    record = json.load(open(bench_path, encoding="utf-8"))
    mem = record.get("memory")
    if not isinstance(mem, dict):
        return [
            "BENCH_baseline.json has no 'memory' section — the "
            "class-stamped ledger leg did not run (or the baseline "
            "predates it); refresh the baseline"
        ]
    ops = open(ops_path, encoding="utf-8").read()
    errors = []
    for key in sorted(_leaf_keys(mem, set())):
        if DOC_EXEMPT.match(key):
            continue
        if key not in ops:
            errors.append(
                f"memory bench key '{key}' is not documented in "
                "docs/OPERATIONS.md"
            )
    return errors


def check_model_zoo_keys(root: str) -> list:
    """The heterogeneous-fleet leg must exist in the baseline and every
    leaf key under ``model_zoo`` must be documented in OPERATIONS.md."""
    bench_path = os.path.join(root, "BENCH_baseline.json")
    ops_path = os.path.join(root, "docs", "OPERATIONS.md")
    if not os.path.exists(bench_path):
        return [f"missing {bench_path} (commit the benchmark baseline)"]
    if not os.path.exists(ops_path):
        return ["missing docs/OPERATIONS.md"]
    record = json.load(open(bench_path, encoding="utf-8"))
    mz = record.get("model_zoo")
    if not isinstance(mz, dict):
        return [
            "BENCH_baseline.json has no 'model_zoo' section — the "
            "heterogeneous-fleet leg did not run (or the baseline "
            "predates it); refresh the baseline"
        ]
    ops = open(ops_path, encoding="utf-8").read()
    errors = []
    for key in sorted(_leaf_keys(mz, set())):
        if DOC_EXEMPT.match(key):
            continue
        if key not in ops:
            errors.append(
                f"model_zoo bench key '{key}' is not documented in "
                "docs/OPERATIONS.md"
            )
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=".")
    args = ap.parse_args(argv)
    checks = (
        ("relative links", check_links),
        ("§ references", check_section_refs),
        ("docstring coverage", check_docstrings),
        ("bench-key documentation", check_bench_keys),
        ("configs classified in DESIGN.md §12", check_configs_in_design),
        ("model_zoo keys documented", check_model_zoo_keys),
        ("PageClass members in DESIGN.md §6", check_page_classes),
        ("memory keys documented", check_memory_keys),
    )
    failed = False
    for name, fn in checks:
        errors = fn(args.root)
        if errors:
            failed = True
            print(f"FAIL {name} ({len(errors)}):", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
        else:
            print(f"ok   {name}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
