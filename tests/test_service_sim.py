"""Integration tests: the service executor + paper workloads end-to-end."""

import pytest

from repro.sched import MursConfig
from repro.core.spark_sim import (
    make_grep,
    make_pr,
    make_sort,
    make_wc,
    run_batch,
    run_service,
)


@pytest.fixture(scope="module")
def fair_run():
    return run_service(
        [make_sort(), make_wc(), make_grep()], heap_gb=6.0, oom_is_fatal=False
    )


@pytest.fixture(scope="module")
def murs_run():
    return run_service(
        [make_sort(), make_wc(), make_grep()],
        heap_gb=6.0,
        murs=MursConfig(),
        oom_is_fatal=False,
    )


class TestServiceExecution:
    def test_all_jobs_complete(self, fair_run):
        for jm in fair_run.jobs.values():
            assert jm.finish_time > 0

    def test_gc_happens_under_pressure(self, fair_run):
        assert fair_run.minor_gcs + fair_run.full_gcs > 0
        assert fair_run.total_gc_time > 0

    def test_murs_all_jobs_complete_no_starvation(self, murs_run):
        """§VI-D: FIFO resume prevents starvation — every job finishes."""
        for jm in murs_run.jobs.values():
            assert jm.finish_time > 0, f"{jm.job_id} starved"

    def test_murs_suspends_under_pressure(self, murs_run):
        assert murs_run.suspensions > 0

    def test_murs_improves_light_jobs(self, fair_run, murs_run):
        """The paper's core claim: light tasks complete quickly under MURS."""
        light_fair = fair_run.jobs["grep"].exec_time
        light_murs = murs_run.jobs["grep"].exec_time
        assert light_murs < light_fair

    def test_murs_reduces_gc_of_light_jobs(self, fair_run, murs_run):
        assert murs_run.jobs["grep"].gc_time <= fair_run.jobs["grep"].gc_time
        assert murs_run.jobs["wc"].gc_time <= fair_run.jobs["wc"].gc_time

    def test_murs_does_not_increase_spills(self, fair_run, murs_run):
        f = sum(j.spills for j in fair_run.jobs.values())
        m = sum(j.spills for j in murs_run.jobs.values())
        assert m <= f


class TestBatchVsService:
    def test_service_mode_hurts_light_jobs(self):
        """Motivation (Fig 1): WC suffers PR's pressure in service mode."""
        service = run_service(
            [make_pr(), make_wc()], heap_gb=15.0, oom_is_fatal=False
        )
        batch = run_batch([make_wc()], heap_gb=15.0)
        wc_service = service.jobs["wc"].exec_time
        wc_batch = batch["wc"].jobs["wc"].exec_time
        assert wc_service > wc_batch * 1.2

    def test_batch_runs_isolated(self):
        batch = run_batch([make_grep(), make_wc()], heap_gb=8.0)
        assert set(batch) == {"grep", "wc"}
        for jid, m in batch.items():
            assert m.jobs[jid].finish_time > 0


class TestWorkloadShapes:
    def test_stage_structure(self):
        assert len(make_grep().stages) == 1
        assert len(make_wc().stages) == 2
        assert len(make_sort().stages) == 3
        assert len(make_pr(iterations=5).stages) == 6

    def test_pr_task_count_matches_paper(self):
        """Table III: PR = 1500 tasks cluster-wide → ~372 per executor."""
        pr = make_pr()
        n = sum(len(s) for s in pr.stages)
        assert 300 <= n <= 400

    def test_wc_task_count_matches_paper(self):
        wc = make_wc()
        n = sum(len(s) for s in wc.stages)
        assert n == 250  # 1000 / 4 executors


class TestExecutorFuzzLiveness:
    """Property: for ANY workload mix and heap size, the MURS executor makes
    progress and never starves a job (unless the run genuinely OOMs)."""

    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(
        n_jobs=st.integers(1, 3),
        heap_gb=st.floats(4.0, 20.0),
        rate=st.floats(0.2, 4.0),
        agg=st.booleans(),
    )
    @settings(max_examples=10, deadline=None)
    def test_all_jobs_finish_or_oom(self, n_jobs, heap_gb, rate, agg):
        from repro.sched import MursConfig
        from repro.core.service import JobSpec, ServiceExecutor
        from repro.core.tasks import ApiProfile, Phase, make_stage_tasks
        from repro.core.usage_models import UsageModel

        api = ApiProfile(
            "fuzz",
            UsageModel.SUB_LINEAR if agg else UsageModel.LINEAR,
            rate=rate,
            garbage_per_byte=1.5,
        )
        ex = ServiceExecutor(
            cores=8, heap_bytes=heap_gb * 1e9, murs=MursConfig(),
            dt=0.1, max_time=4000.0, oom_is_fatal=False,
        )
        for j in range(n_jobs):
            tasks = make_stage_tasks(
                f"job{j}", 0, n_tasks=12, stage_input_bytes=1.5e9,
                phases=[Phase("read", api, 1.0)], skew=0.3,
            )
            ex.submit(JobSpec(f"job{j}", [tasks]))
        m = ex.run()
        if not m.oom:
            for jm in m.jobs.values():
                assert jm.finish_time > 0, "liveness: job starved"
        # the pool accounting never goes negative
        assert m.peak_pool_used_fraction >= 0.0
