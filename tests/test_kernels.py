"""Pallas kernel validation: hypothesis shape/dtype sweeps vs ref oracles.

All kernels run in interpret mode on CPU (the kernel body executes in
Python); assert_allclose against the pure-jnp oracle in ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
    def test_modes(self, dtype, causal, window):
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        q = _rand(k1, (2, 128, 64), dtype)
        k = _rand(k2, (2, 128, 64), dtype)
        v = _rand(k3, (2, 128, 64), dtype)
        out = ops.flash_attention(
            q, k, v, causal=causal, window=window, block_q=64, block_k=64
        )
        gold = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(gold, np.float32),
            atol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
        )

    @given(
        sq_blocks=st.integers(1, 4),
        sk_blocks=st.integers(1, 4),
        hd=st.sampled_from([32, 64, 128]),
        bh=st.integers(1, 3),
        q_offset=st.sampled_from([0, 64]),
    )
    @settings(max_examples=12, deadline=None)
    def test_shape_sweep(self, sq_blocks, sk_blocks, hd, bh, q_offset):
        key = jax.random.PRNGKey(sq_blocks * 100 + sk_blocks)
        k1, k2, k3 = jax.random.split(key, 3)
        sq, sk = sq_blocks * 64, sk_blocks * 64
        q = _rand(k1, (bh, sq, hd), jnp.float32)
        k = _rand(k2, (bh, sk, hd), jnp.float32)
        v = _rand(k3, (bh, sk, hd), jnp.float32)
        out = ops.flash_attention(
            q, k, v, causal=True, q_offset=q_offset, block_q=64, block_k=64
        )
        gold = ref.flash_attention_ref(q, k, v, causal=True, q_offset=q_offset)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(gold), atol=1e-4
        )


class TestDecodeAttention:
    @pytest.mark.parametrize("cur_pos", [0, 63, 100, 255])
    def test_positions(self, cur_pos):
        key = jax.random.PRNGKey(1)
        k1, k2, k3 = jax.random.split(key, 3)
        q = _rand(k1, (4, 64), jnp.float32)
        k = _rand(k2, (4, 256, 64), jnp.float32)
        v = _rand(k3, (4, 256, 64), jnp.float32)
        out = ops.decode_attention(q, k, v, cur_pos, block_k=64)
        gold = ref.decode_attention_ref(q, k, v, cur_pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=1e-4)

    def test_sliding_window(self):
        key = jax.random.PRNGKey(2)
        k1, k2, k3 = jax.random.split(key, 3)
        q = _rand(k1, (2, 32), jnp.float32)
        k = _rand(k2, (2, 128, 32), jnp.float32)
        v = _rand(k3, (2, 128, 32), jnp.float32)
        out = ops.decode_attention(q, k, v, 100, window=16, block_k=32)
        gold = ref.decode_attention_ref(q, k, v, 100, window=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=1e-4)


class TestGroupedMatmul:
    @given(
        e=st.integers(1, 6),
        c_blocks=st.integers(1, 3),
        d_blocks=st.integers(1, 3),
        f_blocks=st.integers(1, 2),
    )
    @settings(max_examples=10, deadline=None)
    def test_shape_sweep(self, e, c_blocks, d_blocks, f_blocks):
        key = jax.random.PRNGKey(e)
        k1, k2 = jax.random.split(key)
        c, d, f = c_blocks * 64, d_blocks * 128, f_blocks * 64
        x = _rand(k1, (e, c, d), jnp.float32)
        w = _rand(k2, (e, d, f), jnp.float32)
        out = ops.grouped_matmul(x, w, block_c=64, block_f=64, block_d=128)
        gold = ref.grouped_matmul_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(gold), rtol=1e-4, atol=1e-3
        )

    def test_bf16(self):
        key = jax.random.PRNGKey(7)
        k1, k2 = jax.random.split(key)
        x = _rand(k1, (4, 128, 256), jnp.bfloat16)
        w = _rand(k2, (4, 256, 128), jnp.bfloat16)
        out = ops.grouped_matmul(x, w, block_d=128)
        gold = ref.grouped_matmul_ref(x, w)
        rel = np.abs(
            np.asarray(out, np.float32) - np.asarray(gold, np.float32)
        ).max() / max(np.abs(np.asarray(gold, np.float32)).max(), 1e-9)
        assert rel < 2e-2


class TestSSDScan:
    @given(
        chunks=st.integers(1, 4),
        nh=st.integers(1, 4),
        hd=st.sampled_from([16, 32]),
        ds=st.sampled_from([8, 16]),
    )
    @settings(max_examples=10, deadline=None)
    def test_shape_sweep(self, chunks, nh, hd, ds):
        key = jax.random.PRNGKey(chunks * 10 + nh)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s = 2, chunks * 32
        x = _rand(k1, (b, s, nh, hd), jnp.float32) * 0.5
        dt = jax.nn.softplus(_rand(k2, (b, s, nh), jnp.float32))
        A = -jnp.exp(_rand(k3, (nh,), jnp.float32) * 0.3)
        Bm = _rand(k1, (b, s, ds), jnp.float32) * 0.5
        C = _rand(k2, (b, s, ds), jnp.float32) * 0.5
        out = ops.ssd_scan(x, dt, A, Bm, C, chunk=32)
        gold = ref.ssd_scan_ref(x, dt, A, Bm, C)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(gold), rtol=1e-3, atol=1e-3
        )

    def test_matches_model_chunked_scan(self):
        """The Pallas kernel, the model's jnp chunked scan, and the
        sequential recurrence must all agree."""
        from repro.models.blocks import _ssd_scan

        key = jax.random.PRNGKey(3)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, nh, hd, ds = 2, 96, 2, 16, 8
        x = _rand(k1, (b, s, nh, hd), jnp.float32) * 0.5
        dt = jax.nn.softplus(_rand(k2, (b, s, nh), jnp.float32))
        A = -jnp.exp(_rand(k3, (nh,), jnp.float32) * 0.3)
        Bm = _rand(k1, (b, s, ds), jnp.float32) * 0.5
        C = _rand(k2, (b, s, ds), jnp.float32) * 0.5
        gold = ref.ssd_scan_ref(x, dt, A, Bm, C)
        model = _ssd_scan(x, dt, A, Bm, C, chunk=32)
        kern = ops.ssd_scan(x, dt, A, Bm, C, chunk=32)
        np.testing.assert_allclose(np.asarray(model), np.asarray(gold), atol=1e-3)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(gold), atol=1e-3)


class TestPagedDecode:
    @given(
        bh=st.integers(1, 4),
        max_pages=st.integers(1, 4),
        page=st.sampled_from([16, 32]),
        hd=st.sampled_from([32, 64]),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=12, deadline=None)
    def test_matches_gather_oracle(self, bh, max_pages, page, hd, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        n_pool = bh * max_pages + 3
        q = _rand(k1, (bh, hd), jnp.float32)
        k_pool = _rand(k2, (n_pool, page, hd), jnp.float32)
        v_pool = _rand(k3, (n_pool, page, hd), jnp.float32)
        # random non-overlapping-ish page table + random valid lengths ≥ 1
        table = jax.random.permutation(k4, n_pool)[: bh * max_pages].reshape(
            bh, max_pages
        )
        lens = jax.random.randint(k5, (bh,), 1, max_pages * page + 1)
        out = ops.paged_decode_attention(q, k_pool, v_pool, table, lens)
        gold = ref.paged_decode_attention_ref(q, k_pool, v_pool, table, lens)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(gold), atol=1e-4
        )

    def test_consumes_block_allocator_tables(self):
        """End-to-end: page tables produced by the serving block allocator
        drive the Pallas kernel; numerics must match the dense reference
        over each request's contiguous K/V."""
        from repro.configs import ARCHS
        from repro.serve.kv_cache import PagedKVManager, kv_bytes_per_token

        cfg = ARCHS["internlm2-1.8b"]
        page, hd = 16, 64
        page_bytes = kv_bytes_per_token(cfg) * page
        mgr = PagedKVManager(capacity_bytes=page_bytes * 8, page_tokens=page)
        lens = {"a": 40, "b": 17, "c": 60}  # c overflows the 8-page pool
        for rid, n in lens.items():
            mgr.register(rid, cfg)
            mgr.grow_to(rid, n)
        assert mgr.overflow_pages > 0  # the pool is genuinely overcommitted
        tables = {rid: mgr.page_table(rid) for rid in lens}
        flat = [pid for t in tables.values() for pid in t]
        assert len(set(flat)) == len(flat), "pages must never be shared"
        n_pool = mgr.page_id_bound  # ids are recycled; bound > current count
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (3, hd), jnp.float32)
        k_pool = np.zeros((n_pool, page, hd), np.float32)
        v_pool = np.zeros_like(k_pool)
        dense_k, dense_v = {}, {}
        for i, (rid, n) in enumerate(lens.items()):
            kk = jax.random.normal(jax.random.PRNGKey(10 + i),
                                   (len(tables[rid]) * page, hd))
            vv = jax.random.normal(jax.random.PRNGKey(20 + i),
                                   (len(tables[rid]) * page, hd))
            dense_k[rid], dense_v[rid] = np.asarray(kk), np.asarray(vv)
            for j, pid in enumerate(tables[rid]):
                k_pool[pid] = dense_k[rid][j * page:(j + 1) * page]
                v_pool[pid] = dense_v[rid][j * page:(j + 1) * page]
        table = jnp.asarray(mgr.table_array(list(lens), max_pages=4))
        seq = jnp.asarray([lens[r] for r in lens], jnp.int32)
        out = np.asarray(
            ops.paged_decode_attention(
                q, jnp.asarray(k_pool), jnp.asarray(v_pool), table, seq
            )
        )
        # dense per-request oracle: softmax over the contiguous K/V prefix
        for i, (rid, n) in enumerate(lens.items()):
            kk = dense_k[rid][:n]
            vv = dense_v[rid][:n]
            s = np.asarray(q)[i] @ kk.T / np.sqrt(hd)
            p = np.exp(s - s.max())
            p /= p.sum()
            np.testing.assert_allclose(out[i], p @ vv, atol=1e-4)

    def test_shared_prefix_pages_numerics(self):
        """Prefix sharing is a PAGE-TABLE property: requests whose tables
        alias the same prefix pages must read identical K/V through the
        kernel's indirection — no new kernel needed.  The tables come from
        a real prefix-cache match plus the engine's copy-on-write guard
        (the shared terminal page splits before request b's first write),
        and numerics are checked against per-request dense oracles and a
        physically-duplicated (no aliasing) layout."""
        from repro.configs import ARCHS
        from repro.serve.kv_cache import PagedKVManager, kv_bytes_per_token

        cfg = ARCHS["internlm2-1.8b"]
        page, hd = 16, 64
        page_bytes = kv_bytes_per_token(cfg) * page
        mgr = PagedKVManager(
            capacity_bytes=page_bytes * 16,
            page_tokens=page,
            enable_prefix_cache=True,
        )
        shared_prompt = list(range(40))  # 2 full pages + 8-token terminal
        mgr.register("a", cfg)
        mgr.grow_to("a", 64)  # prompt + decoded tokens: 4 pages
        mgr.insert_prefix("a", shared_prompt, "T", tuple(shared_prompt))
        mgr.register("b", cfg)
        matched, _ = mgr.match_prefix("b", shared_prompt)
        assert matched == 40
        # the engine's COW guard before b writes position 40 (which lands
        # in the shared terminal page): b gets a private copy
        mgr.make_private("b", 2)
        mgr.grow_to("b", 64)
        ta, tb = mgr.page_table("a"), mgr.page_table("b")
        assert ta[:2] == tb[:2], "full prefix pages must alias, not copy"
        assert not set(ta[2:]) & set(tb[2:]), "suffix pages must be private"

        # per-request dense K/V streams sharing the first 40 positions
        n_pool = mgr.page_id_bound
        q = jax.random.normal(jax.random.PRNGKey(3), (2, hd), jnp.float32)
        sa_k = np.asarray(jax.random.normal(jax.random.PRNGKey(11), (64, hd)))
        sa_v = np.asarray(jax.random.normal(jax.random.PRNGKey(12), (64, hd)))
        sb_k = np.concatenate(
            [sa_k[:40],
             np.asarray(jax.random.normal(jax.random.PRNGKey(13), (24, hd)))]
        )
        sb_v = np.concatenate(
            [sa_v[:40],
             np.asarray(jax.random.normal(jax.random.PRNGKey(14), (24, hd)))]
        )
        k_pool = np.zeros((n_pool, page, hd), np.float32)
        v_pool = np.zeros_like(k_pool)
        for table_ids, sk, sv in ((ta, sa_k, sa_v), (tb, sb_k, sb_v)):
            for j, pid in enumerate(table_ids):
                k_pool[pid] = sk[j * page:(j + 1) * page]
                v_pool[pid] = sv[j * page:(j + 1) * page]
        table = jnp.asarray(mgr.table_array(["a", "b"], max_pages=4))
        lens = jnp.asarray([50, 46], jnp.int32)
        out = np.asarray(
            ops.paged_decode_attention(
                q, jnp.asarray(k_pool), jnp.asarray(v_pool), table, lens
            )
        )
        # oracle 1: dense per-request softmax over the contiguous prefix
        for i, (sk, sv, n) in enumerate(((sa_k, sa_v, 50), (sb_k, sb_v, 46))):
            s = np.asarray(q)[i] @ sk[:n].T / np.sqrt(hd)
            p = np.exp(s - s.max())
            p /= p.sum()
            np.testing.assert_allclose(out[i], p @ sv[:n], atol=1e-4)
        # oracle 2: physically duplicate b's shared pages into fresh pool
        # slots — aliased and duplicated layouts must agree exactly
        k2 = np.concatenate([k_pool, k_pool[np.asarray(ta[:2])]], axis=0)
        v2 = np.concatenate([v_pool, v_pool[np.asarray(ta[:2])]], axis=0)
        table_dup = np.asarray(table).copy()
        table_dup[1, :2] = np.arange(n_pool, n_pool + 2)
        out_dup = np.asarray(
            ops.paged_decode_attention(
                q, jnp.asarray(k2), jnp.asarray(v2),
                jnp.asarray(table_dup), lens,
            )
        )
        np.testing.assert_allclose(out, out_dup, atol=1e-6)


class TestPagedDecodeInt8:
    @given(
        bh=st.integers(1, 4),
        max_pages=st.integers(1, 3),
        seed=st.integers(0, 50),
    )
    @settings(max_examples=8, deadline=None)
    def test_matches_dequantize_first_oracle(self, bh, max_pages, seed):
        """Dequantizing per-page int8 codes INSIDE the page sweep must
        match dequantizing the whole pool up front."""
        from repro.dist.compression import quantize

        page, hd = 16, 64
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        n_pool = bh * max_pages + 2
        q = _rand(k1, (bh, hd), jnp.float32)
        kf = _rand(k2, (n_pool, page, hd), jnp.float32)
        vf = _rand(k3, (n_pool, page, hd), jnp.float32)
        kq, ks = jax.vmap(quantize)(kf)
        vq, vs = jax.vmap(quantize)(vf)
        table = jax.random.permutation(k4, n_pool)[: bh * max_pages].reshape(
            bh, max_pages
        )
        lens = jax.random.randint(k5, (bh,), 1, max_pages * page + 1)
        out = ops.paged_decode_attention_int8(
            q, kq, vq, ks, vs, table, lens
        )
        gold = ref.paged_decode_attention_int8_ref(
            q, kq, vq, ks, vs, table, lens
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(gold), atol=1e-4
        )
