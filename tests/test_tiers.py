"""Tiered KV hierarchy: compression, bandwidth, disk spill, demotion.

The hypothesis property test pins the safety contract of the tentpole:

    * bytes are CONSERVED across tiers — a demoted page's raw bytes never
      change while it moves HBM → (flight) → host → disk → (flight) → HBM,
    * a page is never resident in two tiers at once (single location),
    * a demoted page is never readable (``touch``) without a completed
      promotion event first.

Plus the two satellite bugfix regressions: multi-victim overcommit must
clear within a single ``step()``, and a zero-capacity pool must report
0.0 (empty, not permanently full) and still admit constant-state work.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCHS
from repro.core.memory_manager import MemoryPool
from repro.core.sampler import TaskStats
from repro.models import init_model
from repro.sched import (
    BasePolicy,
    FairPolicy,
    MursConfig,
    MursPolicy,
    PriorityConfig,
    PriorityPolicy,
)
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import (
    DEMOTED,
    PageBlockAllocator,
    PagedKVManager,
    kv_bytes_per_token,
)
from repro.serve.tiers import CompressedBlock, TierConfig, TieredKVStore

CFG = ARCHS["internlm2-1.8b"]
PAGE = 4096.0


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _drain(store, ticks=200):
    events = []
    for _ in range(ticks):
        events += store.tick()
        if store.link.in_flight == 0:
            break
    return events


class TestCompressedBlock:
    def test_int8_roundtrip_error_bounded(self):
        payload = np.linspace(-3.0, 3.0, 512).astype(np.float32)
        block = CompressedBlock.compress(PAGE, payload, compress=True)
        deq = block.decompress()
        # symmetric int8: |x − deq| ≤ scale/2 everywhere
        assert np.max(np.abs(payload - deq)) <= block.scale / 2 + 1e-7
        assert block.quant_error <= block.scale / 2 + 1e-7
        assert block.codes.dtype == np.int8

    def test_byte_model(self):
        c = CompressedBlock.compress(PAGE, None, compress=True)
        assert c.stored_bytes == pytest.approx(PAGE / 2 + 4)
        raw = CompressedBlock.compress(PAGE, None, compress=False)
        assert raw.stored_bytes == PAGE


class TestTieredKVStore:
    def _mk(self, host_pages=3.0, pcie=PAGE, disk=PAGE / 4):
        return TieredKVStore(
            TierConfig(
                host_capacity_bytes=host_pages * PAGE,
                pcie_bytes_per_tick=pcie,
                disk_bytes_per_tick=disk,
            )
        )

    def test_demotion_is_asynchronous(self):
        ts = self._mk(pcie=PAGE)  # compressed page ≈ half a tick
        ts.demote("k", PAGE)
        assert ts.location("k") == "to_host"
        assert not ts.touch("k")
        ts.tick()
        assert ts.location("k") == "host"

    def test_promotion_emits_resident_event_with_payload(self):
        ts = self._mk()
        payload = np.arange(64, dtype=np.float32)
        ts.demote("k", PAGE, payload)
        _drain(ts)
        assert ts.promote("k")
        events = _drain(ts)
        assert len(events) == 1
        kind, key, deq = events[0]
        assert (kind, key) == ("resident", "k")
        assert np.max(np.abs(deq - payload)) < 0.5
        assert ts.location("k") == "hbm" and ts.touch("k")

    def test_compression_halves_transfer_ticks(self):
        slow = TierConfig(
            host_capacity_bytes=100 * PAGE, pcie_bytes_per_tick=PAGE / 2
        )
        for compress, expect_ticks in ((True, 2), (False, 3)):
            ts = TieredKVStore(
                TierConfig(
                    host_capacity_bytes=slow.host_capacity_bytes,
                    pcie_bytes_per_tick=slow.pcie_bytes_per_tick,
                    compress=compress,
                )
            )
            ts.demote("k", PAGE)
            ticks = 0
            while ts.location("k") != "host":
                ts.tick()
                ticks += 1
            # int8 moves half the bytes → half the ticks (1.01 vs 2)
            assert ticks <= expect_ticks
        assert ts.compression_ratio == 1.0  # the uncompressed store

    def test_host_overflow_spills_lru_to_disk(self):
        ts = self._mk(host_pages=0.6)  # holds ONE compressed page
        ts.demote("old", PAGE, now=0.0)
        _drain(ts)
        ts.demote("new", PAGE, now=5.0)
        _drain(ts)
        assert ts.location("old") == "disk"  # LRU victim
        assert ts.location("new") == "host"
        assert ts.disk_spill_bytes == pytest.approx(PAGE / 2 + 4)

    def test_disk_promotion_pays_slow_link_and_counts_reads(self):
        ts = self._mk(host_pages=0.6, pcie=100 * PAGE, disk=PAGE / 8)
        ts.demote("a", PAGE)
        _drain(ts)
        ts.demote("b", PAGE)
        _drain(ts)  # a → disk
        assert ts.promote("a")
        assert ts.disk_read_bytes > 0
        ts.tick()
        assert ts.location("a") == "to_hbm"  # slow: still in flight
        _drain(ts)
        assert ts.location("a") == "hbm"

    def test_infinite_link_rate_completes_instantly(self):
        """TierConfig's default link rates are inf (instant DMA); the
        drain arithmetic must not produce 0·inf = NaN and wedge the
        transfer in flight forever."""
        ts = TieredKVStore(TierConfig(host_capacity_bytes=100 * PAGE))
        ts.demote("k", PAGE)
        ts.tick()
        assert ts.location("k") == "host"
        ts.promote("k")
        events = ts.tick()
        assert [e[:2] for e in events] == [("resident", "k")]

    def test_discard_cancels_in_flight(self):
        ts = self._mk(pcie=PAGE / 100)
        ts.demote("k", PAGE)
        ts.discard("k")
        assert ts.location("k") == "hbm"
        assert ts.link.in_flight == 0
        assert _drain(ts) == []


class TestTierProperty:
    """Random demote/promote/touch/tick streams: conservation, single
    residency, and no read of a demoted page without a promotion event."""

    @given(
        ops=st.lists(
            st.tuples(st.integers(0, 3), st.integers(0, 5)),
            min_size=1,
            max_size=60,
        )
    )
    @settings(max_examples=60, deadline=None)
    def test_random_op_stream(self, ops):
        cfg = TierConfig(
            host_capacity_bytes=2.2 * PAGE,
            pcie_bytes_per_tick=PAGE / 2,
            disk_bytes_per_tick=PAGE / 4,
        )
        ts = TieredKVStore(cfg)
        tracked = {}  # key → raw bytes demoted and not yet back/discarded
        now = 0.0
        for kind, k in ops:
            key = f"p{k}"
            if kind == 0 and ts.location(key) == "hbm":
                ts.demote(key, PAGE, None, now)
                tracked[key] = PAGE
            elif kind == 1:
                ts.promote(key, now)  # no-op unless host/disk
            elif kind == 2:
                readable = ts.touch(key)
                # a demoted page is NEVER readable without a completed
                # promotion event (which pops it from `tracked` below)
                assert readable == (key not in tracked)
            else:
                now += 1.0
                for ev, evkey, _ in ts.tick(now):
                    assert ev == "resident"
                    tracked.pop(evkey)
            # ---- invariants, after every op
            # bytes conserved across tiers: tracked raw never mutates
            assert ts.tracked_raw_bytes == pytest.approx(
                sum(tracked.values())
            )
            # single location: state and link queue agree exactly
            inflight = {t.key for t in ts.link._queue}
            for kk, state in ts._state.items():
                if state in ("to_host", "to_hbm"):
                    assert kk in inflight
                else:
                    assert state in ("host", "disk")
                    assert kk not in inflight
            # the host tier honors its capacity
            assert (
                ts.host_used_bytes
                <= cfg.host_capacity_bytes + 1e-9
            )


class TestAllocatorSwap:
    def test_swap_out_frees_and_preserves_position(self):
        a = PageBlockAllocator(4)
        a.grow_to("r", 3)
        a.swap_out("r", 1)
        assert a.table("r") == (0, DEMOTED, 2)
        assert a.free_pages == 2
        assert not a.resident("r")
        assert a.demoted_indices("r") == (1,)
        # demoted entries carry no HBM bytes
        assert a.owner_share("r") == pytest.approx(2.0)
        a.swap_in("r", 1)
        assert a.resident("r") and a.owner_share("r") == pytest.approx(3.0)

    def test_only_private_physical_pages_demote(self):
        a = PageBlockAllocator(2)
        a.grow_to("r", 3)  # third page overflows
        a.share("s", [a.table("r")[0]])
        with pytest.raises(ValueError):
            a.swap_out("r", 0)  # shared
        with pytest.raises(ValueError):
            a.swap_out("r", 2)  # overflow
        a.swap_out("r", 1)
        with pytest.raises(ValueError):
            a.swap_out("r", 1)  # already demoted

    def test_table_array_masks_demoted(self):
        a = PageBlockAllocator(4)
        a.grow_to("r", 2)
        a.swap_out("r", 0)
        arr = a.table_array(["r"], max_pages=3)
        assert arr.min() >= 0


def _tiered_kv(n_pages=6, host_pages=8.0, pcie_pages=2.0, prefix=False):
    pb = kv_bytes_per_token(CFG) * 16
    return PagedKVManager(
        capacity_bytes=pb * n_pages,
        enable_prefix_cache=prefix,
        tier_config=TierConfig(
            host_capacity_bytes=host_pages * pb,
            pcie_bytes_per_tick=pcie_pages * pb,
        ),
    ), pb


class TestManagerDemotion:
    def test_request_page_roundtrip(self):
        kv, pb = _tiered_kv()
        kv.register("r", CFG)
        kv.grow_to("r", 40)  # 3 pages
        assert kv.demote_page("r", 2)
        assert not kv.resident("r")
        assert kv.request_bytes("r") == pytest.approx(2 * pb)
        for _ in range(5):
            kv.tick_tiers()
        assert kv.promote_request("r", 4) == 1
        restored = []
        for t in range(10):
            restored += kv.tick_tiers(float(t))
            if kv.resident("r"):
                break
        assert kv.resident("r")
        assert [(rid, idx) for rid, idx, _ in restored] == [("r", 2)]

    def test_release_discards_tier_copies(self):
        kv, _ = _tiered_kv()
        kv.register("r", CFG)
        kv.grow_to("r", 40)
        kv.demote_page("r", 0)
        kv.demote_page("r", 1)
        kv.release("r")
        assert kv.tiers.tracked_raw_bytes == 0.0
        assert kv.tiers.link.in_flight == 0

    def test_cold_trie_page_demotes_and_promotes_on_match(self):
        kv, _ = _tiered_kv(prefix=True)
        kv.register("w", CFG)
        toks = list(range(40))  # 2 full pages + 8-token terminal
        kv.grow_to("w", 40)
        kv.insert_prefix("w", toks, "T", tuple(toks))
        kv.release("w")  # 3 cold cached pages
        demoted = 0
        while kv.demote_cold_page():
            demoted += 1
        assert demoted == 3
        for _ in range(10):
            kv.tick_tiers()
        # the prefix is still KNOWN but not shareable: a match truncates
        # at the first host node and triggers its promotion
        kv.register("r", CFG)
        matched, _snap = kv.match_prefix("r", toks)
        assert matched == 0
        done = False
        for t in range(20):
            kv.tick_tiers(float(t))
            if kv._prefix._nodes[tuple(toks[:16])].host is False:
                done = True
                break
        assert done, "matched host node must promote back"
        kv.release("r")
        kv.register("r2", CFG)
        matched2, _ = kv.match_prefix("r2", toks)
        assert matched2 == 16  # the promoted first page is shareable again


class TestHostNodePromotionUnderFullPool:
    def test_inner_host_node_survives_failed_promotion(self):
        """A promotion completing into a FULL pool must not drop an
        INNER host node — that would orphan its still-cached descendant
        chain.  It stays host; the next match retries."""
        kv, _ = _tiered_kv(n_pages=4, prefix=True)
        kv.register("w", CFG)
        toks = list(range(32))  # 2 full pages
        kv.grow_to("w", 32)
        kv.insert_prefix("w", toks, "T", tuple(toks))
        kv.release("w")
        while kv.demote_cold_page():
            pass
        for _ in range(10):
            kv.tick_tiers()
        # fill the pool so take_free fails at promotion completion
        kv.register("hog", CFG)
        kv.grow_to("hog", 16 * 4)
        kv.register("r", CFG)
        kv.match_prefix("r", toks)  # fires promote_cb on the root node
        for t in range(10):
            kv.tick_tiers(float(t))
        trie = kv._prefix
        root, child = tuple(toks[:16]), tuple(toks)
        assert root in trie._nodes and trie._nodes[root].host
        assert child in trie._nodes, "descendant must not be orphaned"
        # pool frees up: the retried promotion reattaches the chain
        kv.release("hog")
        kv.release("r")
        kv.register("r2", CFG)
        kv.match_prefix("r2", toks)
        for t in range(10):
            kv.tick_tiers(float(t))
        assert not trie._nodes[root].host


class TestDemotionPressureHint:
    def test_base_and_fair_never_proactive(self):
        assert BasePolicy().demotion_pressure("anyone") == 0.0
        assert FairPolicy().demotion_pressure("anyone") == 0.0

    def test_murs_low_rate_tenants_demote_first(self):
        pol = MursPolicy(MursConfig.for_serving(period=1.0))
        pool = MemoryPool(capacity=1e9)
        running = [
            TaskStats(
                task_id="t0", consumption=1e8, rate=300.0,
                progress=0.5, remaining_bytes=1e8, group="heavy",
            ),
            TaskStats(
                task_id="t1", consumption=1e8, rate=10.0,
                progress=0.5, remaining_bytes=1e8, group="light",
            ),
        ]
        pol.propose(pool, running, now=0.0)
        light = pol.demotion_pressure("light")
        heavy = pol.demotion_pressure("heavy")
        assert light > heavy > 0.0, "every tenant demotable, light first"

    def test_priority_weight_ordered(self):
        pol = PriorityPolicy(PriorityConfig(weights={"gold": 4.0}))
        assert pol.demotion_pressure("gold") < pol.demotion_pressure(
            "bronze"
        )


class TestOvercommitResolutionRegression:
    def test_multi_victim_overcommit_clears_in_one_step(self, small_model):
        """One fat victim may not cover the deficit: the resolution loop
        must demote across however many frozen victims it takes, in the
        SAME call — overcommit lingering a tick per victim is the bug."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 16 * 8  # 8-page pool
        eng = ServingEngine(
            cfg, params,
            EngineConfig(
                n_slots=3, max_seq=64, hbm_capacity_bytes=cap,
                prefix_cache=False,
            ),
        )
        eng.submit(Request("a", "T", list(range(10, 40)), 4))  # 2 pages
        eng.submit(Request("b", "U", list(range(50, 80)), 4))  # 2 pages
        eng.submit(Request("c", "V", list(range(4)), 4))  # 1 page
        for _ in range(2):
            eng.step()
        for rid in ("a", "b"):
            req = eng.requests[rid]
            assert req.state in ("prefill", "decoding")
            eng._set_state(req, "suspended")  # keeps the state index true
            eng._release_slot(req)
        # c suddenly needs 7 pages: deficit 3 > either victim's 2 pages
        eng.kv.grow_to("c", 16 * 7)
        assert eng.kv.overflow_pages > 0
        eng._resolve_overcommit()
        eng.kv.reclaim()
        assert eng.kv.overflow_pages == 0, "must clear in one call"
        assert eng.kv.has_demoted("a") and eng.kv.has_demoted("b"), (
            "both frozen victims must contribute pages"
        )
        assert eng.reactive_offloads == 0, "running work was never touched"

    def test_zero_capacity_pool_reports_empty_and_admits(self):
        """A constant-state deployment with no KV pool must read 0.0
        (empty), not permanently 100% full."""
        kv = PagedKVManager(capacity_bytes=0.0)
        assert kv.used_fraction == 0.0
        pool = MemoryPool(capacity=0.0)
        assert pool.used_fraction == 0.0 and pool.live_fraction == 0.0
        mamba = ARCHS["mamba2-2.7b"]
        kv.register("r", mamba)
        assert kv.request_pages("r") == 0 and kv.resident("r")

    def test_zero_capacity_engine_serves_constant_state(self):
        """End to end: a mamba-style engine with a zero-byte KV pool
        admits and completes requests instead of reading full forever."""
        cfg = ARCHS["mamba2-2.7b"].smoke()
        params = init_model(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=2, max_seq=32, hbm_capacity_bytes=0.0),
        )
        eng.submit(Request("r0", "T", list(range(6)), 4))
        eng.submit(Request("r1", "U", list(range(8)), 4))
        out = eng.run(max_ticks=200).extras
        assert out["failed"] == 0 and out["completed"] == 2
        assert eng.kv.used_fraction == 0.0


class TestEngineTiering:
    def test_reactive_tiering_spills_to_disk_but_serves(self, small_model):
        """FAIR under a tight pool and a small host tier: the reactive
        path demotes running work, the host tier overflows into the disk
        tier (the paper's data spilling) — and everything still
        completes, paying transfer stalls instead of failures."""
        cfg, params = small_model
        pb = kv_bytes_per_token(cfg) * 16
        eng = ServingEngine(
            cfg, params,
            EngineConfig(
                n_slots=3, max_seq=64, hbm_capacity_bytes=pb * 4,
                policy=FairPolicy(), prefix_cache=False,
                host_capacity_bytes=pb * 1.0,
                pcie_bytes_per_tick=pb * 2.0,
            ),
        )
        for i in range(3):
            eng.submit(Request(f"a{i}", "A", list(range(10, 18)), 30))
        out = eng.run(max_ticks=600).extras
        assert out["failed"] == 0 and out["completed"] == 3
        assert out["offload_events"] > 0
        assert out["tiers"]["disk_spill_bytes"] > 0
        assert out["tiers"]["compression_ratio"] > 1.5
        assert out["transfer_stall_ticks"] > 0

    def test_murs_proactive_demotion_avoids_reactive_path(self, small_model):
        """MURS at the same load: suspension + proactive frozen-KV
        demotion keep the reactive spill path silent."""
        cfg, params = small_model
        pb = kv_bytes_per_token(cfg) * 16
        eng = ServingEngine(
            cfg, params,
            EngineConfig(
                n_slots=3, max_seq=64, hbm_capacity_bytes=pb * 4,
                policy=MursPolicy(MursConfig.for_serving(period=1.0)),
                prefix_cache=False,
                host_capacity_bytes=pb * 1.0,
                pcie_bytes_per_tick=pb * 2.0,
                demote_threshold=0.8,  # eager: demote within murs's band
            ),
        )
        for i in range(3):
            eng.submit(Request(f"a{i}", "A", list(range(10, 18)), 30))
        out = eng.run(max_ticks=600).extras
        assert out["failed"] == 0 and out["completed"] == 3
        assert out["offload_events"] == 0, "reactive path must stay silent"
        assert out["proactive_demotions"] > 0, "the mechanism must fire"
