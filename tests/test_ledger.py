"""The MemoryLedger (DESIGN.md §13): class-stamped byte tallies.

Three layers of coverage:

* direct ledger unit tests — class derivation, fractional shared-page
  attribution, exact settle, tier flows, SCRATCH semantics;
* a hypothesis property suite driving a :class:`PagedKVManager`
  through random alloc / share / COW / freeze / demote / promote /
  evict / free streams, asserting after EVERY op that the incremental
  state equals :meth:`MemoryLedger.recount` (the gate hard bit), that
  bytes are conserved across tier transitions, and that no page is
  ever stamped with two classes at once;
* a projection drift regression — the incremental admission-estimate
  total must equal a ground-truth recount after a long random
  note/drop stream (the old ``_projected_bytes`` float accumulated
  error and needed a settle-on-empty reset; the ledger must not).
"""

import math
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCHS
from repro.serve import (
    MemoryLedger,
    PagedKVManager,
    PageClass,
    TierConfig,
)
from repro.serve.ledger import DISK, HBM, HOST, TO_HOST, PressurePlan

CFG = ARCHS["internlm2-1.8b"].smoke()


def make_mgr(capacity_pages=64, tiers=True, prefix=True):
    mgr = PagedKVManager(
        capacity_bytes=0.0,  # sized below, in pages
        page_tokens=16,
        enable_prefix_cache=prefix,
        tier_config=TierConfig(host_capacity_bytes=1e12) if tiers else None,
    )
    mgr.capacity_bytes = mgr.page_bytes_for(CFG) * capacity_pages
    return mgr


def ledger_single_class_per_page(ledger):
    """No page is ever in two classes at once."""
    for pid, entries in ledger._page_entries.items():
        classes = {cls for (_o, cls, _b) in entries}
        assert len(classes) == 1, f"page {pid} stamped {classes}"


def check_invariants(mgr):
    led = mgr.ledger
    assert led.matches_recount()
    ledger_single_class_per_page(led)
    # per-class totals at HBM sum to the tier total
    by_class = led.class_breakdown(HBM)
    assert math.isclose(
        sum(by_class.values()), led.tier_bytes(HBM),
        rel_tol=1e-9, abs_tol=1e-6,
    )
    # allocator pages in use == HBM page bytes (HBM total minus fixed state)
    if mgr._alloc is not None:
        page_hbm = led.tier_bytes(HBM) - led.class_bytes(
            PageClass.FIXED_STATE
        )
        assert math.isclose(
            page_hbm,
            mgr._alloc.pages_in_use * mgr._pool_page_bytes,
            rel_tol=1e-9, abs_tol=1e-6,
        )


class TestLedgerUnit:
    def test_fixed_state_registers_and_settles(self):
        led = MemoryLedger()
        led.register_owner("r1", tenant="A", kind="request",
                           page_bytes=100.0, state_bytes=40.0)
        assert led.class_bytes(PageClass.FIXED_STATE) == 40.0
        assert led.tenant_class_bytes("A", PageClass.FIXED_STATE) == 40.0
        led.release_owner("r1")
        assert led.class_bytes(PageClass.FIXED_STATE) == 0.0
        assert led.hbm_bytes() == 0.0

    def test_fractional_shared_attribution(self):
        led = MemoryLedger()
        led.register_owner("a", tenant="A", page_bytes=90.0)
        led.register_owner("b", tenant="B", page_bytes=90.0)
        led.register_owner("c", tenant="C", page_bytes=90.0)
        led.page_update(7, ["a", "b", "c"])
        assert led.class_bytes(PageClass.SHARED_PREFIX) == pytest.approx(90.0)
        for t in "ABC":
            assert led.tenant_class_bytes(
                t, PageClass.SHARED_PREFIX
            ) == pytest.approx(30.0)
        assert led.owner_bytes("a") == pytest.approx(30.0)
        # one holder drops: the page turns private for the survivors? no —
        # two holders is still shared
        led.page_update(7, ["a", "b"])
        assert led.page_class(7) is PageClass.SHARED_PREFIX
        led.page_update(7, ["a"])
        assert led.page_class(7) is PageClass.PRIVATE_SUFFIX
        assert led.owner_bytes("a") == pytest.approx(90.0)
        led.page_update(7, [])
        assert led.page_class(7) is None
        assert led.hbm_bytes() == 0.0

    def test_frozen_restamps_sole_pages_only(self):
        led = MemoryLedger()
        led.register_owner("r", tenant="A", page_bytes=50.0)
        led.register_owner("s", tenant="B", page_bytes=50.0)
        led.page_update(1, ["r"])          # sole: PRIVATE_SUFFIX
        led.page_update(2, ["r", "s"])     # shared: stays SHARED_PREFIX

        # set_frozen restamps by walking the attached allocator's tables
        class FakeAlloc:
            _tables = {"r": (1, 2), "s": (2,)}
            _holders = {1: ["r"], 2: ["r", "s"]}

        led.attach_allocator(FakeAlloc())
        led.set_frozen("r", True)
        assert led.page_class(1) is PageClass.FROZEN
        assert led.page_class(2) is PageClass.SHARED_PREFIX
        assert led.class_bytes(PageClass.FROZEN) == pytest.approx(50.0)
        led.set_frozen("r", False)
        assert led.page_class(1) is PageClass.PRIVATE_SUFFIX
        assert led.class_bytes(PageClass.FROZEN) == 0.0

    def test_tier_moves_record_flows(self):
        led = MemoryLedger()
        led.register_owner("r", tenant="A", page_bytes=64.0)
        led.tier_demote(("req", "r", 0), 64.0, 32.0)
        assert led.tier_bytes(TO_HOST) == pytest.approx(32.0)
        led.tier_move(("req", "r", 0), HOST)
        assert led.tier_bytes(HOST) == pytest.approx(32.0)
        led.tier_move(("req", "r", 0), DISK)
        assert led.flow(HOST, DISK) == pytest.approx(32.0)
        led.tier_drop(("req", "r", 0))
        assert led.tier_bytes(DISK) == 0.0
        # the cumulative flow survives the drop (spill is monotonic)
        assert led.flow(HOST, DISK) == pytest.approx(32.0)

    def test_release_owner_drops_tier_copies(self):
        led = MemoryLedger()
        led.register_owner("r", tenant="A", page_bytes=64.0)
        led.tier_demote(("req", "r", 0), 64.0, 32.0)
        led.tier_move(("req", "r", 0), HOST)
        led.release_owner("r")
        assert led.tier_bytes(HOST) == 0.0
        assert led.matches_recount()

    def test_pressure_plan_default_score_and_orders(self):
        plan = PressurePlan()
        assert plan.reclaim_order[0] is PageClass.SCRATCH
        assert plan.reclaim_order.index(PageClass.COLD_CACHED) < (
            plan.reclaim_order.index(PageClass.FROZEN)
        )
        # a class without a scorer defaults to 1.0 (flat)
        assert plan.score(PageClass.COLD_CACHED, "anyone") == 1.0

    def test_stats_shape(self):
        led = MemoryLedger()
        s = led.stats()
        assert set(s["by_class"]) == {c.value for c in PageClass}
        assert set(s["peak_by_class"]) == {c.value for c in PageClass}
        assert s["ledger_matches_recount"] is True
        for key in ("by_tier", "hbm_bytes", "projected_bytes",
                    "disk_spill_bytes"):
            assert key in s


class TestScratchClass:
    def test_scratch_allocatable_and_classed(self):
        mgr = make_mgr(capacity_pages=16, tiers=False)
        mgr.register("r1", CFG, tenant="A")
        got = mgr.register_scratch("draft", 4, tenant="A")
        assert got == 4
        assert mgr.scratch_bytes == pytest.approx(
            4 * mgr._pool_page_bytes
        )
        assert mgr.ledger.class_bytes(PageClass.SCRATCH) == (
            pytest.approx(mgr.scratch_bytes)
        )
        check_invariants(mgr)

    def test_scratch_evicted_before_cold_and_frozen(self):
        """SCRATCH drains first under pressure — before cold cache is
        evicted and before any frozen page is demoted (the reclaim
        order of the default PressurePlan, by construction)."""
        mgr = make_mgr(capacity_pages=32)
        mgr.register("warm", CFG, tenant="A")
        mgr.grow_to("warm", 64)  # 4 pages
        toks = list(range(100, 164))
        mgr.insert_prefix("warm", toks, "A", ("snap",))
        mgr.release("warm")  # pages survive as COLD_CACHED
        cold_before = mgr.ledger.class_bytes(PageClass.COLD_CACHED)
        assert cold_before > 0
        mgr.register("frozen-req", CFG, tenant="B")
        mgr.grow_to("frozen-req", 32)
        mgr.set_frozen("frozen-req", True)
        frozen_before = mgr.ledger.class_bytes(PageClass.FROZEN)
        assert frozen_before > 0
        mgr.register_scratch("draft", 3, tenant="B")
        # drive reclaim in plan order: scratch must empty before the
        # other classes lose a byte
        plan = PressurePlan()
        freed = 0
        for cls in plan.reclaim_order:
            if cls is PageClass.SCRATCH:
                while mgr.evict_scratch(1) > 0:
                    freed += 1
                    check_invariants(mgr)
            if freed >= 3:
                break
        assert freed == 3
        assert mgr.ledger.class_bytes(PageClass.SCRATCH) == 0.0
        assert mgr.ledger.class_bytes(PageClass.COLD_CACHED) == (
            pytest.approx(cold_before)
        )
        assert mgr.ledger.class_bytes(PageClass.FROZEN) == (
            pytest.approx(frozen_before)
        )
        check_invariants(mgr)

    def test_release_scratch_retires_owner(self):
        mgr = make_mgr(capacity_pages=16, tiers=False)
        mgr.register("r1", CFG, tenant="A")
        mgr.register_scratch("draft", 5, tenant="A")
        assert mgr.release_scratch("draft") == 5
        assert mgr.scratch_bytes == 0.0
        assert not mgr.ledger.has_owner("draft")
        check_invariants(mgr)


class TestProjectionDrift:
    def test_incremental_equals_recount_after_long_random_run(self):
        """Satellite-1 regression: the old engine kept a running
        ``_projected_bytes`` float that drifted under float cancellation
        and needed a settle-on-empty reset.  The ledger's exact-settle
        buckets must agree with a ground-truth fsum after thousands of
        adds/drops WITHOUT any reset."""
        led = MemoryLedger()
        rng = random.Random(42)
        live = []
        for i in range(5000):
            if live and rng.random() < 0.45:
                led.drop_projection(live.pop(rng.randrange(len(live))))
            else:
                owner = f"r{i}"
                led.note_projection(
                    owner, f"t{rng.randrange(4)}",
                    rng.uniform(1.0, 1e9) * (10 ** rng.randrange(-3, 3)),
                )
                live.append(owner)
        assert led.projected_bytes() == pytest.approx(
            led.projected_recount(), rel=1e-9
        )
        # drain to empty: every bucket must settle to EXACTLY zero
        for owner in live:
            led.drop_projection(owner)
        assert led.projected_bytes() == 0.0
        assert led.projected_recount() == 0.0
        assert led.projected_by_tenant() == {}


# --------------------------------------------------------------------------
# hypothesis property suite: random op streams against recount()

OPS = st.lists(
    st.tuples(
        st.sampled_from(
            ["register", "grow", "match", "insert", "cow", "freeze",
             "thaw", "demote", "demote_cold", "promote", "tick",
             "evict_cache", "scratch", "evict_scratch", "release"]
        ),
        st.integers(min_value=0, max_value=7),   # actor pick
        st.integers(min_value=1, max_value=96),  # token count / amount
    ),
    min_size=1,
    max_size=60,
)


@given(ops=OPS, seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_ledger_matches_recount_under_random_streams(ops, seed):
    rng = random.Random(seed)
    mgr = make_mgr(capacity_pages=24)
    # four canonical prompt streams — requests on the same stream share
    # prefix pages; the driver always publishes a request's ACTUAL
    # tokens (the engine contract: insert_prefix sees the real prompt)
    streams = [
        [(seed + i * 131 + j) % 997 for j in range(24 * 16)]
        for i in range(4)
    ]
    live = []           # registered request ids
    tokens = {}         # rid -> its prompt stream
    frozen = set()
    scratch_next = 0
    now = 0.0
    counter = 0

    for op, pick, amount in ops:
        now += 1.0
        if op == "register":
            rid = f"r{counter}"
            counter += 1
            mgr.register(rid, CFG, tenant=f"t{pick % 3}")
            tokens[rid] = streams[pick % 4]
            live.append(rid)
        elif op == "grow" and live:
            rid = live[pick % len(live)]
            mgr.grow_to(rid, min(amount * 4, len(tokens[rid])))
        elif op == "match" and live:
            rid = live[pick % len(live)]
            if mgr._alloc is not None and (
                mgr._alloc.pages_held(rid) == 0
            ):
                mgr.match_prefix(rid, tokens[rid], now)
        elif op == "insert" and live:
            rid = live[pick % len(live)]
            held = (
                mgr._alloc.pages_held(rid)
                if mgr._alloc is not None else 0
            )
            if held > 0:
                toks = tokens[rid][: held * 16]
                mgr.insert_prefix(rid, toks, "g",
                                  (pick % 4,), now)
        elif op == "cow" and live:
            rid = live[pick % len(live)]
            held = (
                mgr._alloc.pages_held(rid)
                if mgr._alloc is not None else 0
            )
            if held > 0:
                mgr.make_private(rid, pick % held)
        elif op == "freeze" and live:
            rid = live[pick % len(live)]
            mgr.set_frozen(rid, True)
            frozen.add(rid)
        elif op == "thaw" and frozen:
            rid = rng.choice(sorted(frozen))
            if rid in live:
                mgr.set_frozen(rid, False)
            frozen.discard(rid)
        elif op == "demote" and live:
            rid = live[pick % len(live)]
            idxs = mgr.demotable_indices(rid)
            if idxs:
                mgr.demote_page(rid, idxs[pick % len(idxs)], None, now)
        elif op == "demote_cold":
            mgr.demote_cold_page(now)
        elif op == "promote" and live:
            rid = live[pick % len(live)]
            mgr.promote_request(rid, 2, now)
        elif op == "tick":
            mgr.tick_tiers(now)
        elif op == "evict_cache":
            mgr.evict_cache(1 + pick % 3)
        elif op == "scratch":
            owner = f"s{scratch_next % 2}"
            scratch_next += 1
            mgr.register_scratch(owner, 1 + amount % 3,
                                 tenant=f"t{pick % 3}")
        elif op == "evict_scratch":
            mgr.evict_scratch(1 + pick % 3)
        elif op == "release" and live:
            rid = live.pop(pick % len(live))
            frozen.discard(rid)
            tokens.pop(rid, None)
            mgr.release(rid)

        check_invariants(mgr)

    # drain everything: the ledger must settle back to exactly zero HBM
    for owner in list(mgr._scratch):
        mgr.release_scratch(owner)
    for rid in list(live):
        mgr.release(rid)
    mgr.evict_cache(10**6)
    if mgr.tiers is not None:
        for _ in range(64):
            mgr.tick_tiers(now)
            now += 1.0
    check_invariants(mgr)
    led = mgr.ledger
    live_hbm = led.tier_bytes(HBM) - led.class_bytes(PageClass.COLD_CACHED)
    # only cache pages (and their host copies) may outlive the requests
    assert live_hbm == pytest.approx(0.0, abs=1e-6)
