"""Policy-layer tests: protocol conformance, FAIR parity, FIFO
starvation-freedom, priority weighting, and the `_resumed_at` hygiene fix.
"""

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCHS
from repro.core.memory_manager import MemoryPool
from repro.core.sampler import TaskStats
from repro.core.spark_sim import make_grep, make_wc, run_service
from repro.models import init_model
from repro.sched import (
    BasePolicy,
    FairPolicy,
    MursConfig,
    MursPolicy,
    PriorityConfig,
    PriorityPolicy,
    SchedulingPolicy,
)
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import kv_bytes_per_token


def _stats(i, rate, consumption=1e8, progress=0.5, remaining=1e8, group=""):
    return TaskStats(
        task_id=f"t{i}",
        consumption=consumption,
        rate=rate,
        progress=progress,
        remaining_bytes=remaining,
        group=group,
    )


class TestProtocol:
    @pytest.mark.parametrize(
        "policy",
        [FairPolicy(), MursPolicy(), PriorityPolicy(), BasePolicy()],
        ids=["fair", "murs", "priority", "base"],
    )
    def test_conformance(self, policy):
        assert isinstance(policy, SchedulingPolicy)
        # the declarative attributes every runtime interrogates
        assert 0.0 < policy.admission_headroom <= 1.0
        assert policy.period > 0
        assert isinstance(policy.proactive, bool)

    def test_round_robin_assign_rotates(self):
        p = FairPolicy()
        picks = p.assign(5, {"a": 3, "b": 2, "c": 1})
        assert picks == ["a", "b", "c", "a", "b"]
        # cursor persists across calls — next pick continues the rotation
        # (after the drain above the cursor sits on the second group)
        assert p.assign(1, {"a": 1, "b": 1})[0] == "b"

    def test_assign_respects_pending_counts(self):
        p = FairPolicy()
        picks = p.assign(10, {"a": 1, "b": 2})
        assert sorted(picks) == ["a", "b", "b"]


class TestFairParitySimulator:
    """The legacy `murs=None` spelling and an explicit FairPolicy must be
    the same scheduler: identical metrics, run-to-run deterministic.
    (This pins config resolution + determinism; the substantive behavioral
    pins for FAIR live in the pre-existing assertions of
    test_service_sim.py / test_serving.py, which this refactor kept
    green unchanged.)"""

    def test_sim_metrics_identical(self):
        jobs = [make_wc(), make_grep()]
        legacy = run_service(jobs, heap_gb=6.0, oom_is_fatal=False)
        via_policy = run_service(
            [make_wc(), make_grep()], heap_gb=6.0, oom_is_fatal=False,
            policy=FairPolicy(),
        )
        assert legacy.minor_gcs == via_policy.minor_gcs
        assert legacy.full_gcs == via_policy.full_gcs
        assert legacy.total_gc_time == pytest.approx(via_policy.total_gc_time)
        assert legacy.sim_time == pytest.approx(via_policy.sim_time)
        for jid, jm in legacy.jobs.items():
            other = via_policy.jobs[jid]
            assert jm.finish_time == pytest.approx(other.finish_time)
            assert jm.spills == other.spills
            assert jm.gc_time == pytest.approx(other.gc_time)


class TestFairParityEngine:
    """Same contract as the simulator parity test: `scheduler=None` and an
    explicit FairPolicy resolve to one code path with identical output."""

    def test_engine_metrics_identical(self):
        cfg = ARCHS["internlm2-1.8b"].smoke()
        params = init_model(cfg, jax.random.PRNGKey(0))
        cap = kv_bytes_per_token(cfg) * 80

        def reqs():
            r = [Request(f"A{i}", "A", list(range(10, 18)), 30) for i in range(3)]
            r += [Request(f"B{i}", "B", list(range(30, 34)), 6) for i in range(2)]
            return r

        outs = {}
        for key, ecfg in (
            ("legacy", EngineConfig(n_slots=4, max_seq=64,
                                    hbm_capacity_bytes=cap, scheduler=None)),
            ("policy", EngineConfig(n_slots=4, max_seq=64,
                                    hbm_capacity_bytes=cap,
                                    policy=FairPolicy())),
        ):
            eng = ServingEngine(cfg, params, ecfg)
            for r in reqs():
                eng.submit(r)
            outs[key] = eng.run(max_ticks=400)
        assert outs["legacy"] == outs["policy"]


class TestFifoStarvationFreedom:
    """§VI-D: the suspended queue resumes in FIFO order and every suspended
    task is eventually resumed given enough completions."""

    @given(
        n_tasks=st.integers(2, 16),
        live_frac=st.floats(0.5, 0.95),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=40, deadline=None)
    def test_fifo_resume_order_property(self, n_tasks, live_frac, seed):
        import random

        rng = random.Random(seed)
        sched = MursPolicy(MursConfig())
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", live_frac * 10e9)
        tasks = [
            _stats(i, rate=rng.uniform(0.5, 8.0), remaining=rng.uniform(1e8, 2e9))
            for i in range(n_tasks)
        ]
        d = sched.propose(pool, tasks, now=0.0)
        suspended_order = list(d.suspend)
        assert list(sched.suspended_queue) == suspended_order
        # drive completions until the queue drains: resume order == FIFO
        resumed = []
        for k in range(len(suspended_order)):
            tid = sched.on_task_complete(f"done{k}")
            assert tid is not None, "starvation: queue did not drain"
            resumed.append(tid)
        assert resumed == suspended_order
        assert not sched.has_suspended
        assert sched.on_task_complete() is None

    def test_below_yellow_resumes_all(self):
        sched = MursPolicy(MursConfig())
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", 5e9)
        d = sched.propose(pool, [_stats(i, rate=5.0, remaining=1e9)
                                 for i in range(6)])
        assert d.suspend
        pool.live.clear()
        d2 = sched.propose(pool, [])
        assert set(d2.resume) == set(d.suspend)


class TestResumedAtHygiene:
    """Satellite fix: `_resumed_at` must not grow without bound."""

    def _pressured(self):
        sched = MursPolicy(MursConfig())
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", 5e9)
        tasks = [_stats(i, rate=5.0, remaining=1e9) for i in range(6)]
        assert sched.propose(pool, tasks, now=0.0).suspend
        return sched, pool, tasks

    def test_on_task_complete_purges_finished_task(self):
        sched, pool, tasks = self._pressured()
        tid = sched.on_task_complete()
        assert tid in sched._resumed_at
        # the resumed task later finishes: its immunity stamp must go
        sched.on_task_complete(tid)
        assert tid not in sched._resumed_at

    def test_drop_purges_resumed_at(self):
        sched, pool, tasks = self._pressured()
        tid = sched.on_task_complete()
        sched.drop(tid)
        assert tid not in sched._resumed_at
        assert tid not in sched.suspended_queue

    def test_propose_prunes_expired_immunity(self):
        sched, pool, tasks = self._pressured()
        tid = sched.on_task_complete()
        assert tid in sched._resumed_at
        pool.live.clear()  # pressure gone — nothing new suspends
        imm = sched.config.resume_immunity
        # first pass: prunes the old stamp but resume-all re-stamps the
        # still-queued tasks (they need fresh immunity)
        sched.propose(pool, [], now=imm + 1.0)
        assert tid not in sched._resumed_at
        # once those stamps expire too, the dict drains completely
        sched.propose(pool, [], now=2 * imm + 2.0)
        assert sched._resumed_at == {}

    def test_long_lived_service_bounded(self):
        """Thousands of suspend/resume/complete cycles leave no residue."""
        sched = MursPolicy(MursConfig(resume_immunity=0.5))
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", 5e9)
        now = 0.0
        for round_ in range(200):
            tasks = [
                _stats(1000 * round_ + i, rate=5.0, remaining=1e9)
                for i in range(4)
            ]
            sched.propose(pool, tasks, now=now)
            while sched.has_suspended:
                tid = sched.on_task_complete()
                sched.on_task_complete(tid)  # ... and then it finishes
            now += 1.0
        assert len(sched._resumed_at) <= 8


class TestPriorityPolicy:
    def test_stride_assign_respects_weights(self):
        p = PriorityPolicy(PriorityConfig(weights={"gold": 3.0, "free": 1.0}))
        picks = p.assign(8, {"gold": 100, "free": 100})
        assert picks.count("gold") >= 2 * picks.count("free")
        assert picks.count("free") >= 1  # no starvation

    def test_sheds_lowest_weight_group_first(self):
        p = PriorityPolicy(
            PriorityConfig(weights={"gold": 4.0, "free": 1.0},
                           shed_threshold=0.6)
        )
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", 7e9)
        tasks = [
            _stats(i, rate=3.0, remaining=2e9, group="gold") for i in range(2)
        ] + [
            _stats(10 + i, rate=3.0, remaining=2e9, group="free")
            for i in range(2)
        ]
        d = p.propose(pool, tasks)
        assert d.suspend, "must shed above the threshold"
        free_ids, gold_ids = {"t10", "t11"}, {"t0", "t1"}
        assert free_ids & set(d.suspend), "low-weight group sheds first"
        assert gold_ids - set(d.suspend), "high-weight group keeps a task"

    def test_resumes_below_threshold(self):
        p = PriorityPolicy(PriorityConfig(weights={}, shed_threshold=0.6,
                                          resume_below=0.4))
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", 7e9)
        d = p.propose(pool, [_stats(i, rate=3.0, remaining=2e9)
                             for i in range(4)])
        assert d.suspend
        pool.live.clear()
        d2 = p.propose(pool, [])
        assert set(d2.resume) == set(d.suspend)


class TestShimCompatibility:
    def test_core_scheduler_shim_removed(self):
        """The one-release ``repro.core.scheduler`` re-export shim is
        gone; the canonical names live in :mod:`repro.sched` (and
        ``repro.core`` still re-exports them for its own API)."""
        import importlib
        import sys

        sys.modules.pop("repro.core.scheduler", None)
        with pytest.raises(ModuleNotFoundError):
            importlib.import_module("repro.core.scheduler")
        import repro.core as core
        from repro.sched.murs import MursPolicy as MP
        from repro.sched.protocol import SchedulingDecision

        assert core.MursScheduler is MP
        assert core.MursConfig is MursConfig
        assert SchedulingDecision().is_noop

    def test_serving_config_preset(self):
        cfg = MursConfig.for_serving(period=2.0)
        assert cfg.collector_trigger is None
        assert not cfg.fair_share_guard
        assert cfg.exec_fraction == 0.95
        assert cfg.period == 2.0


class TestCachePressureHint:
    """The prefix-cache eviction hint every policy now exposes."""

    def test_base_and_fair_default_to_pure_lru(self):
        assert BasePolicy().cache_pressure("anyone") == 0.0
        assert FairPolicy().cache_pressure("anyone") == 0.0

    def test_murs_low_rate_tenants_evict_first(self):
        pol = MursPolicy(MursConfig.for_serving(period=1.0))
        pool = MemoryPool(capacity=1e9)  # light pool: propose is a no-op
        running = [
            _stats(0, rate=300.0, group="heavy"),
            _stats(1, rate=10.0, group="light"),
        ]
        pol.propose(pool, running, now=0.0)
        light, heavy = pol.cache_pressure("light"), pol.cache_pressure("heavy")
        assert light > heavy, "low-usage-rate prefixes must evict first"
        assert 0.0 <= heavy <= light <= 1.0
        # unseen groups sit mid-scale so LRU still tie-breaks
        assert pol.cache_pressure("nobody") == 0.5

    def test_murs_rate_ema_tracks_groups(self):
        pol = MursPolicy(MursConfig.for_serving(period=1.0))
        pool = MemoryPool(capacity=1e9)
        for _ in range(5):
            pol.propose(pool, [_stats(0, rate=100.0, group="g")], now=0.0)
        p_before = pol.cache_pressure("g")
        for _ in range(20):
            pol.propose(
                pool,
                [
                    _stats(0, rate=1.0, group="g"),
                    _stats(1, rate=100.0, group="other"),
                ],
                now=0.0,
            )
        assert pol.cache_pressure("g") > p_before  # g cooled off → evictable

    def test_priority_weight_ordered(self):
        pol = PriorityPolicy(PriorityConfig(weights={"gold": 4.0}))
        assert pol.cache_pressure("gold") < pol.cache_pressure("bronze")

    def test_engine_wires_policy_hint_into_eviction(self):
        """The engine hands the resolved policy's pressure plan to the KV
        manager — the trie's eviction order is policy-owned (the plan's
        COLD_CACHED score, which ``cache_pressure`` wraps)."""
        from repro.configs import ARCHS
        from repro.models import init_model

        cfg = ARCHS["internlm2-1.8b"].smoke()
        params = init_model(cfg, jax.random.PRNGKey(0))
        pol = PriorityPolicy(PriorityConfig(weights={"A": 4.0}))
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=2, max_seq=64,
                         hbm_capacity_bytes=kv_bytes_per_token(cfg) * 64,
                         policy=pol),
        )
        assert eng.kv.cache_pressure_fn("bronze") == pol.cache_pressure(
            "bronze"
        )
        assert eng.kv.cache_pressure_fn("A") == pytest.approx(1.0 / 5.0)
        assert eng.kv.cache_pressure_fn("A") < eng.kv.cache_pressure_fn(
            "bronze"
        )
