"""Prefix-sharing paged KV cache: refcounts, COW, trie, eviction, engine.

The allocator invariants here are the safety contract of the tentpole:

    * no page is ever freed (back on the free list) while referenced,
    * copy-on-write never mutates a shared page — the writer gets a fresh
      page; every other holder's table is untouched,
    * eviction only ever touches COLD pages (held by the cache alone) —
      a page referenced by an active request is untouchable.

A hypothesis property test drives a random op stream (admit / publish /
append / release / evict / COW) through :class:`PagedKVManager` with the
trie enabled and checks the refcount bookkeeping after every step.
"""

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCHS
from repro.models import init_model
from repro.sched import MursConfig, MursPolicy
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import (
    CACHE_OWNER,
    PageBlockAllocator,
    PagedKVManager,
    PrefixCache,
    kv_bytes_per_token,
)

CFG = ARCHS["internlm2-1.8b"]


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestAllocatorRefcounts:
    def test_share_and_staged_free(self):
        a = PageBlockAllocator(n_pages=4)
        a.grow_to("r1", 2)
        a.share("r2", [0, 1])
        assert a.refcount(0) == 2 and a.refcount(1) == 2
        assert a.pages_in_use == 2  # distinct pages, not table entries
        a.free("r1")
        # still referenced by r2: nothing returns to the free list
        assert a.free_pages == 2 and a.refcount(0) == 1
        a.free("r2")
        assert a.free_pages == 4 and a.pages_in_use == 0

    def test_owner_share_sums_to_physical(self):
        a = PageBlockAllocator(n_pages=8)
        a.grow_to("r1", 3)
        a.share("r2", [0, 1])
        a.grow_to("r2", 4)  # two private pages on top of the shared ones
        total = a.owner_share("r1") + a.owner_share("r2")
        assert total == pytest.approx(a.pages_in_use)

    def test_cow_never_mutates_shared_page(self):
        a = PageBlockAllocator(n_pages=4)
        a.grow_to("r1", 1)
        a.share("r2", [0])
        new = a.ensure_private("r2", 0)
        assert new != 0
        assert a.table("r1") == (0,)  # the shared page is untouched
        assert a.table("r2") == (new,)
        assert a.refcount(0) == 1 and a.refcount(new) == 1
        assert a.cow_events == 1
        # private page: COW is a no-op
        assert a.ensure_private("r2", 0) == new
        assert a.cow_events == 1

    def test_share_rejects_dead_and_overflow_pages(self):
        a = PageBlockAllocator(n_pages=1)
        a.grow_to("r1", 2)  # second page overflows
        with pytest.raises(ValueError):
            a.share("r2", [a.table("r1")[1]])  # overflow: never shared
        with pytest.raises(ValueError):
            a.share("r2", [7])  # not live

    def test_release_pages_partial(self):
        a = PageBlockAllocator(n_pages=4)
        a.grow_to("r1", 3)
        a.release_pages("r1", [a.table("r1")[1]])
        assert a.pages_held("r1") == 2
        assert a.free_pages == 2


class TestPrefixCacheTrie:
    def _mk(self, n_pages=8, page_tokens=4):
        a = PageBlockAllocator(n_pages)
        return a, PrefixCache(a, page_tokens)

    def test_insert_then_exact_and_partial_match(self):
        a, c = self._mk()
        a.grow_to("r1", 3)  # 10 tokens @ page 4 → 2 full + 1 partial
        toks = list(range(10))
        assert c.insert(a.table("r1"), toks, "t", tuple(toks)) == 3
        # exact match shares every page, including the partial terminal
        m, snap = c.match("r2", toks, now=1.0)
        assert m == 10 and snap == tuple(toks)
        assert a.table("r2") == a.table("r1")
        # a longer prompt still matches the full cached feed as its prefix
        m2, _ = c.match("r3", toks + [99, 98], now=2.0)
        assert m2 == 10
        # diverging after one page matches only the page-aligned prefix
        m3, _ = c.match("r4", toks[:4] + [77, 77, 77, 77], now=3.0)
        assert m3 == 4
        assert c.hits == 3 and c.lookups == 3

    def test_eviction_only_touches_cold_leaves(self):
        a, c = self._mk(n_pages=8)
        a.grow_to("r1", 2)
        toks = list(range(8))  # two full pages
        c.insert(a.table("r1"), toks, "t", tuple(toks))
        a.free("r1")  # cache is now the only holder (cold)
        m, _ = c.match("r2", toks[:4], now=1.0)  # re-warm page 0
        assert m == 4
        # page 0 is referenced by r2 → only the depth-2 leaf is evictable
        assert c.evictable_pages == 1
        assert c.evict(5) == 1
        assert a.pages_held("r2") == 1  # request tables never touched
        a.free("r2")
        assert c.evict(5) == 1  # now the root page is a cold leaf
        assert c.cached_pages == 0
        assert a.free_pages == a.n_pages

    def test_uncounted_match_for_replays(self):
        """count_stats=False re-shares pages without moving the hit/dedup
        counters — an offload-reload re-matching its OWN prefix must not
        satisfy the benchmark's hit-rate acceptance bit."""
        a, c = self._mk()
        a.grow_to("r1", 1)
        c.insert(a.table("r1"), [1, 2, 3, 4], "t", (1, 2, 3, 4))
        a.free("r1")
        m, _ = c.match("r1b", [1, 2, 3, 4], count_stats=False)
        assert m == 4 and a.pages_held("r1b") == 1
        assert c.hits == 0 and c.lookups == 0 and c.hit_tokens == 0
        assert c.shared_pages_acquired == 0

    def test_protected_pages_survive_eviction(self):
        """The admission probe's matched pages must be shielded from the
        admission pass's own evictions — otherwise the probe's arithmetic
        is invalidated by the eviction it triggers."""
        a, c = self._mk()
        a.grow_to("r1", 1)
        c.insert(a.table("r1"), [1, 2, 3, 4], "t", (1, 2, 3, 4))
        pid = a.table("r1")[0]
        a.free("r1")  # cold: cache is the only holder
        assert c.evict(1, protect=[pid]) == 0
        assert c.evict(1) == 1

    def test_eviction_order_lru_then_pressure(self):
        a, c = self._mk(n_pages=8)
        a.grow_to("r1", 1)
        a.grow_to("r2", 1)
        c.insert(a.table("r1"), [1, 2, 3, 4], "light", (1, 2, 3, 4), now=0.0)
        c.insert(a.table("r2"), [5, 6, 7, 8], "heavy", (5, 6, 7, 8), now=5.0)
        p1 = a.table("r1")[0]
        p2 = a.table("r2")[0]
        a.free("r1")
        a.free("r2")
        # pure LRU: the older (r1's) page goes first
        assert c.evict(1) == 1
        assert a.refcount(p1) == 0 and a.refcount(p2) == 1
        # policy pressure outranks LRU: re-insert both, mark "heavy" hot
        a.grow_to("r3", 1)
        c.insert(a.table("r3"), [1, 2, 3, 4], "light", (1, 2, 3, 4), now=0.0)
        a.free("r3")
        pressure = {"light": 0.1, "heavy": 0.9}.get
        assert c.evict(1, pressure) == 1
        assert a.refcount(p2) == 0  # heavy-pressure group evicted first


class TestAdmissionArithmetic:
    P = 16
    PB = kv_bytes_per_token(CFG) * 16

    def _cold_prefix_pool(self, n_pages):
        kv = PagedKVManager(
            capacity_bytes=self.PB * n_pages,
            page_tokens=self.P,
            enable_prefix_cache=True,
        )
        kv.register("warm", CFG)
        kv.grow_to("warm", 48)
        kv.insert_prefix("warm", list(range(40)), "T", tuple(range(40)))
        kv.release("warm")  # 3 cold cached pages (2 full + terminal)
        return kv

    def test_probe_counts_terminal_cow_page(self):
        """A match ending in a shared PARTIAL page costs one extra page
        the moment the request appends (COW) — admission must count it,
        or it admits one page more than it checked."""
        kv = self._cold_prefix_pool(8)
        new_bytes, protected = kv.admission_probe(CFG, list(range(50)))
        # 4 pages total, 3 cached, 1 genuinely new + 1 COW split
        assert new_bytes == pytest.approx(2 * self.PB)
        assert len(protected) == 3

    def test_cow_under_drained_pool_transfers_ownership(self):
        """With the free list empty and the cache the only other holder,
        COW must hand the page over (evict the cache node) instead of
        allocating an overflow id."""
        kv = self._cold_prefix_pool(4)  # 3 cold pages + 1 free
        kv.register("b", CFG)
        matched, _ = kv.match_prefix("b", list(range(50)))
        assert matched == 40
        kv.grow_to("b", 50)  # takes the last free page
        kv.make_private("b", 2)  # COW guard before writing position 40
        assert kv.overflow_pages == 0
        assert kv.resident("b")


PAGE_BYTES = kv_bytes_per_token(CFG) * 4


def _check_refcounts(kv: PagedKVManager) -> None:
    a = kv._alloc
    held = {}
    for table in a._tables.values():
        for pid in table:
            held[pid] = held.get(pid, 0) + 1
    assert held == a._ref, "refcounts must equal table references"
    assert not set(a._free) & set(held), "free page still referenced"
    assert not set(a._free_overflow) & set(held)
    # the trie's holdings are exactly its nodes' pages
    if kv._prefix is not None:
        assert sorted(a._tables.get(CACHE_OWNER, [])) == sorted(
            n.page_id for n in kv._prefix._nodes.values()
        )


class TestRefcountInvariantsProperty:
    @given(
        ops=st.lists(
            st.tuples(
                st.integers(0, 5), st.integers(0, 3), st.integers(1, 30)
            ),
            min_size=1,
            max_size=40,
        )
    )
    @settings(max_examples=30, deadline=None)
    def test_random_op_stream(self, ops):
        kv = PagedKVManager(
            capacity_bytes=PAGE_BYTES * 6,
            page_tokens=4,
            enable_prefix_cache=True,
        )
        live = {}
        serial = 0
        for kind, tenant, x in ops:
            if kind == 0:  # admit: register, longest-prefix match, grow
                rid = f"r{serial}"
                serial += 1
                tokens = [(x + i) % 5 for i in range((x % 9) + 1)]
                kv.register(rid, CFG)
                kv.match_prefix(rid, tokens)
                kv.grow_to(rid, len(tokens))
                live[rid] = tokens
            elif kind == 1 and live:  # publish prompt pages into the trie
                rid = sorted(live)[x % len(live)]
                kv.insert_prefix(
                    rid, live[rid], f"t{tenant}", tuple(live[rid])
                )
            elif kind == 2 and live:  # decode append: grow + COW guard
                rid = sorted(live)[x % len(live)]
                live[rid].append(x % 5)
                kv.grow_to(rid, len(live[rid]))
                kv.make_private(
                    rid, (len(live[rid]) - 1) // kv.page_tokens
                )
            elif kind == 3 and live:  # completion: release every reference
                rid = sorted(live)[x % len(live)]
                others = {
                    o: list(t)
                    for o, t in kv._alloc._tables.items()
                    if o != rid
                }
                kv.release(rid)
                del live[rid]
                for o, t in others.items():
                    assert list(kv._alloc._tables.get(o, [])) == t
            elif kind == 4 and kv._alloc is not None:  # pressure: evict
                requests_before = {
                    o: list(t)
                    for o, t in kv._alloc._tables.items()
                    if o != CACHE_OWNER
                }
                kv.evict_cache((x % 4) + 1)
                # eviction never touches a page an active request holds
                for o, t in requests_before.items():
                    assert list(kv._alloc._tables.get(o, [])) == t
            elif kind == 5 and live:  # explicit COW on an arbitrary page
                rid = sorted(live)[x % len(live)]
                pages = kv.page_table(rid)
                if pages:
                    idx = x % len(pages)
                    old = pages[idx]
                    ref = kv._alloc.refcount(old)
                    new = kv._alloc.ensure_private(rid, idx)
                    if ref > 1:
                        assert new != old
                        assert kv._alloc.refcount(old) == ref - 1
                    else:
                        assert new == old
            if kv._alloc is not None:
                _check_refcounts(kv)


class TestEnginePrefixSharing:
    def test_exact_hit_skips_prefill_same_tokens(self, small_model):
        """A repeated prompt must generate bit-identical greedy tokens
        while skipping its entire prefill (the tentpole's correctness +
        win condition in one)."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 400
        eng = ServingEngine(
            cfg,
            params,
            EngineConfig(n_slots=2, max_seq=64, hbm_capacity_bytes=cap),
        )
        prompt = list(range(10, 30))
        eng.submit(Request("cold", "T", prompt, 6))
        eng.run(max_ticks=100)
        eng.submit(Request("warm", "T", prompt, 6))
        out = eng.run(max_ticks=200).extras
        assert (
            eng.requests["warm"].generated == eng.requests["cold"].generated
        )
        assert out["prefix_cache"]["requests_hit"] == 1
        assert out["prefix_cache"]["prefill_tokens_skipped"] == len(prompt)
        assert out["prefix_cache"]["hit_tokens"] == len(prompt)
        # decoding past the shared terminal page split it, mutating nothing
        assert out["prefix_cache"]["cow_events"] > 0

    def test_partial_hit_matches_cold_engine(self, small_model):
        """Chunked prefill must start at the first uncached token and end
        with the same tokens a cache-less engine produces."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 400
        base = list(range(10, 30))
        longer = base + list(range(50, 60))
        outs = {}
        for mode, enabled in (("cache", True), ("nocache", False)):
            eng = ServingEngine(
                cfg,
                params,
                EngineConfig(
                    n_slots=2,
                    max_seq=64,
                    hbm_capacity_bytes=cap,
                    prefill_chunk_tokens=8,
                    prefix_cache=enabled,
                ),
            )
            eng.submit(Request("a", "T", base, 4))
            eng.run(max_ticks=100)
            eng.submit(Request("b", "T", longer, 4))
            out = eng.run(max_ticks=200).extras
            outs[mode] = (eng.requests["b"].generated, out)
        assert outs["cache"][0] == outs["nocache"][0]
        assert outs["cache"][1]["prefix_cache"]["hit_tokens"] >= len(base)
        assert outs["nocache"][1]["prefix_cache"]["enabled"] is False

    def test_shared_prompt_lowers_peak_pool(self, small_model):
        """Equal tenant load, one shared system prompt: dedup must show a
        hit rate > 0 and a lower pool peak than the no-sharing baseline —
        the ISSUE's acceptance criterion, as a test."""
        cfg, params = small_model
        system = list(range(10, 42))  # 32-token shared system prompt
        cap = kv_bytes_per_token(cfg) * 16 * 12  # 12-page pool
        peaks, rates = {}, {}
        for mode, enabled in (("shared", True), ("baseline", False)):
            eng = ServingEngine(
                cfg,
                params,
                EngineConfig(
                    n_slots=4,
                    max_seq=64,
                    hbm_capacity_bytes=cap,
                    prefix_cache=enabled,
                ),
            )
            # one request warms the cache; the rest of the stream arrives
            # two ticks later (identical schedule for both engines)
            eng.submit(Request("u0", "tenant0", system + [100], 4))
            eng.step()
            eng.step()
            for i in range(1, 4):
                eng.submit(
                    Request(f"u{i}", f"tenant{i}", system + [100 + i], 4)
                )
            out = eng.run(max_ticks=300).extras
            assert out["failed"] == 0 and out["completed"] == 4
            peaks[mode] = out["peak_used_fraction"]
            rates[mode] = out["prefix_cache"].get("token_hit_rate", 0.0)
        assert rates["shared"] > 0.0
        assert peaks["shared"] < peaks["baseline"]

    def test_eviction_under_pressure_stays_correct(self, small_model):
        """A pool far smaller than the distinct-prompt working set forces
        policy-ordered cold-prefix eviction; everything still completes
        with zero failures and zero lingering overflow."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 16 * 4  # 4-page pool
        eng = ServingEngine(
            cfg,
            params,
            EngineConfig(
                n_slots=2,
                max_seq=64,
                hbm_capacity_bytes=cap,
                policy=MursPolicy(MursConfig.for_serving(period=1.0)),
            ),
        )
        for i in range(4):
            eng.submit(
                Request(
                    f"r{i}",
                    f"T{i}",
                    list(range(100 + 20 * i, 120 + 20 * i)),
                    4,
                )
            )
        out = eng.run(max_ticks=400).extras
        assert out["failed"] == 0 and out["completed"] == 4
        assert out["prefix_cache"]["evictions"] > 0
        assert eng.kv.overflow_pages == 0

    def test_ttft_improves_on_warm_long_prompt(self, small_model):
        """Skipping prefill must show up as time-to-first-token: the warm
        repeat of a long prompt beats the cold run."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 1000
        eng = ServingEngine(
            cfg,
            params,
            EngineConfig(
                n_slots=2,
                max_seq=64,
                hbm_capacity_bytes=cap,
                prefill_chunk_tokens=4,  # long prompt → many chunk ticks
            ),
        )
        prompt = list(range(5, 37))  # 32 tokens, 8 ticks of prefill
        eng.submit(Request("cold", "T", prompt, 3))
        eng.run(max_ticks=100)
        cold_ttft = eng.requests["cold"].first_token_tick - eng.requests[
            "cold"
        ].submit_tick
        eng.submit(Request("warm", "T", prompt, 3))
        eng.run(max_ticks=200)
        warm_ttft = eng.requests["warm"].first_token_tick - eng.requests[
            "warm"
        ].submit_tick
        assert warm_ttft < cold_ttft
