"""Unit + property tests for the MURS core (scheduler, models, sampler)."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.memory_manager import MemoryPool
from repro.core.sampler import Sampler, TaskStats
from repro.sched import MursConfig
from repro.sched.murs import MursPolicy as MursScheduler
from repro.core.usage_models import (
    MODEL_EXPONENT,
    RateEstimator,
    UsageModel,
    classify_trace,
    fit_power_law,
    live_bytes_at,
)


# ------------------------------------------------------------- usage models
class TestUsageModels:
    @pytest.mark.parametrize("model", list(UsageModel))
    def test_classify_recovers_generating_model(self, model):
        xs = [float(i) * 1e6 for i in range(1, 40)]
        ys = [live_bytes_at(model, x, 2.0) for x in xs]
        assert classify_trace(xs, ys) is model

    def test_power_law_fit_exact(self):
        a0, b0 = 3.0, 0.7
        xs = [float(i) for i in range(1, 50)]
        ys = [a0 * x**b0 for x in xs]
        a, b = fit_power_law(xs, ys)
        assert math.isclose(a, a0, rel_tol=1e-6)
        assert math.isclose(b, b0, rel_tol=1e-6)

    def test_model_order(self):
        order = [
            UsageModel.CONSTANT,
            UsageModel.SUB_LINEAR,
            UsageModel.LINEAR,
            UsageModel.SUPER_LINEAR,
        ]
        assert [m.order for m in order] == [0, 1, 2, 3]
        assert [MODEL_EXPONENT[m] for m in order] == [0.0, 0.5, 1.0, 1.5]

    @given(
        model=st.sampled_from(list(UsageModel)),
        rate=st.floats(0.1, 10.0),
        n=st.integers(5, 60),
    )
    @settings(max_examples=60, deadline=None)
    def test_classification_property(self, model, rate, n):
        """classify_trace recovers the generator for any rate / length."""
        xs = [float(i) * 1e5 for i in range(1, n + 1)]
        ys = [live_bytes_at(model, x, rate) for x in xs]
        assert classify_trace(xs, ys) is model

    def test_rate_estimator_linear_slope(self):
        est = RateEstimator()
        for i in range(1, 20):
            est.update(i * 100.0, i * 300.0)
        assert math.isclose(est.rate, 3.0, rel_tol=1e-6)
        assert est.model is UsageModel.LINEAR


# --------------------------------------------------------------- pool tests
class TestMemoryPool:
    def test_accounting(self):
        p = MemoryPool(capacity=100.0)
        p.add_live("a", 30.0)
        p.add_transient("a", 10.0)
        assert p.used_bytes == 40.0
        assert p.free_bytes == 60.0
        assert p.live_fraction == pytest.approx(0.3)
        survivors = p.minor_gc()
        assert survivors == 30.0
        assert p.transient_bytes == 0.0
        assert p.release_owner("a") == 30.0
        assert p.used_bytes == 0.0

    @given(
        allocs=st.lists(
            st.tuples(st.sampled_from("abcd"), st.floats(0, 1e9)), max_size=30
        )
    )
    @settings(max_examples=50, deadline=None)
    def test_never_negative(self, allocs):
        p = MemoryPool(capacity=1e9)
        for owner, b in allocs:
            p.add_live(owner, b)
            p.add_transient(owner, b / 2)
        assert p.used_bytes >= 0.0
        assert p.free_bytes >= 0.0


# ---------------------------------------------------------- scheduler tests
def _stats(i, rate, consumption=1e8, progress=0.5, remaining=1e8):
    return TaskStats(
        task_id=f"t{i}",
        consumption=consumption,
        rate=rate,
        progress=progress,
        remaining_bytes=remaining,
    )


class TestMursScheduler:
    def make(self, capacity=10e9, live=0.0, **kw):
        sched = MursScheduler(MursConfig(**kw))
        pool = MemoryPool(capacity=capacity)
        if live:
            pool.add_live("x", live)
        return sched, pool

    def test_no_suspension_below_yellow(self):
        sched, pool = self.make(live=0.3 * 10e9)
        d = sched.propose(pool, [_stats(i, rate=float(i)) for i in range(8)])
        assert d.suspend == []

    def test_suspends_heavy_tasks_at_yellow(self):
        # live 5 GB of 10 GB → yellow band; trigger headroom 1.5 GB
        sched, pool = self.make(live=5e9)
        tasks = [
            _stats(i, rate=3.0, consumption=2e8, remaining=4e8) for i in range(8)
        ] + [_stats(10 + i, rate=0.0, remaining=4e8) for i in range(4)]
        d = sched.propose(pool, tasks)
        assert d.suspend, "heavy tasks must be suspended under pressure"
        # the zero-rate (light) tasks must all be kept
        light_ids = {f"t{10 + i}" for i in range(4)}
        assert not light_ids & set(d.suspend)

    def test_suspension_order_prefers_low_future_growth(self):
        sched, pool = self.make(live=5e9)
        tasks = [
            _stats(0, rate=0.1, remaining=1e8),
            _stats(1, rate=5.0, remaining=1e9),
            _stats(2, rate=2.0, remaining=1e9),
        ]
        d = sched.propose(pool, tasks)
        if d.suspend:
            # the highest-future-growth task is suspended first
            assert "t1" in d.suspend
            assert "t0" not in d.suspend

    def test_kept_tasks_fit_budget(self):
        """Whichever path fires (yellow keep-loop or spill guard), the kept
        set's projected memory must fit the corresponding budget."""
        cfg = MursConfig()
        sched = MursScheduler(cfg)
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", 5e9)
        tasks = [
            _stats(i, rate=2.0, consumption=1e8, remaining=5e8) for i in range(16)
        ]
        d = sched.propose(pool, tasks)
        assert d.suspend, "16 heavy tasks at 50% occupancy must not all fit"
        kept = [t for t in tasks if t.task_id not in set(d.suspend)]
        if d.reason == "spill-avoidance":
            projected = sum(
                t.consumption + t.rate * t.remaining_bytes
                for t in kept[cfg.min_running:]
            )
            assert projected <= cfg.exec_fraction * pool.capacity + 1e-6
        else:
            free = min(
                cfg.collector_trigger * pool.capacity - pool.live_bytes,
                pool.free_bytes,
            )
            need = sum(t.memory_necessary for t in kept[cfg.min_running:])
            assert need <= free + 1e-6

    def test_fifo_resume_order(self):
        sched, pool = self.make(live=5e9)
        tasks = [_stats(i, rate=5.0, remaining=1e9) for i in range(6)]
        d = sched.propose(pool, tasks)
        assert len(d.suspend) >= 2
        first, second = d.suspend[0], d.suspend[1]
        assert sched.on_task_complete() == first
        assert sched.on_task_complete() == second

    def test_below_yellow_resumes_all(self):
        sched, pool = self.make(live=5e9)
        d = sched.propose(pool, [_stats(i, rate=5.0, remaining=1e9) for i in range(6)])
        assert d.suspend
        pool.live.clear()  # pressure gone
        d2 = sched.propose(pool, [])
        assert set(d2.resume) == set(d.suspend)
        assert not sched.has_suspended

    def test_resume_immunity_blocks_resuspension(self):
        sched, pool = self.make(live=5e9)
        tasks = [_stats(i, rate=5.0, remaining=1e9) for i in range(6)]
        d = sched.propose(pool, tasks, now=0.0)
        tid = sched.on_task_complete()
        assert tid == d.suspend[0]
        # immediately re-proposing must not re-suspend the resumed task
        d2 = sched.propose(pool, tasks, now=0.5)
        assert tid not in d2.suspend

    def test_spill_guard_respects_exec_pool(self):
        cfg = MursConfig(exec_fraction=0.2)
        sched = MursScheduler(cfg)
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", 4.5e9)  # yellow band
        # projected totals far exceed the 2 GB exec pool
        tasks = [
            _stats(i, rate=4.0, consumption=4e8, remaining=4e8) for i in range(10)
        ]
        d = sched.propose(pool, tasks)
        assert d.suspend
        kept = [t for t in tasks if t.task_id not in set(d.suspend)]
        projected = sum(
            t.consumption + t.rate * t.remaining_bytes
            for t in kept[cfg.min_running:]
        )
        assert projected <= cfg.exec_fraction * pool.capacity + 1e-6

    @given(
        live_frac=st.floats(0.0, 1.0),
        rates=st.lists(st.floats(0.0, 10.0), min_size=1, max_size=24),
    )
    @settings(max_examples=80, deadline=None)
    def test_invariants_property(self, live_frac, rates):
        """Core safety invariants for arbitrary pool states and task mixes."""
        cfg = MursConfig()
        sched = MursScheduler(cfg)
        pool = MemoryPool(capacity=10e9)
        pool.add_live("x", live_frac * 10e9)
        tasks = [
            _stats(i, rate=r, consumption=1e8, remaining=5e8)
            for i, r in enumerate(rates)
        ]
        d = sched.propose(pool, tasks)
        # 1. suspended ⊆ running
        assert set(d.suspend) <= {t.task_id for t in tasks}
        # 2. no suspension below yellow
        if live_frac < cfg.yellow:
            assert d.suspend == []
        # 3. at least min_running tasks stay active
        assert len(tasks) - len(d.suspend) >= min(len(tasks), cfg.min_running)
        # 4. the FIFO queue exactly mirrors the suspension decision
        assert list(sched.suspended_queue) == d.suspend


# -------------------------------------------------------------- sampler test
class TestSampler:
    def test_observe_and_stats(self):
        s = Sampler()
        for i in range(1, 10):
            s.observe("a", processed_bytes=i * 10.0, total_bytes=100.0,
                      live_bytes=i * 30.0)
        (st_,) = s.stats(["a"])
        assert st_.progress == pytest.approx(0.9)
        assert st_.rate == pytest.approx(3.0)
        assert st_.remaining_bytes == pytest.approx(10.0)
        assert st_.model is UsageModel.LINEAR
        s.forget("a")
        (st2,) = s.stats(["a"])
        assert st2.consumption == 0.0
