"""Checkpoint / delta-migration / restore properties (DESIGN.md §11).

The state-management layer added for elastic serving makes three hard
promises, each pinned here:

* **Determinism survives recovery** — greedy decode is deterministic, so
  a request restored from a KV checkpoint (or re-run from zero) must
  produce bit-identical tokens to an undisturbed run;
* **A restore beats a cold reset** — replay work after a crash with a
  checkpoint is strictly less than the replay-from-zero counterfactual
  whenever the checkpoint covered anything;
* **One page, one tier** — a restored page lands in HBM through the
  import path and nowhere else: the page table and the compressed tier
  store never both claim the same (request, page) at once.

The hypothesis stream drives random submit/step/crash interleavings
against periodic checkpoints, the way `test_cluster` does for the
migration plane.
"""

import os
import tempfile

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCHS
from repro.models import init_model
from repro.serve import (
    ClusterConfig,
    EngineConfig,
    Request,
    ServingCluster,
    ServingEngine,
)
from repro.serve.kv_cache import DEMOTED, kv_bytes_per_token


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine_factory(cfg, tokens=120, n_slots=3):
    cap = kv_bytes_per_token(cfg) * tokens

    def make():
        return EngineConfig(
            n_slots=n_slots, max_seq=64, hbm_capacity_bytes=cap
        )

    return make


def _prompts(n):
    return [[2 + (7 * i + j) % 40 for j in range(6 + i)] for i in range(n)]


def _reference_tokens(cfg, params, prompts, max_new):
    """Undisturbed single-engine run: the bit-exact answer key."""
    eng = ServingEngine(cfg, params, _engine_factory(cfg)())
    for i, p in enumerate(prompts):
        eng.submit(Request(f"q{i}", "T", list(p), max_new))
    eng.run(max_ticks=600)
    return {
        rid: list(r.generated) for rid, r in eng.requests.items()
    }


def _assert_one_tier_per_page(cl):
    """The page table and the compressed tier store must never both
    hold the same (request, page): DEMOTED table entries have a block,
    resident entries must not."""
    for eng in cl.replicas:
        tiers = eng.kv.tiers
        block_keys = set()
        if tiers is not None:
            block_keys = {
                k for k in tiers._blocks if k and k[0] == "req"
            }
        for rid in eng.requests:
            table = eng.kv.page_table(rid)
            for idx, pid in enumerate(table):
                key = ("req", rid, idx)
                if pid == DEMOTED:
                    assert key in block_keys, (
                        f"{key} demoted but no tier block"
                    )
                else:
                    assert key not in block_keys, (
                        f"{key} resident in HBM AND in a tier"
                    )


class TestCrashRestoreProperties:
    @settings(max_examples=5, deadline=None)
    @given(
        ops=st.lists(
            st.tuples(
                st.sampled_from(["step", "crash"]),
                st.integers(min_value=0, max_value=7),
            ),
            min_size=3,
            max_size=10,
        )
    )
    def test_random_crash_restore_stream(self, small_model, ops):
        """Random step/crash interleavings against periodic KV
        checkpoints: tokens stay bit-identical to an undisturbed run,
        restored replay is strictly cheaper than replay-from-zero, and
        no page ever sits in two tiers at once."""
        cfg, params = small_model
        prompts = _prompts(3)
        max_new = 8
        reference = _reference_tokens(cfg, params, prompts, max_new)
        ckpt_dir = tempfile.mkdtemp(prefix="ckpt_prop_")
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=_engine_factory(cfg), n_replicas=2,
                max_retries=4, retry_backoff_ticks=1.0,
                max_backoff_ticks=2.0,
                checkpoint_every_ticks=3, checkpoint_dir=ckpt_dir,
            ),
        )
        for i, p in enumerate(prompts):
            cl.submit(Request(f"q{i}", "T", list(p), max_new))
        # collect tokens AT completion — a later crash of the same
        # replica would otherwise discard the finished history
        final = {}

        def harvest():
            for eng in cl.replicas:
                for rid, r in eng.requests.items():
                    if r.state == "done":
                        final[rid] = list(r.generated)

        n_crashes = 0
        for kind, arg in ops:
            if kind == "step":
                for _ in range(1 + arg):
                    cl.step()
                    harvest()
            elif kind == "crash" and n_crashes < 2:
                n_crashes += 1
                cl.crash_replica(arg % 2)
                _assert_one_tier_per_page(cl)
        while cl.has_pending and cl.tick < 800:
            cl.step()
            harvest()
        _assert_one_tier_per_page(cl)
        assert sorted(cl.completed) == [f"q{i}" for i in range(3)]
        # (1) bit-identical greedy tokens, crash or no crash
        for rid, toks in final.items():
            assert toks == reference[rid], f"{rid} diverged after restore"
        # (2) restored replay strictly below the from-zero counterfactual
        if cl.ckpt_restored_tokens > 0:
            assert (
                cl.ckpt_replayed_tokens < cl.ckpt_from_zero_tokens
            ), "a covering checkpoint must beat a cold reset"
        # conservation: kept + replayed work covers the from-zero work
        if cl.ckpt_restored_requests:
            assert (
                cl.ckpt_restored_tokens + cl.ckpt_replayed_tokens
                >= cl.ckpt_from_zero_tokens
            )

    def test_checkpoint_file_roundtrip(self, small_model, tmp_path):
        """_write_checkpoint / _read_checkpoint invert each other: rid,
        pos, generated, and every page payload come back bit-exact from
        the self-describing file."""
        cfg, params = small_model
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=_engine_factory(cfg), n_replicas=1,
                checkpoint_every_ticks=4,
                checkpoint_dir=str(tmp_path),
            ),
        )
        for i, p in enumerate(_prompts(2)):
            cl.submit(Request(f"q{i}", "T", list(p), 12))
        for _ in range(6):
            cl.step()
        snap = cl.replicas[0].snapshot_kv()
        assert snap is not None and snap["reqs"]
        cl._write_checkpoint(0, snap)
        back = cl._read_checkpoint(0)
        for entry in snap["reqs"]:
            rid = entry["rid"]
            assert back[rid]["pos"] == entry["pos"]
            assert back[rid]["generated"] == [
                int(t) for t in entry["generated"]
            ]
            for idx, payload in entry["pages"].items():
                np.testing.assert_array_equal(
                    back[rid]["pages"][idx], np.asarray(payload)
                )

    def test_checkpoint_pruning_keeps_newest(self, small_model, tmp_path):
        cfg, params = small_model
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=_engine_factory(cfg), n_replicas=1,
                checkpoint_every_ticks=2,
                checkpoint_dir=str(tmp_path), checkpoint_keep=2,
            ),
        )
        for i, p in enumerate(_prompts(2)):
            cl.submit(Request(f"q{i}", "T", list(p), 20))
        for _ in range(12):
            cl.step()
        files = sorted(os.listdir(tmp_path / "r0"))
        assert len(files) <= 2
        assert cl.ckpt_saved > 2  # older files were written, then pruned


class TestDeltaMigration:
    def test_delta_cutover_ships_fewer_bytes_than_full(self, small_model):
        """Engine-level delta protocol: a cutover against a pre-copy
        baseline charges only the dirty pages — strictly below the
        monolithic counterfactual once clean pages exist — and the
        merged payloads still cover the whole resident set."""
        cfg, params = small_model
        eng = ServingEngine(cfg, params, _engine_factory(cfg)())
        eng.submit(Request("m0", "T", list(range(2, 20)), 24))
        for _ in range(6):
            eng.step()
        snap = eng.precopy_request("m0")
        assert snap is not None and snap.payloads
        for _ in range(3):  # keep serving: only the tail page dirties
            eng.step()
        ticket = eng.export_request("m0", baseline=snap)
        assert ticket is not None
        assert ticket.full_wire_bytes > 0, "delta path must have run"
        assert ticket.wire_bytes < ticket.full_wire_bytes
        assert ticket.precopy_wire_bytes == snap.wire_bytes
        assert 0 < ticket.delta_pages < len(ticket.page_payloads)
        # the merged set covers everything a monolithic copy would
        req = ticket.request
        pages_needed = -(-req.pos // eng.kv.page_tokens)
        assert all(
            i in ticket.page_payloads for i in range(pages_needed)
        )

    def test_import_after_delta_cutover_is_bit_exact(self, small_model):
        """The migrated request continues on the target with the same
        tokens an undisturbed engine produces."""
        cfg, params = small_model
        prompts = _prompts(1)
        reference = _reference_tokens(cfg, params, prompts, 10)
        src = ServingEngine(cfg, params, _engine_factory(cfg)())
        src.submit(Request("q0", "T", list(prompts[0]), 10))
        for _ in range(4):
            src.step()
        snap = src.precopy_request("q0")
        for _ in range(2):
            src.step()
        ticket = src.export_request("q0", baseline=snap)
        assert ticket is not None
        dst = ServingEngine(cfg, params, _engine_factory(cfg)())
        dst.import_request(ticket)
        dst.run(max_ticks=200)
        assert list(dst.requests["q0"].generated) == reference["q0"]
