"""Per-architecture smoke tests (deliverable f).

Every assigned arch instantiates a REDUCED same-family config (small dims,
few experts, tiny vocab) and runs one forward + one train step on CPU,
asserting output shapes and finiteness.  The FULL configs are exercised
only via the dry-run (ShapeDtypeStructs, no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import decode_step, forward, init_model, prefill
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import make_train_step

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.enc_layers:
        batch["frame_embeds"] = jax.random.normal(
            key, (b, s * 2, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finiteness(arch, key):
    cfg = ARCHS[arch].smoke()
    params = init_model(cfg, key)
    batch = _batch(cfg, key)
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    logits = forward(cfg, params, batch["tokens"], extra=extra or None, remat=False)
    b, s = batch["tokens"].shape
    expect_s = s + (cfg.vision_tokens if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch, key):
    cfg = ARCHS[arch].smoke()
    params = init_model(cfg, key)
    from repro.optim import adamw

    opt_state = adamw.init(params)
    step = make_train_step(
        cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4), remat=False
    )
    batch = _batch(cfg, key)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(new_opt.step) == 1
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params,
        new_params,
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0


@pytest.mark.parametrize(
    "arch",
    ["internlm2-1.8b", "gemma3-1b", "qwen1.5-110b", "mamba2-2.7b",
     "zamba2-1.2b", "whisper-base"],
)
def test_prefill_decode_matches_forward(arch, key):
    """Prefill + step-by-step decode must reproduce full-forward logits
    (exact for dense; small bf16/state tolerance for SSM; MoE archs are
    excluded — capacity dropping differs between batch shapes by design)."""
    cfg = ARCHS[arch].smoke()
    params = init_model(cfg, key)
    S, B, GEN = 12, 2, 3
    batch = _batch(cfg, key, b=B, s=S + GEN)
    tokens = batch["tokens"]
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    full = forward(cfg, params, tokens, extra=extra or None, remat=False)
    logits_p, caches = prefill(
        cfg, params, tokens[:, :S], extra=extra or None, max_seq=S + GEN,
        remat=False,
    )
    tol = 0.15 if cfg.ssm is not None else 1e-3
    assert float(jnp.abs(logits_p[:, -1] - full[:, S - 1]).max()) <= tol
    for t in range(GEN):
        logits_d, caches = decode_step(
            cfg, params, tokens[:, S + t : S + t + 1], caches, jnp.int32(S + t)
        )
        err = float(jnp.abs(logits_d[:, 0] - full[:, S + t]).max())
        assert err <= tol, f"{arch} decode step {t}: err {err}"


def test_all_archs_have_param_counts_near_advertised():
    expected = {
        "granite-moe-3b-a800m": 3.3e9,
        "deepseek-v2-236b": 236e9,
        "internlm2-1.8b": 1.8e9,
        "stablelm-1.6b": 1.6e9,
        "gemma3-1b": 1.0e9,
        "qwen1.5-110b": 111e9,
        "internvl2-26b": 20e9,  # LM backbone of the 26B VLM
        "whisper-base": 0.09e9,
        "mamba2-2.7b": 2.7e9,
        "zamba2-1.2b": 1.2e9,
    }
    for arch, target in expected.items():
        n = ARCHS[arch].param_count()
        assert 0.6 * target <= n <= 1.45 * target, (
            f"{arch}: {n / 1e9:.2f}B vs advertised {target / 1e9:.2f}B"
        )


def test_ring_buffer_wrap(key):
    """Sliding-window ring cache must be EXACT through multiple wraps."""
    import dataclasses

    cfg = dataclasses.replace(ARCHS["gemma3-1b"].smoke(), sliding_window=4)
    params = init_model(cfg, key)
    S, B, GEN = 10, 2, 8  # generation wraps the 4-slot ring twice
    tokens = jax.random.randint(key, (B, S + GEN), 0, cfg.vocab)
    full = forward(cfg, params, tokens, remat=False)
    lp, caches = prefill(cfg, params, tokens[:, :S], max_seq=S + GEN, remat=False)
    assert float(jnp.abs(lp[:, -1] - full[:, S - 1]).max()) < 1e-3
    for t in range(GEN):
        ld, caches = decode_step(
            cfg, params, tokens[:, S + t : S + t + 1], caches, jnp.int32(S + t)
        )
        assert float(jnp.abs(ld[:, 0] - full[:, S + t]).max()) < 1e-3
